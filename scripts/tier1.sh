#!/usr/bin/env bash
# Tier-1 verify.
#
# Lane 1 is the canonical single-device suite (ROADMAP "Tier-1 verify").
# Lane 2 re-runs the device-gated test files with 8 fake CPU devices
# (XLA_FLAGS=--xla_force_host_platform_device_count=8), so the in-process
# multi-device tests — the ones that `pytest.skip("needs N devices")` on a
# 1-device host — actually execute instead of silently skipping.  The
# subprocess-based tests in tests/test_multidevice.py force their own
# device count; lane 2 additionally covers the shard_map tests that run in
# the pytest process itself (e.g. tests/test_core_scan_comm.py's
# multi-device classes).
#
# JAX_PLATFORMS=cpu everywhere: containers with libtpu baked in otherwise
# burn minutes probing TPU metadata (see repo memory / PR 1).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export JAX_PLATFORMS=${JAX_PLATFORMS:-cpu}

echo "== tier-1 lane 0: host-tier safety audit (jax-free, strict) =="
# Pure-AST pass over the host code: donated-buffer lifetimes at every
# jit call site + lock discipline across the watchdog/saver/monitor
# threads.  Runs before any lane that imports jax — a use-after-donate
# or a lock-order cycle fails the build before anything compiles.
python -m repro.analysis --passes hostsafety --strict

echo "== tier-1 lane 1: full suite (single device) =="
python -m pytest -x -q "$@"

echo "== tier-1 lane 2: multi-device (8 fake CPU host devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}" \
    python -m pytest -x -q tests/test_core_scan_comm.py tests/test_multidevice.py

echo "== tier-1 lane 3: benchmark-path smoke (tiny shapes, no timing) =="
# Catches bench-path regressions (import errors, dispatch wiring, row
# schema drift) at CI speed; never rewrites BENCH_kernels.json.
python -m benchmarks.run --smoke

echo "== tier-1 lane 3b: continuous-serve smoke =="
# End-to-end scheduler path: ragged queue, slot recycling, in-window
# sampling — the launcher exits nonzero on any scheduler invariant break.
python -m repro.launch.serve --arch rwkv6-1.6b --smoke --continuous \
    --requests 5 --slots 2 --prompt-len 8 --new-tokens 6 --max-len 32 \
    --decode-window 2 --temperature 0.8 --top-k 16

echo "== tier-1 lane 3c: chaos smoke (fault isolation drill) =="
# Serve under a fixed injection seed: a pinned NaN-in-state fault plus a
# pinned dispatch drop.  The launcher exits nonzero unless every fault is
# quarantined+recovered AND every request's stream is bit-identical to
# the fault-free run (the one-slot blast-radius invariant).
python -m repro.launch.serve --arch rwkv6-1.6b --smoke --continuous \
    --requests 6 --slots 2 --prompt-len 8 --new-tokens 6 --max-len 64 \
    --decode-window 2 --chaos-seed 7 --chaos-nan-at 2 --chaos-drop-at 4 \
    --watchdog-timeout 30

echo "== tier-1 lane 3d: paged-serve smoke (pooled KV + shared prefix) =="
# Pooled KV pages + page tables + one 40-token shared prefix, sampled
# decoding, tight pool (4 private pages per node).  The launcher exits
# nonzero unless every stream is bit-identical to a dense reference
# engine, the page-table audit is clean, and the explicitly sized pool
# beats the dense footprint.
python -m repro.launch.serve --arch gemma3-1b --smoke --continuous --paged \
    --requests 5 --slots 2 --prompt-len 6 --new-tokens 6 --max-len 128 \
    --decode-window 2 --prefix-len 40 --pool-pages 4 \
    --temperature 0.8 --top-k 16
# The paged bench row (admission-cost ratio + footprint fields) must be
# present in the committed benchmark results.
grep -q '"name": "serve_paged"' BENCH_kernels.json

echo "== tier-1 lane 3e: fleet chaos smoke (bitflip + replica kill) =="
# Three engine replicas behind the fleet router.  The victim gets a
# silent bit flip at its first decode window (invisible to isfinite;
# the uint32 checksum chain must catch it within the 2-window spot
# cadence) and is then killed outright at dispatch 3.  The launcher
# exits nonzero unless the corruption is detected, the kill fires, the
# victim's in-flight requests resume on survivors from its last atomic
# snapshot, and every stream is bit-identical to a fault-free
# single-engine run.
python -m repro.launch.serve --arch rwkv6-1.6b --smoke --continuous \
    --replicas 3 --requests 6 --slots 2 --prompt-len 10 --new-tokens 16 \
    --max-len 96 --decode-window 4 --snapshot-every 1 --checksum-every 2 \
    --chaos-bitflip-at 1 --chaos-replica-kill-at 3
# The fleet bench row (goodput under replica kill + modeled drain) must
# be present in the committed benchmark results.
grep -q '"name": "serve_fleet"' BENCH_kernels.json

echo "== tier-1 lane 3f: forced-interleaving drill (8 seeded schedules) =="
# The dynamic complement to lane 0's static audit: a seeded scheduler
# forces preemption windows at every lock acquire/release and jit
# dispatch boundary while a 2-replica fleet serves a chaos workload
# (pinned NaN + dispatch drop).  Exits nonzero unless every schedule's
# streams are bit-identical to the fault-free single-engine baseline.
python -m repro.serve.interleave --arch rwkv6-1.6b --seeds 8

echo "== tier-1 lane 4: static audit (repro.analysis, strict) =="
# Every analysis pass over every default arch family — collectives,
# donation, dtype flow, VMEM budgets, ring slack, retrace sentinel —
# on a single device and on an 8-device fake mesh (where the collective
# budget audit and the cost-model cross-check are non-degenerate).
# --strict: WARN findings fail the lane too.
for n in 1 8; do
    python -m repro.analysis --strict --fake-devices "$n"
done
