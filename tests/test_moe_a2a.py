"""a2a (shard_map all-to-all) MoE must match the gather MoE numerically."""

import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.configs.registry import get_config
    from repro.model import moe as moe_mod
    from repro.model.moe_a2a import apply_moe_sharded
    from repro.model.sharding import init_mk, make_rules, sharding_context

    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:  # jax 0.4.x: auto mode is the only (and default) behavior
        mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        get_config("dbrx-132b").reduced(),
        d_model=32, d_ff=64, num_experts=8, num_experts_per_tok=2,
        moe_capacity_factor=8.0,  # generous: no drops -> exact match
    )
    mk = init_mk(jax.random.key(0), jnp.float32)
    params = moe_mod.init_moe(mk, cfg, "moe")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, 32)).astype(np.float32))

    rules = make_rules(mesh, "train")
    with mesh, sharding_context(mesh, rules):
        ref = jax.jit(lambda p, v: moe_mod.apply_moe(p, v, cfg))(params, x)
        out = jax.jit(lambda p, v: apply_moe_sharded(p, v, cfg))(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)

    # Capacity drops differ between local (per-shard) and global routing —
    # just assert finiteness under pressure.
    with mesh, sharding_context(mesh, rules):
        tight = jax.jit(
            lambda p, v: apply_moe_sharded(p, v, dataclasses.replace(
                cfg, moe_capacity_factor=1.0))
        )(params, x)
    assert bool(jnp.isfinite(tight).all())

    # Gradients flow through the a2a path.
    with mesh, sharding_context(mesh, rules):
        g = jax.jit(jax.grad(
            lambda p: apply_moe_sharded(p, x, cfg).sum()
        ))(params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    assert any(float(jnp.abs(l).max()) > 0 for l in jax.tree.leaves(g))

    print("MOE_A2A_OK")
    """
)


def test_moe_a2a_matches_gather():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root",
             # The script forces host-platform devices; skip TPU probing
             # (30-retry metadata fetches) in containers with libtpu baked in.
             "JAX_PLATFORMS": "cpu"},
        timeout=900,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"
    assert "MOE_A2A_OK" in res.stdout
