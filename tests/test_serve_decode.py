"""Serve-side decode tests: windowed ServeEngine parity, dispatch counts,
multi-token decode_step vs the full forward (incl. ring-buffer wrap), and
the donated-state (no per-step cache copy) regression checks."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.model import model as M
from repro.serve.engine import ServeEngine, make_cache_prefill_step

jax.config.update("jax_platform_name", "cpu")


def _reference_generate(cfg, params, prompts, n_new, max_len=64):
    """The pre-windowed engine loop: per-token prefill + per-token decode
    (no donation, no windows) — the behavioral oracle for generate()."""
    dec = jax.jit(lambda p, s, t, l: M.decode_step(p, cfg, s, t, l))
    b, p_len = prompts.shape
    state = M.init_decode_state(cfg, batch=b, max_len=max_len)
    logits = None
    for i in range(p_len):
        logits, state = dec(params, state, prompts[:, i : i + 1], jnp.int32(i))
    out = [prompts]
    cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for j in range(n_new):
        out.append(cur)
        if j == n_new - 1:
            break
        logits, state = dec(params, state, cur, jnp.int32(p_len + j))
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return jnp.concatenate(out, axis=1)


def _setup(arch, seed=0, batch=2, p_len=7):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, p_len)), jnp.int32)
    return cfg, params, prompts


class TestServeEngineWindows:
    def test_rwkv6_parity_and_dispatch_count(self):
        cfg, params, prompts = _setup("rwkv6-1.6b")
        n_new = 13
        ref = _reference_generate(cfg, params, prompts, n_new)
        for k_win in (1, 4, 8, 32):
            eng = ServeEngine(cfg, params, max_len=64, decode_window=k_win)
            out = eng.generate(prompts, n_new)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
            # Acceptance: exactly ceil(num_new_tokens / K) decode dispatches.
            assert eng.last_decode_dispatches == math.ceil(n_new / k_win)

    def test_rwkv6_parity_vs_full_forward_argmax(self):
        # Teacher-forced check against the training forward: every
        # generated token must equal the argmax of the full forward's
        # logits at the previous position.
        cfg, params, prompts = _setup("rwkv6-1.6b")
        n_new = 9
        eng = ServeEngine(cfg, params, max_len=64, decode_window=4)
        out = eng.generate(prompts, n_new)
        full = M.forward(params, cfg, out[:, :-1])
        want = jnp.argmax(full[:, prompts.shape[1] - 1 :], axis=-1)
        np.testing.assert_array_equal(
            np.asarray(out[:, prompts.shape[1] :]), np.asarray(want))

    def test_attention_arch_parity(self):
        # gemma3: local (ring-buffer) + global layers through the same
        # windowed loop.
        cfg, params, prompts = _setup("gemma3-1b")
        n_new = 9
        ref = _reference_generate(cfg, params, prompts, n_new)
        eng = ServeEngine(cfg, params, max_len=64, decode_window=8)
        out = eng.generate(prompts, n_new)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        assert eng.last_decode_dispatches == math.ceil(n_new / 8)

    def test_generate_zero_and_one_token(self):
        cfg, params, prompts = _setup("rwkv6-1.6b")
        eng = ServeEngine(cfg, params, max_len=64, decode_window=8)
        out0 = eng.generate(prompts, 0)
        np.testing.assert_array_equal(np.asarray(out0), np.asarray(prompts))
        out1 = eng.generate(prompts, 1)
        ref1 = _reference_generate(cfg, params, prompts, 1)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(ref1))
        assert eng.last_decode_dispatches == 1


class TestWindowedDecodeStep:
    def test_windows_match_forward_across_ring_wrap(self):
        # 90 teacher-forced tokens through 7-token decode windows on
        # gemma3 (attn_window 64): the local-layer ring wraps mid-stream;
        # logits must still match the full forward everywhere.
        cfg, params, _ = _setup("gemma3-1b")
        rng = np.random.default_rng(3)
        B, T, K = 2, 90, 7
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
        full = M.forward(params, cfg, tokens)
        state = M.init_decode_state(cfg, batch=B, max_len=128, insert_window=K)
        outs, pos = [], 0
        while pos < T:
            k = min(K, T - pos)
            lg, state = M.decode_step(
                params, cfg, state, tokens[:, pos : pos + k], jnp.int32(pos))
            outs.append(lg)
            pos += k
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)

    def test_one_shot_prefill_matches_forward(self):
        for arch in ("rwkv6-1.6b", "recurrentgemma-2b"):
            cfg, params, _ = _setup(arch)
            rng = np.random.default_rng(4)
            tokens = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (2, 11)), jnp.int32)
            full = M.forward(params, cfg, tokens)
            state = M.init_decode_state(cfg, batch=2, max_len=64,
                                        insert_window=11)
            # max_len= vouches for the local ring capped at the position
            # limit (recurrentgemma's ring is 64 = max_len < window+t-1).
            got, _ = M.decode_step(params, cfg, state, tokens, jnp.int32(0),
                                   max_len=64)
            np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                       rtol=2e-4, atol=2e-4)

    def test_window_wider_than_ring_fails_loudly(self):
        # A window exceeding the ring would evict positions in-window
        # queries still need — must raise at trace time, not corrupt
        # logits (contract: init_decode_state(insert_window >= K)).
        cfg, params, _ = _setup("gemma3-1b")
        tokens = jnp.zeros((1, 70), jnp.int32)  # ring = attn_window = 64
        state = M.init_decode_state(cfg, batch=1, max_len=256)
        with pytest.raises(ValueError, match="insert_window"):
            M.decode_step(params, cfg, state, tokens, jnp.int32(0))

    def test_insert_window_sizes_local_ring(self):
        cfg, _, _ = _setup("gemma3-1b")  # attn_window 64 (reduced)
        w = cfg.attn_window

        def local_cache_len(state):
            from repro.model.attention import KVCache

            caches = [s for s in jax.tree.leaves(
                state, is_leaf=lambda x: isinstance(x, KVCache))
                if isinstance(s, KVCache)]
            return min(c.k.shape[-2] for c in caches)

        s1 = M.init_decode_state(cfg, batch=1, max_len=256)
        assert local_cache_len(s1) == w  # insert_window=1: unchanged
        s8 = M.init_decode_state(cfg, batch=1, max_len=256, insert_window=8)
        assert local_cache_len(s8) == w + 7
        s_cap = M.init_decode_state(cfg, batch=1, max_len=48, insert_window=8)
        assert local_cache_len(s_cap) == 48  # capped at max_len


class TestDonatedState:
    """No per-step cache copy: XLA must alias the decode state in place."""

    def _lowered_text(self, fn, *args):
        return fn.lower(*args).compile().as_text()

    def test_single_step_fallback_donates(self):
        # Regression for the undonated jit: the per-token fallback path
        # must alias state buffers too, or every step copies the caches.
        cfg, params, prompts = _setup("gemma3-1b")
        eng = ServeEngine(cfg, params, max_len=32)
        state = M.init_decode_state(cfg, batch=2, max_len=32)
        txt = self._lowered_text(
            eng._decode, params, state, prompts[:, :1], jnp.int32(0))
        assert "input_output_alias" in txt
        # Buffer-id check: donated leaves are updated in place on CPU.
        out_state_ptrs = None
        in_ptrs = {l.unsafe_buffer_pointer()
                   for l in jax.tree.leaves(state) if l.size > 1}
        _, new_state = eng._decode(params, state, prompts[:, :1], jnp.int32(0))
        out_state_ptrs = {l.unsafe_buffer_pointer()
                         for l in jax.tree.leaves(new_state) if l.size > 1}
        assert in_ptrs & out_state_ptrs, "no state buffer was reused in place"

    def test_window_step_donates(self):
        cfg, params, prompts = _setup("rwkv6-1.6b")
        eng = ServeEngine(cfg, params, max_len=32, decode_window=4)
        fn = eng._window_step(4, last=False)
        state = M.init_decode_state(cfg, batch=2, max_len=32, insert_window=4)
        cur = prompts[:, :1]
        txt = self._lowered_text(fn, params, state, cur, jnp.int32(0))
        assert "input_output_alias" in txt

    def test_prefill_donates(self):
        cfg, params, prompts = _setup("rwkv6-1.6b")
        prefill = make_cache_prefill_step(cfg)
        state = M.init_decode_state(cfg, batch=2, max_len=32,
                                    insert_window=prompts.shape[1])
        txt = self._lowered_text(prefill, params, state, prompts)
        assert "input_output_alias" in txt


class TestCachePrefillStep:
    def test_mesh_routing_matches_plain(self):
        # 1-device mesh: the seq/plain rule routing must not change the
        # numbers (the multi-device lane covers n > 1).
        from repro.launch.mesh import make_seq_mesh

        cfg, params, prompts = _setup("rwkv6-1.6b", p_len=16)
        mesh = make_seq_mesh(1)
        state_a = M.init_decode_state(cfg, batch=2, max_len=64,
                                      insert_window=16)
        state_b = M.init_decode_state(cfg, batch=2, max_len=64,
                                      insert_window=16)
        lg_plain, _ = make_cache_prefill_step(cfg)(params, state_a, prompts)
        # min_len=8 forces the seq-rules route for this 16-token prompt.
        lg_seq, _ = make_cache_prefill_step(cfg, mesh, min_len=8)(
            params, state_b, prompts)
        np.testing.assert_allclose(np.asarray(lg_seq), np.asarray(lg_plain),
                                   rtol=2e-4, atol=2e-4)
