"""Deterministic interleaving drill (``repro.serve.interleave``):
schedule decisions are a pure function of (seed, tag, index); the
instrumented lock behaves as a lock while forcing preemption windows;
``installed()`` restores the production hooks on every exit path; and a
small two-replica chaos drill stays bit-identical under forced
schedules (the full 8-schedule version is tier-1 lane 3f)."""

import threading

import pytest

from repro.ft import watchdog as W
from repro.serve import interleave as I


class TestForcedSchedule:
    def test_decisions_are_seed_deterministic(self):
        a = I.ForcedSchedule(3).decisions("lock.acquire", 64)
        b = I.ForcedSchedule(3).decisions("lock.acquire", 64)
        assert a == b
        assert True in a and False in a

    def test_different_seeds_and_tags_differ(self):
        base = I.ForcedSchedule(3).decisions("lock.acquire", 64)
        assert I.ForcedSchedule(4).decisions("lock.acquire", 64) != base
        assert I.ForcedSchedule(3).decisions("lock.release", 64) != base

    def test_point_counts_and_preempts(self):
        sched = I.ForcedSchedule(0, p_preempt=1.0, max_sleep_s=0.0)
        for _ in range(5):
            sched.point("t")
        assert sched.counts["t"] == 5
        assert sched.preemptions == 5

    def test_inactive_schedule_is_free(self):
        sched = I.ForcedSchedule(0, p_preempt=1.0)
        sched.active = False
        sched.point("t")
        assert sched.counts["t"] == 0
        assert sched.preemptions == 0

    def test_decision_sequence_matches_point_behavior(self):
        """point() consumes exactly the decision stream decisions()
        predicts — the property the bit-identity drill leans on."""
        sched = I.ForcedSchedule(7, max_sleep_s=0.0)
        want = sched.decisions("x", 32)
        before = 0
        got = []
        for _ in range(32):
            sched.point("x")
            got.append(sched.preemptions > before)
            before = sched.preemptions
        assert got == want


class TestInstrumentedLock:
    def test_is_a_lock(self):
        sched = I.ForcedSchedule(0, max_sleep_s=0.0)
        lock = I.InstrumentedLock(sched)
        assert not lock.locked()
        with lock:
            assert lock.locked()
        assert not lock.locked()
        assert sched.counts["lock.acquire"] == 1
        assert sched.counts["lock.release"] == 1

    def test_mutual_exclusion_under_forcing(self):
        """A hammered counter stays exact: the wrapper forces windows
        around the critical section, never inside its atomicity."""
        sched = I.ForcedSchedule(1, p_preempt=0.3, max_sleep_s=1e-4)
        lock = I.InstrumentedLock(sched)
        state = {"n": 0}

        def work():
            for _ in range(50):
                with lock:
                    n = state["n"]
                    state["n"] = n + 1

        threads = [threading.Thread(target=work) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert state["n"] == 200
        assert sched.preemptions > 0


class TestInstalled:
    def test_hooks_swapped_and_restored(self):
        from repro.serve import engine as E

        sched = I.ForcedSchedule(0, max_sleep_s=0.0)
        prev_hook = E.dispatch_hook
        with I.installed(sched):
            assert isinstance(W.make_lock(), I.InstrumentedLock)
            E.dispatch_hook("pre", "decode")
        assert sched.counts["dispatch.pre.decode"] == 1
        assert type(W.make_lock()) is type(threading.Lock())
        assert E.dispatch_hook is prev_hook
        assert sched.active is False

    def test_restored_on_exception(self):
        sched = I.ForcedSchedule(0, max_sleep_s=0.0)
        with pytest.raises(RuntimeError):
            with I.installed(sched):
                raise RuntimeError("boom")
        assert type(W.make_lock()) is type(threading.Lock())


class TestDrill:
    def test_two_schedule_chaos_drill_bit_identical(self):
        stats = I.run_drill("rwkv6-1.6b", seeds=range(2))
        assert stats["schedules"] == 2
        assert stats["preemptions"] > 0
        assert stats["points"] > stats["preemptions"]

    def test_divergence_raises(self, monkeypatch):
        """A drill that cannot fail witnesses nothing: poison the
        baseline and require the drill to notice."""
        import numpy as np

        real = np.array_equal
        monkeypatch.setattr(np, "array_equal", lambda a, b: False)
        try:
            with pytest.raises(RuntimeError, match="diverged"):
                I.run_drill("rwkv6-1.6b", seeds=range(1))
        finally:
            monkeypatch.setattr(np, "array_equal", real)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-v"]))
