"""Host-tier safety audit (``repro.analysis.hostsafety``): the clean
tree audits zero-error with its waivers surfaced; four reintroduced
historical/likely bugs (use-after-donate in the decode loop, the PR 6
unlocked watchdog result-write, a dropped stale-thread fence, the PR 9
pre-round ``busy`` sample) are each caught at the right location with
ERROR severity; the AST-derived donation registry agrees with the live
``audit_jit_entrypoints`` declarations; synthetic fixtures cover the
lock-order cycle detector and the waiver downgrade path.

Everything here is jax-free except the registry cross-check (which
builds the real entrypoint declarations to diff against the AST).
"""

import pytest

from repro.analysis import hostsafety as HS
from repro.analysis.findings import Severity

# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _errors(findings):
    return [f for f in findings if f.severity >= Severity.ERROR]


def _warns(findings):
    return [f for f in findings if f.severity == Severity.WARN]


def _mutated(rel, old, new):
    """Real tree sources with ``old`` -> ``new`` applied in ``rel``.

    Asserts the anchor text still exists exactly once, so a refactor
    that moves the code fails loudly here instead of silently turning
    the drill into a no-op.
    """
    srcs = HS._read_tree_sources()
    assert rel in srcs, f"{rel} missing from HOST_MODULES sources"
    assert srcs[rel].count(old) == 1, (
        f"mutation anchor drifted in {rel}: {old!r} found "
        f"{srcs[rel].count(old)} times")
    srcs[rel] = srcs[rel].replace(old, new)
    return srcs


def _the_error(findings, rule, loc_parts):
    """The single ERROR matching ``rule``; asserts its location."""
    errs = [f for f in _errors(findings) if f"[{rule}]" in f.message]
    assert errs, (
        f"mutation not caught: no [{rule}] ERROR in "
        f"{[f.message for f in _errors(findings)]}")
    f = errs[0]
    for part in loc_parts:
        assert part in f.location, (
            f"[{rule}] caught at {f.location}, expected {part!r} in it")
    assert f.severity == Severity.ERROR
    return f


# --------------------------------------------------------------------------
# clean tree
# --------------------------------------------------------------------------


class TestCleanTree:
    def test_zero_errors_zero_warns(self):
        findings = HS.run()
        assert findings, "audit produced no findings at all"
        assert _errors(findings) == []
        assert _warns(findings) == []

    def test_intentional_findings_are_waived_not_silent(self):
        """The known-intentional patterns (the ``_dispatch`` retry
        re-pass, the instrumented-lock wrapper's bare acquire) must
        surface as waived INFO findings — auditable, not invisible."""
        msgs = [f.message for f in HS.run()]
        assert any("[use-after-donate]" in m and "waived" in m
                   for m in msgs)
        assert any("[bare-acquire]" in m and "waived" in m for m in msgs)

    def test_summaries_report_real_coverage(self):
        findings = HS.run()
        don = [f for f in findings if "donation-lifetime" in f.location]
        lck = [f for f in findings if "lock-discipline" in f.location]
        assert don and don[0].metrics["sites"] >= 10
        assert don[0].metrics["donors"] >= 6
        assert "0 violations" in don[0].message
        assert lck and lck[0].metrics["locks"] >= 3
        assert lck[0].metrics["threads"] >= 2
        assert "acyclic" in lck[0].message


# --------------------------------------------------------------------------
# mutation drills: reintroduce four real bug classes
# --------------------------------------------------------------------------


class TestMutationDrills:
    def test_use_after_donate_in_decode_loop(self):
        """Rebinding the window step's output to a fresh name leaves the
        loop re-passing the already-donated state next iteration —
        silent garbage on hardware that honors donation."""
        srcs = _mutated(
            "src/repro/serve/engine.py",
            "toks, state, cur, pos = fn(self.params, state, cur, pos)",
            "toks, new_state, cur, pos = fn(self.params, state, cur, pos)",
        )
        f = _the_error(HS.run_on_sources(srcs), "use-after-donate",
                       ["src/repro/serve/engine.py", "generate"])
        assert "state" in f.message

    WATCHDOG_RESULT_BLOCK = (
        "            with self._lock:\n"
        "                if gen != self._gen:        "
        "# fenced: step was abandoned\n"
        "                    self.stale_discarded += 1\n"
        "                    return\n"
        "                outcome.append((ok, value))"
    )

    def test_unlocked_watchdog_result_write(self):
        """The PR 6 bug class: the worker thread publishing its result
        without the lock races the timeout path's generation bump."""
        srcs = _mutated(
            "src/repro/ft/watchdog.py",
            self.WATCHDOG_RESULT_BLOCK,
            "            if gen != self._gen:        "
            "# fenced: step was abandoned\n"
            "                self.stale_discarded += 1\n"
            "                return\n"
            "            outcome.append((ok, value))",
        )
        _the_error(HS.run_on_sources(srcs), "unlocked-thread-write",
                   ["src/repro/ft/watchdog.py", "StepWatchdog"])

    def test_dropped_stale_thread_fence(self):
        """Lock kept but generation fence dropped: an abandoned worker's
        late result lands in a restarted step's outcome list."""
        srcs = _mutated(
            "src/repro/ft/watchdog.py",
            self.WATCHDOG_RESULT_BLOCK,
            "            with self._lock:\n"
            "                outcome.append((ok, value))",
        )
        _the_error(HS.run_on_sources(srcs), "stale-thread-write",
                   ["src/repro/ft/watchdog.py", "StepWatchdog"])

    def test_busy_sampled_pre_round(self):
        """The PR 9 bug class: the wedge guard's ``busy`` sampled before
        ``step_round()`` mutates the very state it guards."""
        srcs = _mutated(
            "src/repro/serve/fleet.py",
            "                self.step_round()\n"
            "                after = sum(1 for r in self.record "
            "if r is not None)\n"
            "                # Post-round state: a round that completed "
            "nothing is\n"
            "                # still progress if work remains in flight "
            "(busy\n"
            "                # session) or schedulable (shared queue) — "
            "only the\n"
            "                # all-idle, all-drained case is a wedge.\n"
            "                busy = any(self.sessions[i].busy "
            "for i in self._live())",
            "                busy = any(self.sessions[i].busy "
            "for i in self._live())\n"
            "                self.step_round()\n"
            "                after = sum(1 for r in self.record "
            "if r is not None)",
        )
        _the_error(HS.run_on_sources(srcs), "guard-epoch-mix",
                   ["src/repro/serve/fleet.py", "FleetRouter.run"])

    def test_mutations_do_not_break_parsing(self):
        """Paranoia: none of the drills above relied on a parse error."""
        for srcs in (HS._read_tree_sources(),):
            assert not any("[parse]" in f.message
                           for f in HS.run_on_sources(srcs))


# --------------------------------------------------------------------------
# registry cross-check: AST-derived donors vs live declarations
# --------------------------------------------------------------------------


class TestRegistryCrossCheck:
    def test_declared_donors_match_ast(self):
        """Every ``JitEntry`` that declares a ``donor`` symbol must have
        a matching AST-derived donor with the same ``donate_argnums`` —
        so the live jit declarations and the static audit's registry
        cannot drift apart silently."""
        from repro.analysis.registry import jit_entries
        from repro.configs.registry import get_config

        reg = HS.derived_registry()
        derived = dict(reg.attr_donors)
        derived.update(reg.factories)
        entries = jit_entries(get_config("rwkv6-1.6b").reduced())
        assert entries
        checked = 0
        for e in entries:
            if e.donated is None:
                assert e.donate_argnums is None, e.name
                continue
            assert e.donor is not None, (
                f"{e.name}: donating entrypoint without a donor symbol "
                "for the hostsafety cross-check")
            assert e.donor in derived, (
                f"{e.name}: donor {e.donor!r} not derived from the AST "
                f"(have {sorted(derived)})")
            assert tuple(e.donate_argnums) == tuple(
                derived[e.donor].argnums), (
                f"{e.name}: declared donate_argnums {e.donate_argnums} "
                f"!= AST-derived {derived[e.donor].argnums} for {e.donor}")
            checked += 1
        assert checked >= 5

    def test_train_step_donates_state(self):
        reg = HS.derived_registry()
        assert reg.factories["make_jitted_train_step"].argnums == (0,)

    def test_decode_attr_donor(self):
        reg = HS.derived_registry()
        assert reg.attr_donors["_decode"].argnums == (1,)


# --------------------------------------------------------------------------
# synthetic fixtures: cycle detector + waiver downgrade
# --------------------------------------------------------------------------

LOCK_CYCLE_FIXTURE = '''\
import threading


class Pair:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.x = 0
        self.y = 0

    def fwd(self):
        with self._a:
            with self._b:
                self.x += 1

    def rev(self):
        with self._b:
            with self._a:
                self.y += 1
'''


BARE_ACQUIRE_FIXTURE = '''\
import threading


class Holder:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def grab(self):
        self._lock.acquire(){waiver}
        self.n += 1
        self._lock.release(){waiver}
'''


class TestSyntheticFixtures:
    def test_lock_order_cycle_is_an_error(self):
        findings = HS.run_on_sources({"fix/pair.py": LOCK_CYCLE_FIXTURE})
        errs = [f for f in _errors(findings)
                if "[lock-cycle]" in f.message]
        assert errs, [f.message for f in findings]
        assert "deadlock" in errs[0].message

    def test_bare_acquire_warns_without_waiver(self):
        src = BARE_ACQUIRE_FIXTURE.format(waiver="")
        findings = HS.run_on_sources({"fix/holder.py": src})
        assert any("[bare-acquire]" in f.message for f in _warns(findings))
        assert _errors(findings) == []

    def test_waiver_downgrades_to_info_and_is_listed(self):
        src = BARE_ACQUIRE_FIXTURE.format(
            waiver="  # hostsafety: ok(fixture)")
        findings = HS.run_on_sources({"fix/holder.py": src})
        assert _warns(findings) == []
        assert _errors(findings) == []
        waived = [f for f in findings
                  if "[bare-acquire]" in f.message and "waived" in f.message]
        assert len(waived) == 2
        assert any(f.location.endswith(":waivers") and "fixture"
                   in f.message for f in findings)

    def test_parse_error_is_a_finding_not_a_crash(self):
        findings = HS.run_on_sources({"fix/broken.py": "def f(:\n"})
        assert any("[parse]" in f.message for f in _errors(findings))


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-v"]))
