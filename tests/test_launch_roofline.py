"""Tests for the roofline extraction machinery and launch helpers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.launch import roofline as R
from repro.launch.inputs import batch_specs, cell_is_applicable, decode_specs
from repro.core.lowering import scan_unroll, unrolled_cost_mode

jax.config.update("jax_platform_name", "cpu")


class TestCollectiveParsing:
    HLO = """
  %ag = bf16[16,512]{1,0} all-gather(%p0), channel_id=1
  %ar = f32[128]{0} all-reduce(%x), to_apply=%add
  %ars = (f32[64]{0}, f32[32]{0}) all-reduce-start(%a, %b), channel_id=2
  %ard = (f32[64]{0}, f32[32]{0}) all-reduce-done(%ars)
  %cp = bf16[8,128]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %a2a = f32[4,256]{1,0} all-to-all(%z), dimensions={0}
  %rs = bf16[32]{0} reduce-scatter(%w), to_apply=%add
  %unrelated = f32[2,2]{1,0} add(%u, %v)
"""

    def test_bytes_and_counts(self):
        out = R.parse_collective_bytes(self.HLO)
        assert out["all-gather"]["bytes"] == 16 * 512 * 2
        assert out["all-gather"]["count"] == 1
        # all-reduce: plain (128*4) + start tuple (64+32)*4; done skipped.
        assert out["all-reduce"]["bytes"] == 128 * 4 + (64 + 32) * 4
        assert out["all-reduce"]["count"] == 2
        assert out["collective-permute"]["bytes"] == 8 * 128 * 2
        assert out["all-to-all"]["bytes"] == 4 * 256 * 4
        assert out["reduce-scatter"]["bytes"] == 32 * 2
        assert out["total_bytes"] == sum(
            out[k]["bytes"] for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
        )

    def test_real_compiled_module(self):
        # End-to-end: a psum over 1 device still emits an all-reduce line.
        def f(x):
            return x * 2.0

        txt = jax.jit(f).lower(jnp.ones(4)).compile().as_text()
        out = R.parse_collective_bytes(txt)
        assert out["total_bytes"] == 0


class TestRooflineTerms:
    def test_terms_and_dominant(self):
        t = R.roofline_terms(
            {"flops": 197e12, "bytes accessed": 819e9 * 2},
            {"total_bytes": 50e9 * 4 * 3},
        )
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(2.0)
        assert t.collective_s == pytest.approx(3.0)
        assert t.dominant == "collective"
        assert t.bound_time_s == pytest.approx(3.0)

    def test_model_flops_train_vs_prefill(self):
        cfg = get_config("minitron-8b")
        tr = R.model_flops(cfg, SHAPES["train_4k"])
        pf = R.model_flops(cfg, SHAPES["prefill_32k"])
        # train ~3x forward per token; prefill has more tokens here.
        assert tr > 0 and pf > 0
        # 6ND dominates: check within 2x of hand calc.
        hand = 6 * cfg.param_count() * 4096 * 256
        assert 0.5 < tr / hand < 2.0

    def test_decode_flops_scale_with_active_params(self):
        moe = get_config("qwen3-moe-235b-a22b")
        f_moe = R.model_flops(moe, SHAPES["decode_32k"])
        # decode flops = ACTIVE params (22B, not 235B) + attention reads.
        expected = (
            2.0 * moe.active_param_count() * 128
            + R._decode_attention_flops(moe, 32768, 128)
        )
        assert f_moe == pytest.approx(expected, rel=1e-6)
        assert f_moe < 2.0 * moe.param_count() * 128  # far below total-params cost

    def test_analytic_bytes_positive_all_modes(self):
        cfg = get_config("gemma3-1b")
        for shape_name, mode in [
            ("train_4k", "train"), ("prefill_32k", "prefill"),
            ("decode_32k", "decode"), ("long_500k", "decode_long"),
        ]:
            b = R.analytic_hbm_bytes(cfg, SHAPES[shape_name], 256, mode)
            assert b > 0

    def test_local_window_caps_decode_kv_bytes(self):
        g = get_config("gemma3-1b")       # 5:1 local:global, window 1024
        m = get_config("minitron-8b")     # all full attention
        bg = R.analytic_hbm_bytes(g, SHAPES["long_500k"], 256, "decode")
        # For gemma3, local layers read only window-sized KV.
        full_equiv = 26 * SHAPES["long_500k"].seq_len * g.num_kv_heads * g.head_dim * 2 * 2 / 256
        assert bg < full_equiv  # ring buffers beat full caches


class TestInputs:
    def test_applicability_skips(self):
        ok, _ = cell_is_applicable(get_config("minitron-8b"), "long_500k")
        assert not ok
        ok, _ = cell_is_applicable(get_config("rwkv6-1.6b"), "long_500k")
        assert ok
        ok, _ = cell_is_applicable(get_config("minitron-8b"), "train_4k")
        assert ok

    def test_batch_specs_no_allocation(self):
        cfg = get_config("qwen2-vl-7b")
        specs, pspecs = batch_specs(cfg, SHAPES["train_4k"])
        assert isinstance(specs["tokens"], jax.ShapeDtypeStruct)
        assert specs["tokens"].shape == (256, 4096)
        assert specs["frontend_embeds"].shape == (256, 1024, 3584)
        assert specs["positions"].shape == (3, 256, 4096)
        assert set(pspecs) == set(specs)

    def test_decode_specs_state_structure(self):
        cfg = get_config("gemma3-1b")
        state, tok, ln, extras, _ = decode_specs(cfg, SHAPES["decode_32k"])
        assert tok.shape == (128, 1)
        # Local layers get ring buffers (window), global layers full length.
        leaves = jax.tree.leaves(state)
        shapes = {l.shape for l in leaves if hasattr(l, "shape") and len(l.shape) == 5}
        seq_lens = {s[3] for s in shapes}
        assert 1024 in seq_lens and 32768 in seq_lens


class TestUnrollFlag:
    def test_flag_toggles(self):
        assert scan_unroll() == 1
        with unrolled_cost_mode():
            assert scan_unroll() is True
        assert scan_unroll() == 1

    def test_unrolled_flops_scale_with_scan_length(self):
        # NOTE: fresh closures per mode — jit caches by function identity,
        # which is why the dry-run builds new step closures per lower.
        def make():
            def f(x):
                def body(c, _):
                    return c @ c * 0.5, None

                out, _ = jax.lax.scan(body, x, None, length=8, unroll=scan_unroll())
                return out

            return f

        x = jnp.eye(64)
        rolled = R.cost_analysis_dict(jax.jit(make()).lower(x).compile())["flops"]
        with unrolled_cost_mode():
            unrolled = R.cost_analysis_dict(jax.jit(make()).lower(x).compile())["flops"]
        assert unrolled > 4 * rolled  # 8 bodies vs 1 visited
