"""Unit + property tests for model internals: WKV, RG-LRU, MoE, RoPE, attention decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.registry import get_config
from repro.model import moe as moe_mod
from repro.model.attention import KVCache, apply_attention, init_attention
from repro.model.layers import apply_rope
from repro.model.recurrent import (
    RWKV_HEAD_DIM,
    _wkv_chunked,
    wkv_sequential_ref,
)
from repro.model.sharding import init_mk

jax.config.update("jax_platform_name", "cpu")


class TestWKV:
    @given(
        t=st.sampled_from([16, 32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=15, deadline=None)
    def test_chunked_matches_sequential(self, t, seed):
        b, h, dh = 2, 2, 8
        rng = np.random.default_rng(seed)
        r = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
        w = jnp.asarray(rng.uniform(0.05, 0.99, (b, h, t, dh)).astype(np.float32))
        u = jnp.asarray(rng.standard_normal((h, dh)).astype(np.float32))
        h0 = jnp.asarray(rng.standard_normal((b, h, dh, dh)).astype(np.float32))

        out_c, s_c = _wkv_chunked(r, k, v, w, u, h0, chunk=16)
        out_s, s_s = wkv_sequential_ref(r, k, v, w, u, h0)
        np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s),
                                   rtol=2e-3, atol=2e-3)

    def test_state_carry_composes(self):
        # Running [0:T] must equal running [0:T/2] then [T/2:T] with carry.
        b, h, t, dh = 1, 1, 32, 4
        rng = np.random.default_rng(0)
        mk = lambda: jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
        r, k, v = mk(), mk(), mk()
        w = jnp.asarray(rng.uniform(0.2, 0.95, (b, h, t, dh)).astype(np.float32))
        u = jnp.zeros((h, dh), jnp.float32)
        h0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        full, s_full = wkv_sequential_ref(r, k, v, w, u, h0)
        half = t // 2
        o1, s1 = wkv_sequential_ref(r[:, :, :half], k[:, :, :half], v[:, :, :half],
                                    w[:, :, :half], u, h0)
        o2, s2 = wkv_sequential_ref(r[:, :, half:], k[:, :, half:], v[:, :, half:],
                                    w[:, :, half:], u, s1)
        np.testing.assert_allclose(np.asarray(full[:, :, half:]), np.asarray(o2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)


class TestMoE:
    def _setup(self, e=4, k=2, d=16, f=32, n=24, seed=0):
        cfg = dataclasses.replace(
            get_config("dbrx-132b").reduced(),
            d_model=d, d_ff=f, num_experts=e, num_experts_per_tok=k,
        )
        mk = init_mk(jax.random.key(seed), jnp.float32)
        params = moe_mod.init_moe(mk, cfg, "moe")
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((2, n // 2, d)).astype(np.float32))
        return cfg, params, x

    def test_output_shape_finite(self):
        cfg, params, x = self._setup()
        out = moe_mod.apply_moe(params, x, cfg)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all())

    def test_generous_capacity_equals_dense_topk(self):
        # With capacity >> tokens nothing is dropped: MoE == explicit top-k mix.
        cfg, params, x = self._setup()
        out = moe_mod.apply_moe(params, x, cfg, capacity_factor=8.0)

        xf = x.reshape(-1, cfg.d_model)
        logits = xf @ params["router"]
        wts, experts = moe_mod._topk_routing(logits, cfg.num_experts_per_tok)
        dense = np.zeros_like(np.asarray(xf))
        for t in range(xf.shape[0]):
            for j in range(cfg.num_experts_per_tok):
                e = int(experts[t, j])
                h = jax.nn.silu(xf[t] @ params["w_gate"][e]) * (xf[t] @ params["w_up"][e])
                dense[t] += float(wts[t, j]) * np.asarray(h @ params["w_down"][e])
        np.testing.assert_allclose(
            np.asarray(out.reshape(-1, cfg.d_model)), dense, rtol=2e-3, atol=2e-3
        )

    def test_capacity_drop_is_graceful(self):
        # Tiny capacity: output stays finite; dropped tokens give zeros
        # (the residual stream carries them in the full block).
        cfg, params, x = self._setup()
        out = moe_mod.apply_moe(params, x, cfg, capacity_factor=0.05)
        assert bool(jnp.isfinite(out).all())

    def test_routing_weights_normalized(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.standard_normal((10, 8)).astype(np.float32))
        w, e = moe_mod._topk_routing(logits, 3)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(10), rtol=1e-5)
        assert int(e.max()) < 8


class TestRoPE:
    def test_rotation_preserves_norm(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 4, 8, 64)).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        out = apply_rope(x, pos, 10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(out), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1),
            rtol=1e-4,
        )

    def test_relative_property(self):
        # <rope(q,m), rope(k,n)> depends only on m-n.
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)).astype(np.float32))

        def dot_at(m, n):
            qm = apply_rope(q, jnp.asarray([[m]]), 1e4)
            kn = apply_rope(k, jnp.asarray([[n]]), 1e4)
            return float(jnp.sum(qm * kn))

        assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-3)

    def test_mrope_text_degenerates_to_rope(self):
        # Equal t/h/w positions == plain 1D RoPE.
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((1, 2, 6, 32)).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(6)[None], (1, 6))
        plain = apply_rope(x, pos, 1e4)
        mpos = jnp.broadcast_to(pos[None], (3, 1, 6))
        mro = apply_rope(x, mpos, 1e4, mrope_sections=(8, 4, 4))
        np.testing.assert_allclose(np.asarray(plain), np.asarray(mro), rtol=1e-5)

    def test_mrope_sections_rotate_independently(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((1, 1, 4, 32)).astype(np.float32))
        base = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
        mpos = jnp.stack([base, base, base + 7])  # change only the w stream
        out1 = apply_rope(x, jnp.stack([base, base, base]), 1e4, mrope_sections=(8, 4, 4))
        out2 = apply_rope(x, mpos, 1e4, mrope_sections=(8, 4, 4))
        a1, a2 = np.asarray(out1), np.asarray(out2)
        # t/h sections (first 12 of each half) unchanged; w section differs.
        np.testing.assert_allclose(a1[..., :12], a2[..., :12], rtol=1e-5)
        assert not np.allclose(a1[..., 12:16], a2[..., 12:16])


class TestRingCacheDecode:
    def test_local_ring_buffer_matches_full_cache(self):
        """Windowed decode with a ring cache == decode with a full cache."""
        cfg = dataclasses.replace(
            get_config("gemma3-1b").reduced(), attn_window=8
        )
        mk = init_mk(jax.random.key(0), jnp.float32)
        params = init_attention(mk, cfg, "attn")
        rng = np.random.default_rng(0)
        steps = 20
        xs = [jnp.asarray(rng.standard_normal((1, 1, cfg.d_model)).astype(np.float32))
              for _ in range(steps)]

        def run(cache_len):
            kv = KVCache(
                k=jnp.zeros((1, cfg.num_kv_heads, cache_len, cfg.head_dim)),
                v=jnp.zeros((1, cfg.num_kv_heads, cache_len, cfg.head_dim)),
                length=jnp.int32(0),
            )
            outs = []
            for i, x in enumerate(xs):
                pos = jnp.asarray([[i]], jnp.int32)
                out, kv = apply_attention(
                    params, x, cfg, kind="local", positions=pos, kv_cache=kv
                )
                outs.append(np.asarray(out))
            return np.concatenate(outs, axis=1)

        full = run(64)          # plenty of room: plain cache
        ring = run(8)           # window-sized ring buffer
        np.testing.assert_allclose(ring, full, rtol=1e-4, atol=1e-4)
