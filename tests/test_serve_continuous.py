"""Continuous-batching serve tests: scheduler parity vs solo lockstep
runs, ragged-prompt prefill masking, dead-slot state freezing, in-window
sampling determinism, EOS slot recycling / admission ordering, and the
ring-slack trace-time contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.model import model as M
from repro.model.attention import KVCache
from repro.serve.engine import Request, ServeEngine, make_cache_prefill_step

jax.config.update("jax_platform_name", "cpu")

ARCHS = ["rwkv6-1.6b", "gemma3-1b", "recurrentgemma-2b"]


def _setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    return cfg, params, np.random.default_rng(seed)


def _ragged_requests(rng, cfg, spec):
    return [
        Request(
            tokens=rng.integers(0, cfg.vocab_size, (pl,)).astype(np.int32),
            max_new_tokens=nn,
        )
        for pl, nn in spec
    ]


def _solo_greedy(cfg, params, req, max_len=96, decode_window=4):
    """The lockstep oracle: this request alone, batch of one."""
    eng = ServeEngine(cfg, params, max_len=max_len,
                      decode_window=decode_window)
    full = eng.generate(jnp.asarray(req.tokens)[None, :], req.max_new_tokens)
    return np.asarray(full[0, np.asarray(req.tokens).size:])


SPEC = [(5, 9), (12, 3), (7, 14), (3, 6), (9, 11)]


class TestContinuousParity:
    """Acceptance: ragged prompts + ragged budgets, every request's greedy
    output bit-identical to running it alone."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_greedy_bit_identical_to_solo(self, arch):
        cfg, params, rng = _setup(arch)
        reqs = _ragged_requests(rng, cfg, SPEC)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        outs = eng.serve(reqs, slots=2)
        assert eng.last_serve_stats["admissions"] >= 2  # slots were recycled
        for i, req in enumerate(reqs):
            want = _solo_greedy(cfg, params, req)
            np.testing.assert_array_equal(outs[i], want)

    def test_parity_across_slot_counts_and_windows(self):
        cfg, params, rng = _setup("rwkv6-1.6b", seed=3)
        reqs = _ragged_requests(rng, cfg, SPEC)
        want = None
        for slots, k in ((1, 1), (2, 4), (3, 8), (5, 2)):
            eng = ServeEngine(cfg, params, max_len=96, decode_window=k)
            outs = eng.serve(reqs, slots=slots)
            if want is None:
                want = outs
            else:
                for a, b in zip(want, outs):
                    np.testing.assert_array_equal(a, b)


class TestRaggedPrefill:
    """Bugfix: pad tokens of a batched ragged prompt must contribute
    nothing to KV caches or recurrent states."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_masked_prefill_matches_solo(self, arch):
        cfg, params, rng = _setup(arch, seed=1)
        plens = [4, 9, 6]
        p_max = max(plens)
        prompts = np.zeros((len(plens), p_max), np.int32)
        for b, pl in enumerate(plens):
            prompts[b, :pl] = rng.integers(0, cfg.vocab_size, pl)
        prefill = make_cache_prefill_step(cfg, last_only=True, max_len=64)
        state = M.init_decode_state(cfg, batch=len(plens), max_len=64,
                                    insert_window=p_max)
        lg, state = prefill(params, state, jnp.asarray(prompts),
                            jnp.asarray(plens, jnp.int32))
        for b, pl in enumerate(plens):
            st = M.init_decode_state(cfg, batch=1, max_len=64,
                                     insert_window=p_max)
            lgs, _ = make_cache_prefill_step(cfg, last_only=True, max_len=64)(
                params, st, jnp.asarray(prompts[b : b + 1, :pl]))
            np.testing.assert_array_equal(np.asarray(lg[b, 0]),
                                          np.asarray(lgs[0, 0]))

    def test_unmasked_ragged_prefill_was_polluted(self):
        # The bug this PR fixes: without the mask, pad tokens enter the
        # state and shift the short request's logits.
        cfg, params, rng = _setup("rwkv6-1.6b", seed=2)
        pl, p_max = 4, 12
        prompt = rng.integers(0, cfg.vocab_size, pl).astype(np.int32)
        padded = np.zeros((1, p_max), np.int32)
        padded[0, :pl] = prompt
        prefill = make_cache_prefill_step(cfg, last_only=True, max_len=64)
        s1 = M.init_decode_state(cfg, batch=1, max_len=64, insert_window=p_max)
        lg_mask, _ = prefill(params, s1, jnp.asarray(padded),
                             jnp.asarray([pl], jnp.int32))
        s2 = M.init_decode_state(cfg, batch=1, max_len=64, insert_window=p_max)
        lg_pad, _ = prefill(params, s2, jnp.asarray(padded))  # no mask
        s3 = M.init_decode_state(cfg, batch=1, max_len=64, insert_window=p_max)
        lg_solo, _ = prefill(params, s3, jnp.asarray(prompt[None]))
        np.testing.assert_array_equal(np.asarray(lg_mask[0, 0]),
                                      np.asarray(lg_solo[0, 0]))
        # The unmasked padded run reads its logits at the pad position —
        # a different distribution entirely.
        assert not np.array_equal(np.asarray(lg_pad[0, 0]),
                                  np.asarray(lg_solo[0, 0]))

    def test_generate_with_prompt_lengths_matches_solo(self):
        cfg, params, rng = _setup("gemma3-1b", seed=5)
        plens = np.asarray([5, 8])
        p_max, n_new = 8, 6
        prompts = np.zeros((2, p_max), np.int32)
        for b in range(2):
            prompts[b, : plens[b]] = rng.integers(0, cfg.vocab_size, plens[b])
        eng = ServeEngine(cfg, params, max_len=64, decode_window=4)
        out = eng.generate(jnp.asarray(prompts), n_new,
                           prompt_lengths=jnp.asarray(plens, jnp.int32))
        for b in range(2):
            solo = ServeEngine(cfg, params, max_len=64, decode_window=4)
            want = solo.generate(
                jnp.asarray(prompts[b : b + 1, : plens[b]]), n_new)
            np.testing.assert_array_equal(
                np.asarray(out[b, p_max:]),
                np.asarray(want[0, plens[b]:]))


class TestDeadSlotFreeze:
    """The window scan must leave a finished slot's state bit-identical
    (jnp.where-frozen), not merely approximately unchanged."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_masked_slot_state_untouched(self, arch):
        cfg, params, rng = _setup(arch, seed=4)
        b = 3
        state = M.init_decode_state(cfg, batch=b, max_len=64)
        # Fill with a couple of live steps so the frozen state is nonzero.
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)
        _, state = M.decode_step(params, cfg, state, toks, jnp.int32(0))
        _, state = M.decode_step(params, cfg, state, toks, jnp.int32(1))
        before = jax.tree.leaves(state)
        mask = jnp.asarray([True, False, True])[:, None]
        _, state2 = M.decode_step(params, cfg, state, toks,
                                  jnp.asarray([2, 2, 2], jnp.int32),
                                  token_mask=mask)
        after = jax.tree.leaves(state2)
        for x, y in zip(before, after):
            x, y = np.asarray(x), np.asarray(y)
            if x.ndim == 0:
                continue
            # Batch axis may be 0 (unstacked) or 1 (layer-stacked): the
            # dead slot's rows must be bit-identical on both layouts.
            got_hit = False
            for ax in (0, 1):
                if ax < x.ndim and x.shape[ax] == 3:
                    np.testing.assert_array_equal(
                        np.take(x, 1, axis=ax), np.take(y, 1, axis=ax))
                    got_hit = True
                    break
            assert got_hit, f"no batch axis found for shape {x.shape}"


class TestInWindowSampling:
    def test_deterministic_across_decode_windows(self):
        cfg, params, rng = _setup("rwkv6-1.6b", seed=6)
        reqs = _ragged_requests(rng, cfg, [(5, 7), (9, 4), (3, 10)])
        outs = {}
        for k in (1, 3, 8):
            eng = ServeEngine(cfg, params, max_len=64, decode_window=k)
            outs[k] = eng.serve(reqs, slots=2, temperature=0.8, top_k=16,
                                seed=7)
        for k in (3, 8):
            for a, b in zip(outs[1], outs[k]):
                np.testing.assert_array_equal(a, b)

    def test_seed_and_slot_invariance(self):
        cfg, params, rng = _setup("rwkv6-1.6b", seed=7)
        reqs = _ragged_requests(rng, cfg, [(4, 6), (6, 6), (8, 6)])
        eng = ServeEngine(cfg, params, max_len=64, decode_window=4)
        a = eng.serve(reqs, slots=2, temperature=1.0, seed=11)
        b = eng.serve(reqs, slots=3, temperature=1.0, seed=11)
        c = eng.serve(reqs, slots=2, temperature=1.0, seed=12)
        for u, v in zip(a, b):
            np.testing.assert_array_equal(u, v)  # slot-count invariant
        assert any(not np.array_equal(u, v) for u, v in zip(a, c)), (
            "different seeds produced identical streams")

    def test_top_k_restricts_support(self):
        # With top_k=1, temperature sampling degenerates to greedy.
        cfg, params, rng = _setup("rwkv6-1.6b", seed=8)
        reqs = _ragged_requests(rng, cfg, [(5, 8), (7, 5)])
        eng = ServeEngine(cfg, params, max_len=64, decode_window=4)
        greedy = eng.serve(reqs, slots=2, temperature=0.0)
        topk1 = eng.serve(reqs, slots=2, temperature=1.3, top_k=1, seed=5)
        for u, v in zip(greedy, topk1):
            np.testing.assert_array_equal(u, v)


class TestEosAndAdmission:
    def test_eos_frees_slot_and_truncates(self):
        cfg, params, rng = _setup("rwkv6-1.6b", seed=9)
        reqs = _ragged_requests(rng, cfg, [(5, 12), (8, 12), (4, 12)])
        eng = ServeEngine(cfg, params, max_len=64, decode_window=4)
        base = eng.serve(reqs, slots=2)
        # Pick an EOS id that actually occurs mid-stream in request 0.
        eos = int(base[0][len(base[0]) // 2])
        outs = eng.serve(reqs, slots=2, eos_id=eos)
        for b0, be in zip(base, outs):
            b0 = list(b0)
            if eos in b0:
                np.testing.assert_array_equal(be, b0[: b0.index(eos) + 1])
            else:
                np.testing.assert_array_equal(be, b0)
        assert any(eos in list(b0) for b0 in base)

    def test_admission_ordering_fifo(self):
        # More requests than slots: slot recycling must admit in arrival
        # order, and every request must complete with its own budget.
        cfg, params, rng = _setup("rwkv6-1.6b", seed=10)
        spec = [(4, 3), (5, 9), (6, 2), (3, 7), (7, 4), (5, 5)]
        reqs = _ragged_requests(rng, cfg, spec)
        eng = ServeEngine(cfg, params, max_len=64, decode_window=2)
        outs = eng.serve(reqs, slots=2)
        assert [len(o) for o in outs] == [nn for _, nn in spec]
        assert eng.last_serve_stats["admissions"] >= 3
        for i, req in enumerate(reqs):
            want = _solo_greedy(cfg, params, req, max_len=64, decode_window=2)
            np.testing.assert_array_equal(outs[i], want)

    def test_more_slots_than_requests(self):
        cfg, params, rng = _setup("rwkv6-1.6b", seed=12)
        reqs = _ragged_requests(rng, cfg, [(5, 4), (7, 6)])
        eng = ServeEngine(cfg, params, max_len=64, decode_window=4)
        outs = eng.serve(reqs, slots=4)  # clipped to len(requests)
        for i, req in enumerate(reqs):
            want = _solo_greedy(cfg, params, req, max_len=64, decode_window=4)
            np.testing.assert_array_equal(outs[i], want)

    def test_budget_validation(self):
        cfg, params, _ = _setup("rwkv6-1.6b")
        eng = ServeEngine(cfg, params, max_len=16)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.serve([Request(tokens=np.zeros(4, np.int32),
                               max_new_tokens=0)])
        with pytest.raises(ValueError, match="non-empty"):
            eng.serve([Request(tokens=np.zeros(0, np.int32),
                               max_new_tokens=4)])

    def test_oversized_request_is_shed_not_fatal(self):
        """A request that can't fit max_len is load to refuse (typed shed
        outcome), not a ValueError that aborts every other request."""
        cfg, params, _ = _setup("rwkv6-1.6b")
        eng = ServeEngine(cfg, params, max_len=16, decode_window=2)
        good = Request(tokens=np.arange(4, dtype=np.int32),
                       max_new_tokens=4)
        bad = Request(tokens=np.zeros(10, np.int32), max_new_tokens=10)
        outs = eng.serve([good, bad, good], slots=2)
        assert outs[1].outcome == "shed" and outs[1].size == 0
        assert eng.last_serve_stats["shed"] == 1
        solo = eng.serve([good], slots=1)
        for i in (0, 2):
            assert outs[i].outcome in ("ok", "eos")
            np.testing.assert_array_equal(outs[i].tokens, solo[0].tokens)


class TestRingSlackContract:
    """Bugfix: a decode window wider than the local-attention ring slack
    used to silently corrupt output; it must now fail at trace time."""

    def test_slack_deficient_window_raises(self):
        cfg, params, _ = _setup("gemma3-1b")
        # insert_window=1 ring (attn_window slots), max_len well above it:
        # an 8-token window would wrap the ring mid-window.
        state = M.init_decode_state(cfg, batch=1, max_len=256)
        tokens = jnp.zeros((1, 8), jnp.int32)
        with pytest.raises(ValueError, match="insert_window"):
            M.decode_step(params, cfg, state, tokens, jnp.int32(0),
                          max_len=256)

    def test_capped_ring_is_allowed_with_max_len(self):
        # A ring capped at max_len never wraps — max_len= vouches for it.
        cfg, params, _ = _setup("gemma3-1b")
        state = M.init_decode_state(cfg, batch=1, max_len=48, insert_window=8)
        tokens = jnp.zeros((1, 8), jnp.int32)
        logits, _ = M.decode_step(params, cfg, state, tokens, jnp.int32(0),
                                  max_len=48)
        assert bool(jnp.isfinite(logits).all())

    def test_uncapped_slackful_ring_needs_no_max_len(self):
        cfg, params, _ = _setup("gemma3-1b")
        state = M.init_decode_state(cfg, batch=1, max_len=256, insert_window=8)
        tokens = jnp.zeros((1, 8), jnp.int32)
        logits, _ = M.decode_step(params, cfg, state, tokens, jnp.int32(0))
        assert bool(jnp.isfinite(logits).all())


class TestKVCacheLengths:
    def test_per_request_lengths_in_state(self):
        cfg, _, _ = _setup("gemma3-1b")
        state = M.init_decode_state(cfg, batch=3, max_len=32)
        caches = [s for s in jax.tree.leaves(
            state, is_leaf=lambda x: isinstance(x, KVCache))
            if isinstance(s, KVCache)]
        assert caches
        for c in caches:
            assert c.length.shape[-1] == 3  # per-request, maybe (L, B)


class TestServeBatchStepsModel:
    """cost_model.serve_batch_steps: the scheduler's slot-step accounting."""

    def test_continuous_never_undercounts_budget_one(self):
        from repro.core.cost_model import serve_batch_steps

        # Budget-1 requests finish at admission; the simulator must keep
        # admitting instead of bailing with work still queued.
        useful, lock, cont = serve_batch_steps([1, 50], 1, 4)
        assert useful == 51 and cont >= 50
        useful, lock, cont = serve_batch_steps([1, 1, 5], 2, 1)
        assert useful == 7 and cont >= 4

    def test_ragged_workload_favors_continuous(self):
        from repro.core.cost_model import serve_batch_steps

        useful, lock, cont = serve_batch_steps(
            [56, 8, 48, 12, 60, 10, 40, 16], 2, 4)
        assert useful == 250
        assert cont < lock          # the acceptance regime
        assert useful <= cont       # can't beat perfect utilization

    def test_uniform_workload_is_a_wash(self):
        from repro.core.cost_model import serve_batch_steps

        useful, lock, cont = serve_batch_steps([16, 16, 16, 16], 2, 4)
        assert lock == cont  # no raggedness: the barrier costs nothing
