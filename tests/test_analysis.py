"""Tests for ``repro.analysis``: the static-audit subsystem.

Three layers:

* **clean tree** — every pass, on every default arch family, produces
  findings and none of them are errors (the CLI-green property, asserted
  in-process so a failure points at the pass, not at an exit code);
* **mutations** — seven deliberate regressions (dropped donation, caller
  -side f32 upcast, slack-less ring, oversized VMEM scratch, unbucketed
  admission shapes, a page-pool leak, snapshot-meta field drift) each
  caught by exactly the pass that owns the invariant, with the right
  severity and a location that points at the contract;
* **plumbing** — the Finding table/severity helpers and the per-scope
  chunk-adjustment warning fix (PR 7 satellite: ``resolve_chunk``'s
  warn-once set used to be a single module global shared across configs).
"""

from __future__ import annotations

import os
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import findings as F
from repro.analysis.registry import DEFAULT_ARCHS, PASS_MODULES, get_pass
from repro.configs.registry import get_config

REPO_ROOT = Path(__file__).resolve().parents[1]


# --------------------------------------------------------------------------
# Clean tree: every pass x every arch family audits green
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", DEFAULT_ARCHS)
@pytest.mark.parametrize("pass_name", sorted(PASS_MODULES))
def test_clean_tree_pass_is_green(pass_name, arch):
    cfg = get_config(arch)
    findings = get_pass(pass_name).run(cfg)
    assert findings, f"{pass_name} was silent for {arch} (must report evidence)"
    assert all(f.pass_name == pass_name for f in findings)
    errs = F.errors(findings)
    assert not errs, "\n" + F.format_table(errs, title=f"{arch}/{pass_name}")


def test_cli_green_exit_and_table():
    """The module CLI (what tier-1 lane 4 runs) exits 0 on a clean tree
    and prints a per-arch findings table.  Cheap passes only — the full
    sweep belongs to the tier-1 lane, not the unit suite."""
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--arch", "rwkv6-1.6b",
         "--passes", "resources,ringslack", "--strict"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "rwkv6-1.6b" in r.stdout
    assert "info" in r.stdout


# --------------------------------------------------------------------------
# Mutation 1: drop donate_argnums from the decode-window jit
# --------------------------------------------------------------------------

def test_mutation_dropped_donation_is_caught(monkeypatch):
    from repro.analysis import donation
    from repro.serve.engine import ServeEngine

    orig = ServeEngine._window_step

    def no_donate(self, k, last):
        # Same traced function, donation dropped: the silent perf bug.
        return jax.jit(orig(self, k, last).__wrapped__)

    monkeypatch.setattr(ServeEngine, "_window_step", no_donate)
    findings = donation.run(get_config("rwkv6-1.6b"))
    errs = F.errors(findings)
    assert errs, "donation pass missed the un-donated window jit"
    assert any(
        e.location.endswith("_window_step")
        and "input_output_alias" in e.message
        for e in errs
    ), F.format_table(errs)
    # Only the mutated entry fails; the untouched jits still audit green.
    assert all(e.location.endswith("_window_step") for e in errs)


# --------------------------------------------------------------------------
# Mutation 2: caller-side f32 upcast on the WKV dispatch path
# --------------------------------------------------------------------------

def test_mutation_f32_upcast_is_caught(monkeypatch):
    from repro.analysis import dtype_flow
    from repro.kernels.wkv import ops as wkv_ops

    orig = wkv_ops.wkv_fused

    def upcast_dispatch(r, k, v, w, u, h0, **kw):
        # The classic regression: "for safety" float32 on the I/O path.
        f32 = jnp.float32
        out, s = orig(r.astype(f32), k.astype(f32), v.astype(f32),
                      w.astype(f32), u.astype(f32), h0, **kw)
        return out.astype(r.dtype), s

    monkeypatch.setattr(wkv_ops, "wkv_fused", upcast_dispatch)
    findings = dtype_flow.run(get_config("rwkv6-1.6b"))
    errs = F.errors(findings)
    assert errs, "dtype_flow missed the caller-side upcast"
    assert any(
        "upcast" in e.message and e.location.endswith("wkv_fused")
        for e in errs
    ), F.format_table(errs)


# --------------------------------------------------------------------------
# Mutation 3: decode state built without ring slack
# --------------------------------------------------------------------------

def test_mutation_slackless_ring_is_caught(monkeypatch):
    from repro.analysis import ringslack
    from repro.model import model as M

    orig = M.abstract_decode_state

    def ignores_insert_window(cfg, **kw):
        kw["insert_window"] = 1     # state sized as if windows were 1 token
        return orig(cfg, **kw)

    monkeypatch.setattr(M, "abstract_decode_state", ignores_insert_window)
    findings = ringslack.run(get_config("gemma3-1b"))
    errs = F.errors(findings)
    assert errs, "ringslack missed the slack-less decode state"
    assert any(
        "ring contract" in e.message
        and e.location.endswith("_check_ring_slack")
        for e in errs
    ), F.format_table(errs)


# --------------------------------------------------------------------------
# Mutation 4: a kernel declares VMEM scratch past the per-core budget
# --------------------------------------------------------------------------

def test_mutation_oversized_vmem_scratch_is_caught(monkeypatch):
    from repro.analysis import resources
    from repro.kernels import common

    resources._load_specs()     # ensure the real registrations exist first
    huge = common.KernelResources(
        kernel="mutant.fwd",
        location="src/repro/kernels/mutant.py:mutant_pallas_call",
        grid=(1, 1, 1),
        blocks=(("x", (1, 128), 4),),
        scratch=(("acc", (4096, 4096), 4),),     # 64 MiB of scratch
    )
    monkeypatch.setitem(
        common.KERNEL_RESOURCE_SPECS, "mutant.fwd", lambda cfg: huge
    )
    findings = resources.run(get_config("rwkv6-1.6b"))
    errs = F.errors(findings)
    assert errs, "resources pass missed the VMEM blowout"
    assert any(
        "exceeds" in e.message and "mutant.py" in e.location
        and e.metrics.get("vmem_bytes", 0) > resources.VMEM_BUDGET_BYTES
        for e in errs
    ), F.format_table(errs)
    # Real kernels still fit: the mutant is the only error.
    assert all("mutant.py" in e.location for e in errs)


# --------------------------------------------------------------------------
# Mutation 5: admission stops bucketing prompt shapes (retrace leak)
# --------------------------------------------------------------------------

def test_mutation_unbucketed_admission_is_caught(monkeypatch):
    from repro.analysis import retrace
    from repro.serve import engine as eng_mod

    # Identity "bucketing": every distinct prompt length becomes its own
    # jit-cache key.  slots=1 serializes admissions so each request's
    # exact length reaches the cache key.
    monkeypatch.setattr(
        eng_mod, "_bucket32", lambda length: max(int(length), 1)
    )
    findings = retrace.run(get_config("rwkv6-1.6b"), slots=1)
    errs = F.errors(findings)
    assert errs, "retrace sentinel missed the unbucketed admission shapes"
    assert any(
        "bucketing" in e.message and e.metrics.get("admits", 0) > 2
        for e in errs
    ), F.format_table(errs)


# --------------------------------------------------------------------------
# Mutation 6: the engine stops releasing pages on slot recycle
# --------------------------------------------------------------------------

def test_mutation_leaked_page_is_caught(monkeypatch):
    from repro.analysis import paging
    from repro.serve.paging import PagedController

    # free_slot becomes a no-op: every recycled slot's pages stay owned
    # by a slot that no longer holds a request — the classic pool leak
    # that only shows up as admission stalls hours into a serve.
    monkeypatch.setattr(PagedController, "free_slot",
                        lambda self, slot: None)
    findings = paging.run(get_config("gemma3-1b"))
    errs = F.errors(findings)
    assert errs, "paging pass missed the leaked pages"
    assert any(
        "leaked" in e.message or "survived" in e.message for e in errs
    ), F.format_table(errs)
    assert all(e.location.endswith("PagedController") for e in errs)


# --------------------------------------------------------------------------
# Mutation 7: snapshot-meta field drift breaks the fleet handoff parser
# --------------------------------------------------------------------------

def test_mutation_fleet_meta_drift_is_caught(monkeypatch):
    from repro.analysis import fleet as fleet_pass
    from repro.serve.engine import ServeEngine

    orig = ServeEngine._serve_meta

    def swapped(self, b, k_w, insert_window, n, seed, ctl):
        # Request count and seed trade places: every individual field is
        # still present, so only a layout-aware audit catches it before
        # a handoff trusts meta[3] as the request count.
        m = orig(self, b, k_w, insert_window, n, seed, ctl).copy()
        m[3], m[4] = m[4], m[3]
        return m

    monkeypatch.setattr(ServeEngine, "_serve_meta", swapped)
    findings = fleet_pass.run(get_config("rwkv6-1.6b"))
    errs = F.errors(findings)
    assert errs, "fleet pass missed the meta field drift"
    assert any("field order" in e.message for e in errs), F.format_table(errs)
    assert all(e.location.endswith("FleetRouter") for e in errs)


# --------------------------------------------------------------------------
# Satellite: resolve_chunk warns once per scope, not once per process
# --------------------------------------------------------------------------

def test_resolve_chunk_warns_once_per_scope():
    from repro.kernels.wkv import ops as wkv_ops

    wkv_ops.reset_chunk_warnings(all_scopes=True)
    try:
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            assert wkv_ops.resolve_chunk(10, 4, scope="cfg-a") == 2
            wkv_ops.resolve_chunk(10, 4, scope="cfg-a")   # deduped
            wkv_ops.resolve_chunk(10, 4, scope="cfg-b")   # fresh scope
        assert len(rec) == 2, [str(w.message) for w in rec]

        # The context manager scopes call sites that can't thread a tag.
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            with wkv_ops.chunk_warning_scope("cfg-c"):
                wkv_ops.resolve_chunk(10, 4)
                wkv_ops.resolve_chunk(10, 4)              # deduped in scope
            wkv_ops.resolve_chunk(10, 4)                  # None scope: new
        assert len(rec) == 2, [str(w.message) for w in rec]

        # Per-scope reset forgets one config without silencing others.
        wkv_ops.reset_chunk_warnings("cfg-a")
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            wkv_ops.resolve_chunk(10, 4, scope="cfg-a")   # warns again
            wkv_ops.resolve_chunk(10, 4, scope="cfg-b")   # still deduped
        assert len(rec) == 1, [str(w.message) for w in rec]
    finally:
        wkv_ops.reset_chunk_warnings(all_scopes=True)


def test_wkv_fused_threads_warn_scope():
    from repro.kernels.wkv import ops as wkv_ops

    wkv_ops.reset_chunk_warnings(all_scopes=True)
    try:
        r = jnp.zeros((1, 1, 10, 4), jnp.float32)
        u = jnp.zeros((1, 4), jnp.float32)
        h0 = jnp.zeros((1, 1, 4, 4), jnp.float32)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            for scope in ("model-a", "model-a", "model-b"):
                wkv_ops.wkv_fused(r, r, r, r, u, h0, chunk=4,
                                  use_kernel=False, warn_scope=scope)
        assert len(rec) == 2, [str(w.message) for w in rec]
    finally:
        wkv_ops.reset_chunk_warnings(all_scopes=True)


# --------------------------------------------------------------------------
# Plumbing: findings helpers
# --------------------------------------------------------------------------

def test_findings_severity_and_table():
    fs = [
        F.info("p", "src/a.py:f", "fine", n=1),
        F.warn("p", "src/b.py:g", "iffy"),
        F.error("q", "src/c.py:h", "broken", bytes=7),
    ]
    assert F.worst(fs) == F.Severity.ERROR
    assert F.worst([]) == F.Severity.INFO
    assert [f.location for f in F.errors(fs)] == ["src/c.py:h"]
    assert str(F.Severity.ERROR) == "error"
    assert F.Severity.ERROR > F.Severity.WARN > F.Severity.INFO

    table = F.format_table(fs, title="T")
    lines = table.splitlines()
    assert lines[0] == "T"
    # Most severe first, metrics rendered inline.
    assert lines[1].lstrip().startswith("error")
    assert "bytes=7" in lines[1]
    assert "src/a.py:f" in table and "n=1" in table
    assert F.format_table([]) == "  (no findings)"
