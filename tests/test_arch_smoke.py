"""Per-architecture smoke tests: reduced config, forward + train step + decode.

Each assigned architecture instantiates a REDUCED config of the same family
(same pattern / attention type / MoE routing / recurrence) and runs on CPU:
  * one forward pass — asserts logits shape and finiteness,
  * one train step (CE loss grad) — asserts finite grads,
  * one decode step (where the family has one) — asserts cache consistency.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.model import model as M

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 64


def _inputs(cfg, batch=B, seq=S):
    rng = np.random.default_rng(0)
    kw = {}
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    if cfg.frontend == "vision":
        s_f = seq // 4
        kw["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((batch, s_f, cfg.d_model)).astype(np.float32)
        )
        kw["positions"] = jnp.broadcast_to(
            jnp.arange(seq, dtype=jnp.int32)[None, None], (3, batch, seq)
        )
    if cfg.is_enc_dec:
        kw["enc_tokens_embeds"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)).astype(np.float32)
        )
    return tokens, kw


@pytest.fixture(scope="module", params=list_archs())
def arch(request):
    cfg = get_config(request.param).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    return cfg, params


class TestForward:
    def test_forward_shape_and_finite(self, arch):
        cfg, params = arch
        tokens, kw = _inputs(cfg)
        logits = jax.jit(
            lambda p, t: M.forward(p, cfg, t, **kw)
        )(params, tokens)
        assert logits.shape == (B, S, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all()), f"{cfg.name}: non-finite logits"

    def test_train_step_grads_finite(self, arch):
        cfg, params = arch
        tokens, kw = _inputs(cfg)
        labels = jnp.roll(tokens, -1, axis=1)

        def loss_fn(p):
            logits = M.forward(p, cfg, tokens, **kw)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
            return nll.mean()

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert bool(jnp.isfinite(loss)), f"{cfg.name}: loss {loss}"
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{cfg.name}: nan grads"
        assert any(float(jnp.abs(g).max()) > 0 for g in flat)


class TestDecode:
    def test_decode_step(self, arch):
        cfg, params = arch
        if cfg.is_enc_dec:
            pytest.skip("enc-dec decode covered separately")
        state = M.init_decode_state(cfg, batch=B, max_len=128)
        tokens = jnp.ones((B, 1), jnp.int32)
        step = jax.jit(
            lambda p, s, t, l: M.decode_step(p, cfg, s, t, l)
        )
        logits, state = step(params, state, tokens, jnp.int32(0))
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())
        logits2, state = step(params, state, tokens, jnp.int32(1))
        assert bool(jnp.isfinite(logits2).all())

    def test_decode_matches_prefill_logits(self, arch):
        """Greedy consistency: step-by-step decode == teacher-forced forward."""
        cfg, params = arch
        if cfg.is_enc_dec or cfg.frontend == "vision":
            pytest.skip("needs extra inputs; covered by forward test")
        t = 8
        rng = np.random.default_rng(1)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, t)), jnp.int32)
        full = M.forward(params, cfg, tokens)

        state = M.init_decode_state(cfg, batch=1, max_len=64)
        outs = []
        for i in range(t):
            logits, state = M.decode_step(
                params, cfg, state, tokens[:, i : i + 1], jnp.int32(i)
            )
            outs.append(logits[:, 0])
        stepped = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(stepped, np.float32),
            np.asarray(full, np.float32),
            rtol=2e-2, atol=2e-2,
        )


class TestConfigs:
    def test_exact_assignment_numbers(self):
        expect = {
            "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
            "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
            "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
            "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
            "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
            "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
            "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
            "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
            "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
            "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        }
        for arch_name in list_archs():
            cfg = get_config(arch_name)
            got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                   cfg.d_ff, cfg.vocab_size)
            assert got == expect[cfg.name], cfg.name

    def test_moe_expert_counts(self):
        assert get_config("dbrx-132b").num_experts == 16
        assert get_config("dbrx-132b").num_experts_per_tok == 4
        assert get_config("qwen3-moe-235b-a22b").num_experts == 128
        assert get_config("qwen3-moe-235b-a22b").num_experts_per_tok == 8

    def test_param_counts_in_band(self):
        # Sanity-check total params against the advertised scale (±40%).
        bands = {
            "qwen2-vl-7b": (5e9, 11e9),
            "dbrx-132b": (90e9, 180e9),
            "qwen3-moe-235b-a22b": (160e9, 320e9),
            "minitron-8b": (6e9, 12e9),
            "nemotron-4-15b": (11e9, 22e9),
            "qwen2-0.5b": (0.3e9, 0.8e9),
            "rwkv6-1.6b": (1.0e9, 2.4e9),
            "gemma3-1b": (0.6e9, 1.6e9),
            "recurrentgemma-2b": (1.6e9, 3.8e9),
        }
        for name, (lo, hi) in bands.items():
            n = get_config(name).param_count()
            assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"

    def test_moe_active_params(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        active = cfg.active_param_count()
        assert 14e9 <= active <= 30e9, active / 1e9
