"""Optional-hypothesis guard for the property-based tests.

The container does not ship ``hypothesis``.  A module-level hard import
would make pytest fail *collection* for the whole file, taking every
plain unit test in it down too.  This shim degrades gracefully: when
hypothesis is available the real ``given``/``settings``/``st`` are
re-exported; when it is missing, ``@given`` turns the property test into
an individually-reported skip and the rest of the module keeps running.

Usage (replaces ``from hypothesis import given, settings, strategies as st``)::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy factory
        exists and returns an inert placeholder (never drawn from)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            _strategy.__name__ = name
            return _strategy

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            # (*args, **kwargs) keeps pytest from treating the hypothesis
            # parameters as fixture requests.
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
