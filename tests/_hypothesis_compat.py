"""Optional-hypothesis guard for the property-based tests.

The container does not ship ``hypothesis``.  A module-level hard import
would make pytest fail *collection* for the whole file, taking every
plain unit test in it down too.  When hypothesis is available the real
``given``/``settings``/``st`` are re-exported.  When it is missing, a
tiny deterministic fallback sampler stands in: ``@given`` draws a reduced
number of examples (:data:`FALLBACK_MAX_EXAMPLES`) from minimal strategy
implementations, seeded per-test, so the property tests still *execute*
everywhere instead of skipping.  No shrinking, no database, no coverage
guidance — just deterministic sampling of the declared space.

Usage (replaces ``from hypothesis import given, settings, strategies as st``)::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    import pytest

    HAVE_HYPOTHESIS = False

    # Example budget per property test.  Deliberately small: these run in
    # the tier-1 lane on every PR; real hypothesis (when installed) keeps
    # the test's own max_examples.
    FALLBACK_MAX_EXAMPLES = 6

    class _Strategy:
        """Minimal strategy: a deterministic ``example(rng)`` draw."""

        def example(self, rng: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def example(self, rng):
            return rng.randint(self.min_value, self.max_value)

    class _Floats(_Strategy):
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def example(self, rng):
            return rng.uniform(self.min_value, self.max_value)

        # NB: no NaN/inf/subnormal corners — this is a sampler, not a
        # property-based fuzzer.

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return self.elements[rng.randrange(len(self.elements))]

    class _OneOf(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng):
            return self.options[rng.randrange(len(self.options))].example(rng)

    class _Just(_Strategy):
        def __init__(self, value):
            self.value = value

        def example(self, rng):
            return self.value

    class _Unsupported(_Strategy):
        def __init__(self, name):
            self.name = name

    class _FallbackStrategies:
        """Stand-in for ``hypothesis.strategies`` covering the factories
        this repo's tests use; anything else yields an ``_Unsupported``
        marker and the test skips with a pointer here."""

        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

        @staticmethod
        def one_of(*options):
            return _OneOf(options)

        @staticmethod
        def just(value):
            return _Just(value)

        @staticmethod
        def none():
            return _Just(None)

        @staticmethod
        def booleans():
            return _SampledFrom([False, True])

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return _Unsupported(name)

            _strategy.__name__ = name
            return _strategy

    st = _FallbackStrategies()

    def given(*gargs, **gkwargs):
        def deco(fn):
            cap = min(
                getattr(fn, "_fallback_max_examples", FALLBACK_MAX_EXAMPLES),
                FALLBACK_MAX_EXAMPLES,
            )

            # (*args, **kwargs) keeps pytest from treating the hypothesis
            # parameters as fixture requests (do NOT functools.wraps: the
            # copied signature would reintroduce them).
            def runner(*args, **kwargs):
                if gargs:
                    pytest.skip(
                        "positional @given not supported by the "
                        "hypothesis-less fallback sampler"
                    )
                unsupported = [
                    s.name for s in gkwargs.values()
                    if isinstance(s, _Unsupported)
                ]
                if unsupported:
                    pytest.skip(
                        "strategies not implemented by the fallback "
                        f"sampler: {unsupported} (see _hypothesis_compat)"
                    )
                # Seeded by the test's identity: deterministic across runs
                # and processes (random.seed of a str hashes via sha512,
                # independent of PYTHONHASHSEED).
                rng = random.Random(f"{fn.__module__}::{fn.__qualname__}")
                for _ in range(cap):
                    drawn = {
                        name: strat.example(rng)
                        for name, strat in sorted(gkwargs.items())
                    }
                    fn(*args, **drawn, **kwargs)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco

    def settings(max_examples=None, **_kwargs):
        def deco(fn):
            if max_examples is not None:
                fn._fallback_max_examples = max_examples
            return fn

        return deco
