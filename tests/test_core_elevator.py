"""Unit + property tests for the elevator node (fromThreadOrConst)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    TOKEN_BUFFER_SIZE,
    cascaded_from_thread_or_const,
    from_thread_or_const,
    from_thread_or_const_nd,
    plan_cascade,
    tag_value,
)

jax.config.update("jax_platform_name", "cpu")


def ref_elevator(x, delta, const, window=None):
    """Direct transcription of paper Fig. 4 pseudo-code (per-thread loop)."""
    n = x.shape[0]
    out = np.full_like(np.asarray(x), const)
    for tid in range(n):
        src = tid - delta
        if 0 <= src < n and (window is None or tid // window == src // window):
            out[tid] = x[src]
    return out


class TestFromThreadOrConst:
    def test_basic_shift(self):
        x = jnp.arange(8.0)
        out = from_thread_or_const(x, delta=1, const=-1.0)
        np.testing.assert_array_equal(out, [-1, 0, 1, 2, 3, 4, 5, 6])

    def test_negative_delta(self):
        # Paper Fig. 1c: conv reads tid+1 -> delta = -1.
        x = jnp.arange(5.0)
        out = from_thread_or_const(x, delta=-1, const=0.0)
        np.testing.assert_array_equal(out, [1, 2, 3, 4, 0])

    def test_zero_delta_identity(self):
        x = jnp.arange(4.0)
        np.testing.assert_array_equal(from_thread_or_const(x, 0, 9.0), x)

    def test_window_boundary(self):
        # Window 4: thread 4 must NOT receive from thread 3.
        x = jnp.arange(8.0)
        out = from_thread_or_const(x, delta=1, const=-1.0, window=4)
        np.testing.assert_array_equal(out, [-1, 0, 1, 2, -1, 4, 5, 6])

    def test_multidim_values(self):
        x = jnp.arange(12.0).reshape(6, 2)
        out = from_thread_or_const(x, delta=2, const=0.0)
        np.testing.assert_array_equal(out[:2], np.zeros((2, 2)))
        np.testing.assert_array_equal(out[2:], np.asarray(x[:4]))

    def test_axis_argument(self):
        x = jnp.arange(12.0).reshape(2, 6)
        out = from_thread_or_const(x, delta=1, const=0.0, axis=1)
        expected = np.stack([ref_elevator(np.asarray(x[i]), 1, 0.0) for i in range(2)])
        np.testing.assert_array_equal(out, expected)

    @given(
        n=st.integers(2, 64),
        delta=st.integers(-70, 70),
        window=st.one_of(st.none(), st.integers(1, 16)),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_matches_paper_pseudocode(self, n, delta, window, seed):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(n).astype(np.float32)
        out = from_thread_or_const(jnp.asarray(x), delta, 7.5, window=window)
        np.testing.assert_array_equal(np.asarray(out), ref_elevator(x, delta, 7.5, window))

    def test_2d_tid_space(self):
        # Paper Fig. 2b: fromThreadOrMem<{0,-1}> style 2D deltas.
        x = jnp.arange(12.0).reshape(3, 4)
        out = from_thread_or_const_nd(x, deltas=(1, 0), const=-1.0)
        np.testing.assert_array_equal(np.asarray(out[0]), [-1, -1, -1, -1])
        np.testing.assert_array_equal(out[1:], np.asarray(x[:2]))

    def test_2d_both_axes(self):
        x = jnp.arange(16.0).reshape(4, 4)
        out = from_thread_or_const_nd(x, deltas=(1, 1), const=0.0)
        ref = np.zeros((4, 4), np.float32)
        ref[1:, 1:] = np.asarray(x)[:3, :3]
        np.testing.assert_array_equal(out, ref)

    def test_tag_value_identity(self):
        x = jnp.arange(4.0)
        np.testing.assert_array_equal(tag_value(x, "sum"), x)
        np.testing.assert_array_equal(tag_value(x), x)

    def test_jit_compatible(self):
        f = jax.jit(lambda x: from_thread_or_const(x, 3, 0.0, window=8))
        x = jnp.arange(16.0)
        np.testing.assert_array_equal(f(x), ref_elevator(np.asarray(x), 3, 0.0, 8))


class TestCascade:
    def test_paper_example_delta18(self):
        # Paper Fig. 10a: delta 18, buffer 16 -> nodes [16, 2].
        plan = plan_cascade(18)
        assert plan.node_deltas == (16, 2)
        assert not plan.spilled

    def test_small_delta_single_node(self):
        assert plan_cascade(5).node_deltas == (5,)
        assert plan_cascade(16).node_deltas == (16,)

    def test_node_count_formula(self):
        # ceil(delta / token_buffer) nodes (paper §4.3).
        import math

        for delta in [1, 15, 16, 17, 31, 32, 33, 100]:
            plan = plan_cascade(delta)
            assert plan.num_nodes == math.ceil(delta / TOKEN_BUFFER_SIZE)

    def test_spill_when_exceeding_nodes(self):
        plan = plan_cascade(16 * 17, max_nodes=16)
        assert plan.spilled

    def test_negative_delta(self):
        plan = plan_cascade(-18)
        assert plan.node_deltas == (-16, -2)

    @given(
        n=st.integers(4, 128),
        delta=st.integers(1, 90),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_cascade_equals_single_shift(self, n, delta, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        direct = from_thread_or_const(x, delta, 3.0)
        chained, plan = cascaded_from_thread_or_const(x, delta, 3.0, token_buffer=8)
        np.testing.assert_array_equal(np.asarray(direct), np.asarray(chained))
