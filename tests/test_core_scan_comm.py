"""Tests for chunk_scan, device_comm (shard_map), pipeline, scratchpad."""

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import (
    DIAG_STATE,
    ELEMENTWISE,
    SharedBuffer,
    barrier,
    chunked_linear_scan,
    device_linear_scan_carry,
    device_shift,
    halo_exchange,
    linear_scan,
    pipeline_apply,
    ring_pass,
    seq_carry_scan,
)

jax.config.update("jax_platform_name", "cpu")


def ref_linear_scan(a, b, h0=0.0):
    h = np.zeros_like(b)
    prev = np.broadcast_to(np.asarray(h0, b.dtype), b.shape[1:]).copy()
    for t in range(b.shape[0]):
        prev = a[t] * prev + b[t]
        h[t] = prev
    return h


class TestLinearScan:
    def test_prefix_sum_is_special_case(self):
        # Paper Fig. 6: prefix sum == linear scan with a == 1.
        b = jnp.arange(1.0, 9.0)
        h = linear_scan(jnp.ones_like(b), b)
        np.testing.assert_allclose(h, np.cumsum(np.asarray(b)), rtol=1e-6)

    @given(
        t=st.sampled_from([4, 8, 16, 32]),
        chunk=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_chunked_matches_flat(self, t, chunk, seed):
        if t % chunk:
            return
        rng = np.random.default_rng(seed)
        a = rng.uniform(0.5, 1.0, (t, 3)).astype(np.float32)
        b = rng.standard_normal((t, 3)).astype(np.float32)
        flat = linear_scan(jnp.asarray(a), jnp.asarray(b))
        chunked = chunked_linear_scan(jnp.asarray(a), jnp.asarray(b), chunk=chunk)
        np.testing.assert_allclose(np.asarray(flat), np.asarray(chunked), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(flat), ref_linear_scan(a, b), rtol=2e-4, atol=2e-4)

    def test_h0_injection(self):
        a = jnp.full((4,), 0.5)
        b = jnp.ones((4,))
        h = chunked_linear_scan(a, b, chunk=2, h0=8.0)
        np.testing.assert_allclose(np.asarray(h), ref_linear_scan(np.asarray(a), np.asarray(b), 8.0), rtol=1e-6)

    def test_h0_fold_with_numpy_inputs(self):
        # Regression: the old ``hasattr(b, "at")`` guard silently dropped h0
        # when a/b arrived as numpy arrays.
        rng = np.random.default_rng(3)
        a = rng.uniform(0.5, 1.0, (6, 2)).astype(np.float32)
        b = rng.standard_normal((6, 2)).astype(np.float32)
        h = linear_scan(a, b, h0=2.5)
        np.testing.assert_allclose(
            np.asarray(h), ref_linear_scan(a, b, 2.5), rtol=2e-5, atol=2e-5
        )
        # And identically for jax inputs (both paths share the fold now).
        h_jax = linear_scan(jnp.asarray(a), jnp.asarray(b), h0=2.5)
        np.testing.assert_allclose(np.asarray(h_jax), np.asarray(h), rtol=1e-6)


class TestSegmentMonoid:
    """The shared (decay, state) composition law behind chunked_linear_scan,
    device_linear_scan_carry and the WKV segment summaries."""

    def test_elementwise_compose_is_fold(self):
        rng = np.random.default_rng(7)
        segs = [(jnp.asarray(rng.uniform(0.5, 1.0, 3).astype(np.float32)),
                 jnp.asarray(rng.standard_normal(3).astype(np.float32)))
                for _ in range(4)]
        h0 = jnp.asarray(rng.standard_normal(3).astype(np.float32))
        composed = segs[0]
        for s in segs[1:]:
            composed = ELEMENTWISE.compose(composed, s)
        h = h0
        for s in segs:
            h = ELEMENTWISE.apply(s, h)
        np.testing.assert_allclose(
            np.asarray(ELEMENTWISE.apply(composed, h0)), np.asarray(h),
            rtol=1e-6)

    def test_diag_state_compose_is_fold(self):
        # The WKV case: (..., Dh) decay acting on the rows of a (Dh, Dh)
        # matrix state.
        rng = np.random.default_rng(8)
        dh = 4
        segs = [(jnp.asarray(rng.uniform(0.5, 1.0, dh).astype(np.float32)),
                 jnp.asarray(rng.standard_normal((dh, dh)).astype(np.float32)))
                for _ in range(3)]
        h0 = jnp.asarray(rng.standard_normal((dh, dh)).astype(np.float32))
        composed = segs[0]
        for s in segs[1:]:
            composed = DIAG_STATE.compose(composed, s)
        h = np.asarray(h0)
        for a, b_ in segs:
            h = np.asarray(a)[:, None] * h + np.asarray(b_)
        np.testing.assert_allclose(
            np.asarray(DIAG_STATE.apply(composed, h0)), h, rtol=1e-5,
            atol=1e-5)

    def test_chunked_linear_scan_diag_state(self):
        # chunked_linear_scan runs the matrix-state recurrence under the
        # same monoid: h_t = a_t[:, None] * h_{t-1} + b_t.
        rng = np.random.default_rng(9)
        t, dh = 8, 4
        a = rng.uniform(0.5, 1.0, (t, dh)).astype(np.float32)
        b = rng.standard_normal((t, dh, dh)).astype(np.float32)
        h0 = rng.standard_normal((dh, dh)).astype(np.float32)
        got = chunked_linear_scan(
            jnp.asarray(a), jnp.asarray(b), chunk=4, h0=h0,
            monoid=DIAG_STATE)
        ref = np.zeros((t, dh, dh), np.float32)
        prev = h0.copy()
        for i in range(t):
            prev = a[i][:, None] * prev + b[i]
            ref[i] = prev
        np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-5,
                                   atol=2e-5)


def _mesh1d(n, name="x"):
    devs = jax.devices()
    if len(devs) < n:
        pytest.skip(f"needs {n} devices")
    return Mesh(np.array(devs[:n]), (name,))


@pytest.fixture(scope="module")
def mesh4():
    # Spawn extra host devices for this test module only via chex-free trick:
    # tests run under a separate pytest process; if only 1 device, skip.
    return _mesh1d(4)


class TestDeviceComm:
    """Device-space elevator tests run via shard_map on host devices.

    On the 1-device CPU container these exercise the n=1 path; the
    multi-device path is exercised by tests/test_multidevice.py which
    re-launches pytest with XLA_FLAGS=--xla_force_host_platform_device_count.
    """

    def test_device_shift_single(self):
        mesh = _mesh1d(1)
        f = shard_map(
            lambda x: device_shift(x, "x", delta=0, fill=0.0),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        x = jnp.arange(4.0)
        np.testing.assert_array_equal(f(x), x)

    def test_halo_noop(self):
        mesh = _mesh1d(1)
        f = shard_map(
            lambda x: halo_exchange(x, "x", left=0, right=0),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        x = jnp.arange(8.0)
        np.testing.assert_array_equal(f(x), x)


class TestDeviceCarryEdges:
    """Edge cases of the device-space carry sweeps.

    The single-device-axis cases run everywhere; the n=8 cases need the
    multi-device lane (scripts/tier1.sh lane 2, or any host with >= 8
    devices) — tests/test_multidevice.py covers them via subprocess too.
    """

    def test_single_device_axis_is_identity(self):
        # n=1: no predecessors — the entering carry is the monoid identity
        # (1, 0), forward and reverse.
        mesh = _mesh1d(1)
        for reverse in (False, True):
            f = shard_map(
                lambda a, b: device_linear_scan_carry(
                    a, b, "x", reverse=reverse),
                mesh=mesh, in_specs=(P("x"), P("x")), out_specs=(P("x"), P("x")),
            )
            ca, cb = f(jnp.full((1, 3), 0.5), jnp.ones((1, 3)))
            np.testing.assert_array_equal(np.asarray(ca), np.ones((1, 3)))
            np.testing.assert_array_equal(np.asarray(cb), np.zeros((1, 3)))

    def test_seq_carry_scan_single_device(self):
        # n=1: the chain degenerates to one chunk_fn call from carry_init,
        # in either direction.
        mesh = _mesh1d(1)
        x = jnp.arange(4.0)

        def chunk_fn(carry, v):
            return carry + v.sum(), v + carry

        for reverse in (False, True):
            def run(v, reverse=reverse):
                c, y = seq_carry_scan(
                    chunk_fn, jnp.asarray(10.0), v, "x", reverse=reverse)
                return c.reshape(1), y

            f = shard_map(run, mesh=mesh, in_specs=P("x"),
                          out_specs=(P("x"), P("x")))
            carry, y = f(x)
            np.testing.assert_allclose(np.asarray(carry), [16.0])
            np.testing.assert_allclose(np.asarray(y), np.arange(4.0) + 10.0)

    def test_carry_nonzero_h0_multidevice(self):
        # Nonzero h0 enters shard 0 as the boundary constant: the full
        # sharded scan with entering state ca*h0+cb matches the reference.
        mesh = _mesh1d(8)
        T, D = 32, 3
        rng = np.random.default_rng(11)
        a = rng.uniform(0.6, 1.0, (T, D)).astype(np.float32)
        b = rng.standard_normal((T, D)).astype(np.float32)
        h0 = rng.standard_normal(D).astype(np.float32)

        def sharded(a_loc, b_loc):
            h_loc = linear_scan(a_loc, b_loc)
            ca, cb = device_linear_scan_carry(
                jnp.prod(a_loc, axis=0), h_loc[-1], "x")
            enter = ca * h0 + cb
            return h_loc + jnp.cumprod(a_loc, axis=0) * enter[None]

        out = shard_map(sharded, mesh=mesh, in_specs=(P("x"), P("x")),
                        out_specs=P("x"))(jnp.asarray(a), jnp.asarray(b))
        ref = ref_linear_scan(a, b, h0)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-4,
                                   atol=3e-4)

    def test_carry_reverse_multidevice(self):
        # reverse=True composes successor segments: the entering carry at
        # shard i equals the fold of shards n-1..i+1.
        mesh = _mesh1d(8)
        n, dh = 8, 3
        rng = np.random.default_rng(12)
        A = rng.uniform(0.5, 1.0, (n, dh)).astype(np.float32)
        B = rng.standard_normal((n, dh)).astype(np.float32)

        def rev(a, b):
            ca, cb = device_linear_scan_carry(a[0], b[0], "x", reverse=True)
            return ca[None], cb[None]

        ca, cb = shard_map(
            rev, mesh=mesh, in_specs=(P("x", None), P("x", None)),
            out_specs=(P("x", None), P("x", None)),
        )(jnp.asarray(A), jnp.asarray(B))
        prev_a = np.ones(dh, np.float32)
        prev_b = np.zeros(dh, np.float32)
        for i in range(n - 1, -1, -1):
            np.testing.assert_allclose(np.asarray(ca[i]), prev_a, rtol=1e-5)
            np.testing.assert_allclose(np.asarray(cb[i]), prev_b, rtol=1e-5,
                                       atol=1e-5)
            prev_a = A[i] * prev_a
            prev_b = A[i] * prev_b + B[i]
        # (update order: segment i applied after its successors)

    def test_seq_carry_scan_reverse_multidevice(self):
        mesh = _mesh1d(8)
        vals = jnp.arange(1.0, 9.0)

        def chunk_fn(carry, v):
            s = carry + v.sum()
            return s, jnp.zeros_like(v) + s

        def run(v):
            c, y = seq_carry_scan(
                chunk_fn, jnp.asarray(0.0), v, "x", reverse=True)
            return c.reshape(1), y

        carry, ys = shard_map(
            run, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P("x")))(vals)
        want = np.cumsum(np.arange(1.0, 9.0)[::-1])[::-1]
        np.testing.assert_allclose(np.asarray(ys), want, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(carry)[0], 36.0, rtol=1e-6)


class TestScratchpad:
    def test_barrier_identity(self):
        x = jnp.arange(4.0)
        np.testing.assert_array_equal(barrier(x), x)

    def test_shared_buffer_flow(self):
        buf = SharedBuffer((4,))
        buf.write(jnp.arange(4.0)).sync()
        np.testing.assert_array_equal(buf.read(), np.arange(4.0))
        assert buf.bytes_written == 16

    def test_read_before_sync_raises(self):
        buf = SharedBuffer((4,))
        buf.write(jnp.arange(4.0))
        with pytest.raises(RuntimeError):
            buf.read()
