"""Tests for chunk_scan, device_comm (shard_map), pipeline, scratchpad."""

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import (
    SharedBuffer,
    barrier,
    chunked_linear_scan,
    device_linear_scan_carry,
    device_shift,
    halo_exchange,
    linear_scan,
    pipeline_apply,
    ring_pass,
    seq_carry_scan,
)

jax.config.update("jax_platform_name", "cpu")


def ref_linear_scan(a, b, h0=0.0):
    h = np.zeros_like(b)
    prev = np.broadcast_to(np.asarray(h0, b.dtype), b.shape[1:]).copy()
    for t in range(b.shape[0]):
        prev = a[t] * prev + b[t]
        h[t] = prev
    return h


class TestLinearScan:
    def test_prefix_sum_is_special_case(self):
        # Paper Fig. 6: prefix sum == linear scan with a == 1.
        b = jnp.arange(1.0, 9.0)
        h = linear_scan(jnp.ones_like(b), b)
        np.testing.assert_allclose(h, np.cumsum(np.asarray(b)), rtol=1e-6)

    @given(
        t=st.sampled_from([4, 8, 16, 32]),
        chunk=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_chunked_matches_flat(self, t, chunk, seed):
        if t % chunk:
            return
        rng = np.random.default_rng(seed)
        a = rng.uniform(0.5, 1.0, (t, 3)).astype(np.float32)
        b = rng.standard_normal((t, 3)).astype(np.float32)
        flat = linear_scan(jnp.asarray(a), jnp.asarray(b))
        chunked = chunked_linear_scan(jnp.asarray(a), jnp.asarray(b), chunk=chunk)
        np.testing.assert_allclose(np.asarray(flat), np.asarray(chunked), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(flat), ref_linear_scan(a, b), rtol=2e-4, atol=2e-4)

    def test_h0_injection(self):
        a = jnp.full((4,), 0.5)
        b = jnp.ones((4,))
        h = chunked_linear_scan(a, b, chunk=2, h0=8.0)
        np.testing.assert_allclose(np.asarray(h), ref_linear_scan(np.asarray(a), np.asarray(b), 8.0), rtol=1e-6)

    def test_h0_fold_with_numpy_inputs(self):
        # Regression: the old ``hasattr(b, "at")`` guard silently dropped h0
        # when a/b arrived as numpy arrays.
        rng = np.random.default_rng(3)
        a = rng.uniform(0.5, 1.0, (6, 2)).astype(np.float32)
        b = rng.standard_normal((6, 2)).astype(np.float32)
        h = linear_scan(a, b, h0=2.5)
        np.testing.assert_allclose(
            np.asarray(h), ref_linear_scan(a, b, 2.5), rtol=2e-5, atol=2e-5
        )
        # And identically for jax inputs (both paths share the fold now).
        h_jax = linear_scan(jnp.asarray(a), jnp.asarray(b), h0=2.5)
        np.testing.assert_allclose(np.asarray(h_jax), np.asarray(h), rtol=1e-6)


def _mesh1d(n, name="x"):
    devs = jax.devices()
    if len(devs) < n:
        pytest.skip(f"needs {n} devices")
    return Mesh(np.array(devs[:n]), (name,))


@pytest.fixture(scope="module")
def mesh4():
    # Spawn extra host devices for this test module only via chex-free trick:
    # tests run under a separate pytest process; if only 1 device, skip.
    return _mesh1d(4)


class TestDeviceComm:
    """Device-space elevator tests run via shard_map on host devices.

    On the 1-device CPU container these exercise the n=1 path; the
    multi-device path is exercised by tests/test_multidevice.py which
    re-launches pytest with XLA_FLAGS=--xla_force_host_platform_device_count.
    """

    def test_device_shift_single(self):
        mesh = _mesh1d(1)
        f = shard_map(
            lambda x: device_shift(x, "x", delta=0, fill=0.0),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        x = jnp.arange(4.0)
        np.testing.assert_array_equal(f(x), x)

    def test_halo_noop(self):
        mesh = _mesh1d(1)
        f = shard_map(
            lambda x: halo_exchange(x, "x", left=0, right=0),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        )
        x = jnp.arange(8.0)
        np.testing.assert_array_equal(f(x), x)


class TestScratchpad:
    def test_barrier_identity(self):
        x = jnp.arange(4.0)
        np.testing.assert_array_equal(barrier(x), x)

    def test_shared_buffer_flow(self):
        buf = SharedBuffer((4,))
        buf.write(jnp.arange(4.0)).sync()
        np.testing.assert_array_equal(buf.read(), np.arange(4.0))
        assert buf.bytes_written == 16

    def test_read_before_sync_raises(self):
        buf = SharedBuffer((4,))
        buf.write(jnp.arange(4.0))
        with pytest.raises(RuntimeError):
            buf.read()
