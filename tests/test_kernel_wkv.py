"""Fused WKV Pallas kernel vs sequential/chunked oracles + shared carry
helpers + gradient parity for the custom-VJP reverse elevator sweep."""

import types
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.wkv.ops as wkv_ops
import repro.kernels.wkv.vjp as wkv_vjp
from repro.kernels.common import (
    cumsum_rows,
    halving_chunk,
    largest_divisor_chunk,
    pick_d_block,
    rev_cumsum_rows,
    reversed_chunk,
    shift_rows,
    validate_divisible,
)
from repro.kernels.wkv.bwd import wkv_pallas_bwd
from repro.kernels.wkv.kernel import wkv_pallas, wkv_pallas_train
from repro.kernels.wkv.ops import resolve_chunk, wkv_fused
from repro.kernels.wkv.ref import (
    wkv_chunked_bwd_ref,
    wkv_chunked_ref,
    wkv_sequential_ref,
)

jax.config.update("jax_platform_name", "cpu")


def _wkv_inputs(b, h, t, dh, seed=0, zero_h0=False):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    # Decay in the Finch regime (|log w| small enough for the ratio trick).
    w = jnp.asarray(rng.uniform(0.85, 0.999, (b, h, t, dh)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((h, dh)).astype(np.float32))
    h0 = (
        jnp.zeros((b, h, dh, dh), jnp.float32)
        if zero_h0
        else jnp.asarray(rng.standard_normal((b, h, dh, dh)).astype(np.float32))
    )
    return r, k, v, w, u, h0


def _assert_wkv_close(got, want, tol=1e-4):
    out_g, s_g = got
    out_w, s_w = want
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_w),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s_g), np.asarray(s_w),
                               rtol=tol, atol=tol)


class TestWKVKernel:
    def test_acceptance_shape_nonzero_h0(self):
        # The acceptance-criteria shape: (B=2, H=4, T=256, Dh=64), h0 != 0.
        args = _wkv_inputs(2, 4, 256, 64)
        got = wkv_pallas(*args, chunk=32, interpret=True)
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_decode_t1(self):
        args = _wkv_inputs(2, 2, 1, 64, seed=1)
        got = wkv_pallas(*args, chunk=1, interpret=True)
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_multi_head_small(self):
        args = _wkv_inputs(1, 8, 64, 16, seed=2)
        got = wkv_pallas(*args, chunk=16, interpret=True)
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_chunk_invariance(self):
        # The VMEM state carry must make chunking invisible.
        args = _wkv_inputs(1, 2, 128, 32, seed=3)
        outs = [wkv_pallas(*args, chunk=c, interpret=True) for c in (8, 32, 128)]
        for got in outs[1:]:
            _assert_wkv_close(got, outs[0], tol=5e-5)

    def test_kernel_matches_chunked_ref(self):
        args = _wkv_inputs(2, 2, 128, 32, seed=4)
        got = wkv_pallas(*args, chunk=32, interpret=True)
        _assert_wkv_close(got, wkv_chunked_ref(*args, chunk=32))

    def test_rejects_bad_chunk(self):
        args = _wkv_inputs(1, 1, 96, 16, seed=5)
        with pytest.raises(ValueError):
            wkv_pallas(*args, chunk=64, interpret=True)


class TestWKVDispatch:
    def test_paths_agree(self):
        args = _wkv_inputs(2, 2, 128, 32, seed=6)
        jnp_path = wkv_fused(*args, chunk=32, use_kernel=False)
        kernel_path = wkv_fused(*args, chunk=32, use_kernel=True)
        ref = wkv_sequential_ref(*args)
        _assert_wkv_close(jnp_path, ref)
        _assert_wkv_close(kernel_path, ref)

    def test_odd_length_sequence(self):
        # T=17 (prime): dispatch must still be exact — the old code silently
        # rewrote chunk = t; now the largest valid divisor is picked.
        args = _wkv_inputs(1, 2, 17, 16, seed=7)
        for use_kernel in (False, True):
            got = wkv_fused(*args, chunk=64, use_kernel=use_kernel)
            _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_chunk_adjust_warns_once(self):
        # chunk=16 does not divide T=20 -> largest divisor (10) + warning,
        # fired once per (T, chunk): dispatch runs at trace time under the
        # outer jit, and a per-retrace warning is log spam.
        wkv_ops._CHUNK_WARNED.clear()
        with pytest.warns(UserWarning, match="does not divide"):
            assert resolve_chunk(20, 16) == 10
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert resolve_chunk(20, 16) == 10  # deduped
            # A different (T, chunk) pair still warns.
        with pytest.warns(UserWarning, match="does not divide"):
            assert resolve_chunk(40, 16) == 10
        wkv_ops._CHUNK_WARNED.clear()
        args = _wkv_inputs(1, 1, 20, 16, seed=8)
        with pytest.warns(UserWarning, match="does not divide"):
            got = wkv_fused(*args, chunk=16, use_kernel=False)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            got = wkv_fused(*args, chunk=16, use_kernel=False)
        _assert_wkv_close(got, wkv_sequential_ref(*args))
        wkv_ops._CHUNK_WARNED.clear()

    def test_repeated_warn_count_is_one(self):
        wkv_ops._CHUNK_WARNED.clear()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            for _ in range(4):
                resolve_chunk(20, 16)
        assert len([w for w in rec if "does not divide" in str(w.message)]) == 1
        wkv_ops._CHUNK_WARNED.clear()

    def test_exact_chunk_does_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert resolve_chunk(256, 64) == 64
            assert resolve_chunk(17, 64) == 17  # t < chunk: single chunk

    def test_nonpositive_chunk_raises(self):
        args = _wkv_inputs(1, 1, 8, 8, seed=11)
        for bad in (0, -4):
            with pytest.raises(ValueError, match="chunk must be >= 1"):
                wkv_fused(*args, chunk=bad)

    def test_ref_raises_on_indivisible(self):
        args = _wkv_inputs(1, 1, 20, 16, seed=9)
        with pytest.raises(ValueError):
            wkv_chunked_ref(*args, chunk=16)

    def test_decode_h0_defaults_to_zeros(self):
        r, k, v, w, u, h0 = _wkv_inputs(1, 2, 1, 32, seed=10, zero_h0=True)
        got = wkv_fused(r, k, v, w, u, None)
        _assert_wkv_close(got, wkv_sequential_ref(r, k, v, w, u, h0))


class TestSharedCarryHelpers:
    def test_largest_divisor_chunk(self):
        assert largest_divisor_chunk(256, 64) == 64
        assert largest_divisor_chunk(20, 16) == 10
        assert largest_divisor_chunk(17, 16) == 1
        assert largest_divisor_chunk(17, 64) == 17

    def test_halving_chunk(self):
        assert halving_chunk(2048, 256) == 256
        assert halving_chunk(96, 64) == 32
        assert halving_chunk(8, 256) == 8

    def test_validate_divisible(self):
        validate_divisible("T", 128, 32)
        with pytest.raises(ValueError):
            validate_divisible("T", 128, 48)
        with pytest.raises(ValueError):
            validate_divisible("T", 128, 0)

    def test_pick_d_block(self):
        assert pick_d_block(256) == 256
        assert pick_d_block(1024) == 512
        with pytest.raises(ValueError):
            pick_d_block(768)

    def test_cumsum_rows_matches_cumsum(self):
        rng = np.random.default_rng(0)
        for rows in (1, 7, 8, 33):
            x = jnp.asarray(rng.standard_normal((rows, 16)).astype(np.float32))
            np.testing.assert_allclose(
                np.asarray(cumsum_rows(x, rows)),
                np.cumsum(np.asarray(x), axis=0),
                rtol=1e-5, atol=1e-5,
            )

    def test_shift_rows(self):
        x = jnp.arange(12.0).reshape(4, 3)
        out = np.asarray(shift_rows(x, 2, -1.0))
        np.testing.assert_array_equal(out[:2], -1.0)
        np.testing.assert_array_equal(out[2:], np.asarray(x)[:2])

    def test_shift_rows_negative_delta(self):
        # The reverse-sweep direction: rows move toward lower indices.
        x = jnp.arange(12.0).reshape(4, 3)
        out = np.asarray(shift_rows(x, -2, -1.0))
        np.testing.assert_array_equal(out[:2], np.asarray(x)[2:])
        np.testing.assert_array_equal(out[2:], -1.0)

    def test_rev_cumsum_rows_matches_suffix_sum(self):
        rng = np.random.default_rng(1)
        for rows in (1, 7, 8, 33):
            x = jnp.asarray(rng.standard_normal((rows, 16)).astype(np.float32))
            want = np.flip(np.cumsum(np.flip(np.asarray(x), 0), axis=0), 0)
            np.testing.assert_allclose(
                np.asarray(rev_cumsum_rows(x, rows)), want,
                rtol=1e-5, atol=1e-5,
            )

    def test_rev_cumsum_is_cumsum_adjoint(self):
        # If y = cumsum(x) then dx = rev_cumsum(dy) — the identity the
        # backward kernel leans on for the cumulative log-decay chains.
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
        dy = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
        _, vjp = jax.vjp(lambda v: cumsum_rows(v, 16), x)
        (want,) = vjp(dy)
        np.testing.assert_allclose(
            np.asarray(rev_cumsum_rows(dy, 16)), np.asarray(want),
            rtol=1e-5, atol=1e-5,
        )

    def test_reversed_chunk(self):
        rev = reversed_chunk(8)
        assert [rev(s) for s in range(8)] == [7, 6, 5, 4, 3, 2, 1, 0]


class TestCostModel:
    def test_wkv_bwd_traffic_ordering(self):
        from repro.core.cost_model import wkv_bwd_traffic, wkv_traffic

        naive, shared, direct = wkv_bwd_traffic(4, 4, 2048, 64, chunk=64)
        assert [c.variant for c in (naive, shared, direct)] == [
            "naive", "shared", "direct"]
        assert all(c.name == "wkv_bwd" for c in (naive, shared, direct))
        # The whole point of the reverse sweep: the kernel path stages only
        # the chunk-entry states, a small fraction of the autodiff
        # residuals, so modeled energy strictly improves.
        assert direct.energy_pj < shared.energy_pj < naive.energy_pj
        assert direct.traffic.scratchpad_bytes < shared.traffic.scratchpad_bytes / 10
        # Backward moves more bytes than forward on every variant.
        f_naive, f_shared, f_direct = wkv_traffic(4, 4, 2048, 64, chunk=64)
        assert shared.traffic.scratchpad_bytes > f_shared.traffic.scratchpad_bytes
        assert direct.traffic.dram_bytes > f_direct.traffic.dram_bytes


# ==========================================================================
# Gradient parity: kernel VJP vs jax.grad of the sequential oracle
# ==========================================================================

def _vjp_grads(fn, args, seed=100):
    """Full cotangent pull-back of (out, S_out) through ``fn``."""
    out, vjp = jax.vjp(fn, *args)
    rng = np.random.default_rng(seed)
    cts = tuple(
        jnp.asarray(rng.standard_normal(o.shape).astype(np.float32)).astype(o.dtype)
        for o in out
    )
    return vjp(cts)


def _assert_grads_close(got, want, tol=2e-3):
    for name, g, w in zip(("dr", "dk", "dv", "dw", "du", "dh0"), got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=tol, atol=tol,
            err_msg=f"gradient mismatch for {name}",
        )


class TestWKVGrad:
    def test_kernel_vjp_matches_sequential_autodiff(self):
        # Nonzero h0 — every cotangent including du and dh0.
        args = _wkv_inputs(2, 2, 128, 32, seed=20)
        got = _vjp_grads(
            lambda *a: wkv_fused(*a, chunk=32, use_kernel=True), args)
        want = _vjp_grads(lambda *a: wkv_sequential_ref(*a), args)
        _assert_grads_close(got, want)

    def test_jnp_path_vjp_matches_sequential_autodiff(self):
        args = _wkv_inputs(2, 2, 128, 32, seed=21)
        got = _vjp_grads(
            lambda *a: wkv_fused(*a, chunk=32, use_kernel=False), args)
        want = _vjp_grads(lambda *a: wkv_sequential_ref(*a), args)
        _assert_grads_close(got, want)

    def test_chunked_bwd_ref_matches_sequential_autodiff(self):
        # The jnp oracle for the reverse kernel, called directly.
        r, k, v, w, u, h0 = _wkv_inputs(2, 2, 64, 16, seed=22)
        rng = np.random.default_rng(23)
        d_out = jnp.asarray(
            rng.standard_normal((2, 2, 64, 16)).astype(np.float32))
        d_S = jnp.asarray(
            rng.standard_normal((2, 2, 16, 16)).astype(np.float32))
        got = wkv_chunked_bwd_ref(r, k, v, w, u, h0, d_out, d_S, chunk=16)
        _, vjp = jax.vjp(lambda *a: wkv_sequential_ref(*a), r, k, v, w, u, h0)
        want = vjp((d_out, d_S))
        _assert_grads_close(got, want)

    def test_grad_chunk_invariance(self):
        # Gradients, like outputs, must not see the chunking.
        args = _wkv_inputs(1, 2, 128, 32, seed=24)
        grads = [
            _vjp_grads(
                lambda *a, c=c: wkv_fused(*a, chunk=c, use_kernel=True), args)
            for c in (8, 32, 128)
        ]
        for got in grads[1:]:
            _assert_grads_close(got, grads[0], tol=1e-3)

    def test_grad_odd_length_fallback_chunk(self):
        # T=20 with chunk=16 -> fallback divisor 10; T=17 (prime, > chunk)
        # -> degenerate chunk=1, i.e. 17 single-token chunks.  Both must
        # still differentiate exactly.
        wkv_ops._CHUNK_WARNED.clear()
        for t in (20, 17):
            args = _wkv_inputs(1, 2, t, 16, seed=25 + t)
            want = _vjp_grads(lambda *a: wkv_sequential_ref(*a), args)
            for use_kernel in (True, False):
                got = _vjp_grads(
                    lambda *a: wkv_fused(*a, chunk=16, use_kernel=use_kernel),
                    args)
                _assert_grads_close(got, want)
        wkv_ops._CHUNK_WARNED.clear()

    def test_grad_decode_t1(self):
        args = _wkv_inputs(1, 2, 1, 16, seed=30)
        want = _vjp_grads(lambda *a: wkv_sequential_ref(*a), args)
        for use_kernel in (True, False):
            got = _vjp_grads(
                lambda *a: wkv_fused(*a, chunk=16, use_kernel=use_kernel), args)
            _assert_grads_close(got, want)

    def test_pallas_bwd_matches_chunked_bwd_ref(self):
        # Kernel vs its jnp oracle on identical cotangents, via s_hist from
        # the training forward.
        r, k, v, w, u, h0 = _wkv_inputs(2, 2, 64, 16, seed=31)
        rng = np.random.default_rng(32)
        d_out = jnp.asarray(
            rng.standard_normal((2, 2, 64, 16)).astype(np.float32))
        d_S = jnp.asarray(
            rng.standard_normal((2, 2, 16, 16)).astype(np.float32))
        out, s_out, s_hist = wkv_pallas_train(
            r, k, v, w, u, h0, chunk=16, interpret=True)
        dr, dk, dv, dw, du_part, dh0 = wkv_pallas_bwd(
            r, k, v, w, u, s_hist, d_out, d_S, chunk=16, interpret=True)
        got = (dr, dk, dv, dw, du_part.sum(axis=0), dh0)
        want = wkv_chunked_bwd_ref(r, k, v, w, u, h0, d_out, d_S, chunk=16)
        _assert_grads_close(got, want, tol=5e-4)

    def test_train_forward_emits_entry_states(self):
        # s_hist[c] must equal the state the plain forward would enter
        # chunk c with: s_hist[0] == h0, s_hist[c] == exit state of the
        # (truncated) forward over chunks < c.
        args = _wkv_inputs(1, 2, 64, 16, seed=33)
        r, k, v, w, u, h0 = args
        out_t, s_t, s_hist = wkv_pallas_train(*args, chunk=16, interpret=True)
        out_p, s_p = wkv_pallas(*args, chunk=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_p),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_p),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_hist[:, :, 0]),
                                   np.asarray(h0), rtol=1e-5, atol=1e-5)
        for c in (1, 2, 3):
            _, s_prefix = wkv_sequential_ref(
                r[:, :, : 16 * c], k[:, :, : 16 * c], v[:, :, : 16 * c],
                w[:, :, : 16 * c], u, h0)
            np.testing.assert_allclose(
                np.asarray(s_hist[:, :, c]), np.asarray(s_prefix),
                rtol=2e-4, atol=2e-4)


# ==========================================================================
# Dispatch: auto mode must pick the kernel on TPU (regression: the old
# code mapped use_kernel=None to False, so auto could never select it)
# ==========================================================================

class TestAutoDispatch:
    def _fake_tpu(self, monkeypatch):
        # Pretend the backend is TPU but keep Pallas in interpret mode so
        # the kernel actually runs on this container.
        monkeypatch.setattr(wkv_ops, "on_tpu", lambda: True)
        monkeypatch.setattr(wkv_ops, "interpret_default", lambda: True)

    def test_auto_picks_kernel_forward(self, monkeypatch):
        self._fake_tpu(monkeypatch)
        calls = []
        real = wkv_vjp.wkv_pallas
        monkeypatch.setattr(
            wkv_vjp, "wkv_pallas",
            lambda *a, **kw: calls.append("fwd") or real(*a, **kw))
        args = _wkv_inputs(1, 2, 64, 16, seed=40)
        got = wkv_fused(*args, chunk=16, use_kernel=None)
        assert calls == ["fwd"], "auto mode did not select the Pallas kernel"
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_auto_picks_kernel_for_training(self, monkeypatch):
        self._fake_tpu(monkeypatch)
        calls = []
        real_train = wkv_vjp.wkv_pallas_train
        real_bwd = wkv_vjp.wkv_pallas_bwd
        monkeypatch.setattr(
            wkv_vjp, "wkv_pallas_train",
            lambda *a, **kw: calls.append("train_fwd") or real_train(*a, **kw))
        monkeypatch.setattr(
            wkv_vjp, "wkv_pallas_bwd",
            lambda *a, **kw: calls.append("bwd") or real_bwd(*a, **kw))
        args = _wkv_inputs(1, 2, 64, 16, seed=41)
        got = _vjp_grads(lambda *a: wkv_fused(*a, chunk=16, use_kernel=None),
                         args)
        assert calls == ["train_fwd", "bwd"], (
            "auto mode did not run the kernel VJP pair under jax.grad")
        want = _vjp_grads(lambda *a: wkv_sequential_ref(*a), args)
        _assert_grads_close(got, want)

    def test_apply_rwkv_block_auto_reaches_kernel(self, monkeypatch):
        # End-to-end: the model block with use_kernel=None (the default)
        # must reach the Pallas path under TPU/interpret — including a
        # gradient through it.
        from repro.model import recurrent as rec

        self._fake_tpu(monkeypatch)
        calls = []
        real_train = wkv_vjp.wkv_pallas_train
        real_bwd = wkv_vjp.wkv_pallas_bwd
        monkeypatch.setattr(
            wkv_vjp, "wkv_pallas_train",
            lambda *a, **kw: calls.append("train_fwd") or real_train(*a, **kw))
        monkeypatch.setattr(
            wkv_vjp, "wkv_pallas_bwd",
            lambda *a, **kw: calls.append("bwd") or real_bwd(*a, **kw))

        d = 64  # one WKV head
        rng = np.random.default_rng(42)
        mk = lambda shape, scale=0.1: jnp.asarray(  # noqa: E731
            rng.standard_normal(shape).astype(np.float32) * scale)
        params = {
            "mu": mk((5, d)),
            "w_r": mk((d, d)), "w_k": mk((d, d)),
            "w_v": mk((d, d)), "w_g": mk((d, d)),
            "w_decay_base": mk((d,)),
            "w_decay_lora_a": mk((d, 64)),
            "w_decay_lora_b": mk((64, d)),
            "u_bonus": mk((d,)),
            "w_o": mk((d, d)),
            "out_norm": {"scale": jnp.ones((d,), jnp.float32)},
        }
        cfg = types.SimpleNamespace(fsdp_gather_weights=False, norm_eps=1e-6)
        x = mk((2, 32, d), scale=1.0)

        def loss(p, x_):
            out, _ = rec.apply_rwkv_block(p, x_, cfg, chunk=16)
            return (out * out).sum()

        grads = jax.grad(loss)(params, x)
        assert calls == ["train_fwd", "bwd"], (
            "apply_rwkv_block auto mode did not take the kernel VJP path")

        # Parity: same loss/grads as the forced-jnp path.
        calls.clear()
        monkeypatch.setattr(wkv_ops, "on_tpu", lambda: False)

        def loss_jnp(p, x_):
            out, _ = rec.apply_rwkv_block(p, x_, cfg, chunk=16,
                                          use_kernel=False)
            return (out * out).sum()

        grads_jnp = jax.grad(loss_jnp)(params, x)
        flat, _ = jax.tree.flatten(grads)
        flat_jnp, _ = jax.tree.flatten(grads_jnp)
        for g, gj in zip(flat, flat_jnp):
            np.testing.assert_allclose(np.asarray(g), np.asarray(gj),
                                       rtol=2e-3, atol=2e-3)


# ==========================================================================
# bf16 I/O through dispatch (no caller-side upcast) + segment summaries
# ==========================================================================

class TestWKVBf16:
    """r/k/v/w may arrive in bf16: f32 accumulation inside, input dtype out."""

    def _bf16_inputs(self, *shape_args, **kw):
        r, k, v, w, u, h0 = _wkv_inputs(*shape_args, **kw)
        bf = jnp.bfloat16
        return r.astype(bf), k.astype(bf), v.astype(bf), w.astype(bf), u, h0

    def test_jnp_dispatch_bf16_parity(self):
        args32 = _wkv_inputs(2, 2, 64, 16, seed=60)
        args16 = self._bf16_inputs(2, 2, 64, 16, seed=60)
        out32, s32 = wkv_fused(*args32, chunk=16, use_kernel=False)
        out16, s16 = wkv_fused(*args16, chunk=16, use_kernel=False)
        assert out16.dtype == jnp.bfloat16
        assert s16.dtype == jnp.float32  # state stays full precision
        # bf16 inputs quantize the operands (~2^-8 relative); the f32
        # accumulation keeps the error at the input-rounding level.
        np.testing.assert_allclose(
            np.asarray(out16, dtype=np.float32), np.asarray(out32),
            rtol=0.1, atol=0.1)
        np.testing.assert_allclose(
            np.asarray(s16), np.asarray(s32), rtol=0.1, atol=0.15)

    def test_kernel_interpret_bf16_parity(self):
        args16 = self._bf16_inputs(1, 2, 64, 16, seed=61)
        out_k, s_k = wkv_fused(*args16, chunk=16, use_kernel=True)
        out_j, s_j = wkv_fused(*args16, chunk=16, use_kernel=False)
        assert out_k.dtype == jnp.bfloat16
        # Same bf16 inputs on both backends: kernel vs jnp agree tightly.
        np.testing.assert_allclose(
            np.asarray(out_k, dtype=np.float32),
            np.asarray(out_j, dtype=np.float32), rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_j),
                                   rtol=2e-2, atol=2e-2)

    def test_decode_t1_bf16(self):
        args16 = self._bf16_inputs(2, 2, 1, 16, seed=62)
        out, s = wkv_fused(*args16, chunk=16, use_kernel=False)
        assert out.dtype == jnp.bfloat16 and s.dtype == jnp.float32

    def test_grads_come_back_in_input_dtypes(self):
        args16 = self._bf16_inputs(1, 2, 32, 16, seed=63)
        grads = _vjp_grads(
            lambda *a: wkv_fused(*a, chunk=16, use_kernel=False), args16)
        dtypes = [g.dtype for g in grads]
        assert dtypes[:4] == [jnp.bfloat16] * 4, dtypes
        assert dtypes[4] == jnp.float32 and dtypes[5] == jnp.float32

    def test_model_block_passes_bf16_through(self, monkeypatch):
        # apply_rwkv_block must not upcast before dispatch: the dtype
        # reaching wkv_fused is the model dtype.
        from repro.model import recurrent as rec

        seen = {}
        real = rec.wkv_fused

        def spy(r, *a, **kw):
            seen["dtype"] = r.dtype
            return real(r, *a, **kw)

        monkeypatch.setattr(rec, "wkv_fused", spy)
        d = 64
        rng = np.random.default_rng(64)
        mk = lambda shape, scale=0.1: jnp.asarray(  # noqa: E731
            rng.standard_normal(shape).astype(np.float32) * scale
        ).astype(jnp.bfloat16)
        params = {
            "mu": mk((5, d)),
            "w_r": mk((d, d)), "w_k": mk((d, d)),
            "w_v": mk((d, d)), "w_g": mk((d, d)),
            "w_decay_base": mk((d,)),
            "w_decay_lora_a": mk((d, 64)),
            "w_decay_lora_b": mk((64, d)),
            "u_bonus": mk((d,)),
            "w_o": mk((d, d)),
            "out_norm": {"scale": jnp.ones((d,), jnp.bfloat16)},
        }
        cfg = types.SimpleNamespace(fsdp_gather_weights=False, norm_eps=1e-6)
        x = mk((1, 32, d), scale=1.0)
        out, _ = rec.apply_rwkv_block(params, x, cfg, chunk=16,
                                      use_kernel=False)
        assert seen["dtype"] == jnp.bfloat16
        assert out.dtype == jnp.bfloat16


class TestWKVSummary:
    """The (decay-product, exit-state) segment summary: kernel emit, jnp
    oracle, and the linearity identity the sequence-parallel path uses."""

    def test_segment_decay_matches_kernel_emit(self):
        from repro.kernels.wkv.kernel import wkv_pallas_summary
        from repro.kernels.wkv.ref import wkv_segment_decay

        args = _wkv_inputs(2, 2, 64, 16, seed=70)
        out, s, a = wkv_pallas_summary(*args, chunk=16, interpret=True)
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(wkv_segment_decay(args[3])),
            rtol=1e-5, atol=1e-5)
        # out/s unchanged vs the plain forward.
        _assert_wkv_close((out, s), wkv_sequential_ref(*args))

    def test_summary_composition_identity(self):
        # The protocol's core identity: running from entering state h0 ==
        # running from zero + the (A, S) composition + entry correction.
        from repro.kernels.wkv.ops import wkv_fused_summary
        from repro.kernels.wkv.ref import wkv_entry_correction

        r, k, v, w, u, h0 = _wkv_inputs(2, 2, 64, 16, seed=71)
        out0, s0, a_seg = wkv_fused_summary(r, k, v, w, u, None, chunk=16,
                                            use_kernel=False)
        out_h, s_h = wkv_fused(r, k, v, w, u, h0, chunk=16, use_kernel=False)
        out_fix = out0 + wkv_entry_correction(r, w, h0)
        s_fix = a_seg[..., :, None] * h0 + s0
        np.testing.assert_allclose(np.asarray(out_fix), np.asarray(out_h),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s_fix), np.asarray(s_h),
                                   rtol=2e-4, atol=2e-4)

    def test_summary_grads_match_reference(self):
        # d_a cotangent folds into dw in closed form; check against jax.grad
        # of a pure-jnp rendering (sequential scan + explicit decay product).
        from repro.kernels.wkv.ops import wkv_fused_summary

        r, k, v, w, u, h0 = _wkv_inputs(1, 2, 32, 16, seed=72)

        def f_sum(use_kernel):
            def f(*args):
                out, s, a = wkv_fused_summary(*args, chunk=16,
                                              use_kernel=use_kernel)
                return out.sum() + (s * s).sum() + (a * a * 3.0).sum()
            return f

        def f_ref(r_, k_, v_, w_, u_, h0_):
            out, s = wkv_sequential_ref(r_, k_, v_, w_, u_, h0_)
            logw = jnp.log(jnp.clip(w_, 1e-8, 1.0))
            a = jnp.exp(jnp.sum(logw, axis=2))
            return out.sum() + (s * s).sum() + (a * a * 3.0).sum()

        argnums = tuple(range(6))
        want = jax.grad(f_ref, argnums=argnums)(r, k, v, w, u, h0)
        for use_kernel in (False, True):
            got = jax.grad(f_sum(use_kernel), argnums=argnums)(
                r, k, v, w, u, h0)
            _assert_grads_close(got, want)

    def test_seqshard_cost_model_ordering(self):
        from repro.core.cost_model import wkv_seqshard_traffic

        naive, shared, direct = wkv_seqshard_traffic(4, 4, 8192, 64, 8)
        assert [c.variant for c in (naive, shared, direct)] == [
            "naive", "shared", "direct"]
        # O(Dh²) summary hops vs O(T·D) token re-gather: orders of
        # magnitude fewer bytes cross the seq axis.
        crossed_naive = naive.traffic.dram_bytes
        crossed_direct = direct.traffic.fabric_bytes
        assert crossed_direct * 50 < crossed_naive
        assert direct.energy_pj < shared.energy_pj < naive.energy_pj
        # Summary bytes are independent of T.
        _, _, direct_long = wkv_seqshard_traffic(4, 4, 4 * 8192, 64, 8)
        assert direct_long.traffic.fabric_bytes == crossed_direct


class TestWKVDecodeKernel:
    """Persistent-state decode micro-kernels (kernels/wkv/decode)."""

    def test_single_step_parity_nonzero_state(self):
        from repro.kernels.wkv.decode import wkv_decode_pallas

        args = _wkv_inputs(2, 3, 1, 32, seed=70)  # h0 != 0 by default
        got = wkv_decode_pallas(*args, interpret=True)
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_window_parity_odd_k(self):
        # K not dividing anything (prime, > any chunk): the window kernel
        # has no divisibility constraint.
        from repro.kernels.wkv.decode import wkv_decode_window_pallas

        for k_win in (1, 5, 37):
            args = _wkv_inputs(2, 2, k_win, 16, seed=71)
            got = wkv_decode_window_pallas(*args, interpret=True)
            _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_state_carry_across_consecutive_windows(self):
        # Chaining windows through S_out must equal the one-shot sweep —
        # the serve-loop contract (state carried between dispatches).
        from repro.kernels.wkv.decode import wkv_decode_window_pallas

        r, k, v, w, u, h0 = _wkv_inputs(2, 2, 37, 16, seed=72)
        one_out, one_s = wkv_decode_window_pallas(
            r, k, v, w, u, h0, interpret=True)
        outs, s = [], h0
        for lo, hi in ((0, 16), (16, 32), (32, 37)):
            o, s = wkv_decode_window_pallas(
                r[:, :, lo:hi], k[:, :, lo:hi], v[:, :, lo:hi],
                w[:, :, lo:hi], u, s, interpret=True)
            outs.append(o)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate(outs, axis=2)), np.asarray(one_out),
            rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s), np.asarray(one_s),
                                   rtol=1e-5, atol=1e-5)

    def test_window_bf16_io(self):
        from repro.kernels.wkv.decode import wkv_decode_window_pallas

        r, k, v, w, u, h0 = _wkv_inputs(2, 2, 9, 16, seed=73)
        bf = jnp.bfloat16
        got_o, got_s = wkv_decode_window_pallas(
            r.astype(bf), k.astype(bf), v.astype(bf), w.astype(bf),
            u.astype(bf), h0, interpret=True)
        assert got_o.dtype == bf
        assert got_s.dtype == jnp.float32  # state stays full precision
        want_o, want_s = wkv_sequential_ref(
            r.astype(bf).astype(jnp.float32), k.astype(bf).astype(jnp.float32),
            v.astype(bf).astype(jnp.float32), w.astype(bf).astype(jnp.float32),
            u.astype(bf).astype(jnp.float32), h0)
        np.testing.assert_allclose(
            np.asarray(got_o, dtype=np.float32), np.asarray(want_o),
            rtol=0.1, atol=0.1)
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                                   rtol=2e-2, atol=2e-2)

    def test_decode_grads_match_sequential_autodiff(self):
        args = _wkv_inputs(1, 2, 7, 16, seed=74)
        want = _vjp_grads(lambda *a: wkv_sequential_ref(*a), args)
        for use_kernel in (True, False):
            got = _vjp_grads(
                lambda *a: wkv_fused(*a, decode=True, use_kernel=use_kernel),
                args)
            _assert_grads_close(got, want)

    def test_dispatch_decode_routes_to_decode_kernel(self, monkeypatch):
        # decode=True windows <= DECODE_WINDOW_MAX take the decode kernel;
        # longer stateful sweeps fall through to the chunked elevator path.
        import repro.kernels.wkv.decode as wkv_decode

        calls = []
        real_win = wkv_decode.wkv_decode_window_pallas
        real_one = wkv_decode.wkv_decode_pallas
        monkeypatch.setattr(
            wkv_ops, "wkv_decode_diff",
            lambda *a, **kw: calls.append("decode")
            or wkv_decode.wkv_decode_diff(*a, **kw))
        monkeypatch.setattr(
            wkv_decode, "wkv_decode_window_pallas",
            lambda *a, **kw: calls.append("window") or real_win(*a, **kw))
        monkeypatch.setattr(
            wkv_decode, "wkv_decode_pallas",
            lambda *a, **kw: calls.append("single") or real_one(*a, **kw))

        args = _wkv_inputs(1, 2, 8, 16, seed=75)
        wkv_fused(*args, decode=True, use_kernel=True)
        assert calls == ["decode", "window"]

        calls.clear()
        args1 = _wkv_inputs(1, 2, 1, 16, seed=76)
        wkv_fused(*args1, use_kernel=True)  # t==1 infers decode=True
        assert calls == ["decode", "single"]

        calls.clear()
        args_long = _wkv_inputs(1, 2, 128, 16, seed=77)
        got = wkv_fused(*args_long, chunk=16, decode=True, use_kernel=True)
        assert calls == []  # chunked path, not the decode kernel
        _assert_wkv_close(got, wkv_sequential_ref(*args_long))

    def test_training_path_unaffected_by_decode_default(self):
        # decode=None + t > 1 must keep the chunked (training) route.
        args = _wkv_inputs(1, 2, 32, 16, seed=78)
        got = wkv_fused(*args, chunk=16, use_kernel=False)
        _assert_wkv_close(got, wkv_chunked_ref(*args, chunk=16))

    def test_decode_cost_model_per_token_state_bytes(self):
        # Acceptance: modeled per-token state bytes drop ~K× at K=32.
        from repro.core.cost_model import (
            wkv_decode_token_io,
            wkv_decode_traffic,
        )

        b, h, dh, k = 4, 4, 64, 32
        naive, shared, direct = wkv_decode_traffic(b, h, dh, k)
        assert [c.variant for c in (naive, shared, direct)] == [
            "naive", "shared", "direct"]
        tok_io = wkv_decode_token_io(b, h, dh, k)
        naive_state = naive.traffic.dram_bytes - tok_io
        direct_state = direct.traffic.dram_bytes - tok_io
        assert naive_state == k * direct_state
        assert direct.energy_pj < shared.energy_pj < naive.energy_pj
        # K=1 degenerates to the per-token pattern: no fabric traffic.
        _, _, direct1 = wkv_decode_traffic(b, h, dh, 1)
        assert direct1.traffic.fabric_bytes == 0
