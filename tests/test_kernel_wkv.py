"""Fused WKV Pallas kernel vs sequential/chunked oracles + shared carry helpers."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import (
    cumsum_rows,
    halving_chunk,
    largest_divisor_chunk,
    pick_d_block,
    shift_rows,
    validate_divisible,
)
from repro.kernels.wkv.kernel import wkv_pallas
from repro.kernels.wkv.ops import resolve_chunk, wkv_fused
from repro.kernels.wkv.ref import wkv_chunked_ref, wkv_sequential_ref

jax.config.update("jax_platform_name", "cpu")


def _wkv_inputs(b, h, t, dh, seed=0, zero_h0=False):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    # Decay in the Finch regime (|log w| small enough for the ratio trick).
    w = jnp.asarray(rng.uniform(0.85, 0.999, (b, h, t, dh)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((h, dh)).astype(np.float32))
    h0 = (
        jnp.zeros((b, h, dh, dh), jnp.float32)
        if zero_h0
        else jnp.asarray(rng.standard_normal((b, h, dh, dh)).astype(np.float32))
    )
    return r, k, v, w, u, h0


def _assert_wkv_close(got, want, tol=1e-4):
    out_g, s_g = got
    out_w, s_w = want
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_w),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s_g), np.asarray(s_w),
                               rtol=tol, atol=tol)


class TestWKVKernel:
    def test_acceptance_shape_nonzero_h0(self):
        # The acceptance-criteria shape: (B=2, H=4, T=256, Dh=64), h0 != 0.
        args = _wkv_inputs(2, 4, 256, 64)
        got = wkv_pallas(*args, chunk=32, interpret=True)
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_decode_t1(self):
        args = _wkv_inputs(2, 2, 1, 64, seed=1)
        got = wkv_pallas(*args, chunk=1, interpret=True)
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_multi_head_small(self):
        args = _wkv_inputs(1, 8, 64, 16, seed=2)
        got = wkv_pallas(*args, chunk=16, interpret=True)
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_chunk_invariance(self):
        # The VMEM state carry must make chunking invisible.
        args = _wkv_inputs(1, 2, 128, 32, seed=3)
        outs = [wkv_pallas(*args, chunk=c, interpret=True) for c in (8, 32, 128)]
        for got in outs[1:]:
            _assert_wkv_close(got, outs[0], tol=5e-5)

    def test_kernel_matches_chunked_ref(self):
        args = _wkv_inputs(2, 2, 128, 32, seed=4)
        got = wkv_pallas(*args, chunk=32, interpret=True)
        _assert_wkv_close(got, wkv_chunked_ref(*args, chunk=32))

    def test_rejects_bad_chunk(self):
        args = _wkv_inputs(1, 1, 96, 16, seed=5)
        with pytest.raises(ValueError):
            wkv_pallas(*args, chunk=64, interpret=True)


class TestWKVDispatch:
    def test_paths_agree(self):
        args = _wkv_inputs(2, 2, 128, 32, seed=6)
        jnp_path = wkv_fused(*args, chunk=32, use_kernel=False)
        kernel_path = wkv_fused(*args, chunk=32, use_kernel=True)
        ref = wkv_sequential_ref(*args)
        _assert_wkv_close(jnp_path, ref)
        _assert_wkv_close(kernel_path, ref)

    def test_odd_length_sequence(self):
        # T=17 (prime): dispatch must still be exact — the old code silently
        # rewrote chunk = t; now the largest valid divisor is picked.
        args = _wkv_inputs(1, 2, 17, 16, seed=7)
        for use_kernel in (False, True):
            got = wkv_fused(*args, chunk=64, use_kernel=use_kernel)
            _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_chunk_adjust_warns(self):
        # chunk=16 does not divide T=20 -> largest divisor (10) + warning.
        with pytest.warns(UserWarning, match="does not divide"):
            assert resolve_chunk(20, 16) == 10
        args = _wkv_inputs(1, 1, 20, 16, seed=8)
        with pytest.warns(UserWarning, match="does not divide"):
            got = wkv_fused(*args, chunk=16, use_kernel=False)
        _assert_wkv_close(got, wkv_sequential_ref(*args))

    def test_exact_chunk_does_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert resolve_chunk(256, 64) == 64
            assert resolve_chunk(17, 64) == 17  # t < chunk: single chunk

    def test_nonpositive_chunk_raises(self):
        args = _wkv_inputs(1, 1, 8, 8, seed=11)
        for bad in (0, -4):
            with pytest.raises(ValueError, match="chunk must be >= 1"):
                wkv_fused(*args, chunk=bad)

    def test_ref_raises_on_indivisible(self):
        args = _wkv_inputs(1, 1, 20, 16, seed=9)
        with pytest.raises(ValueError):
            wkv_chunked_ref(*args, chunk=16)

    def test_decode_h0_defaults_to_zeros(self):
        r, k, v, w, u, h0 = _wkv_inputs(1, 2, 1, 32, seed=10, zero_h0=True)
        got = wkv_fused(r, k, v, w, u, None)
        _assert_wkv_close(got, wkv_sequential_ref(r, k, v, w, u, h0))


class TestSharedCarryHelpers:
    def test_largest_divisor_chunk(self):
        assert largest_divisor_chunk(256, 64) == 64
        assert largest_divisor_chunk(20, 16) == 10
        assert largest_divisor_chunk(17, 16) == 1
        assert largest_divisor_chunk(17, 64) == 17

    def test_halving_chunk(self):
        assert halving_chunk(2048, 256) == 256
        assert halving_chunk(96, 64) == 32
        assert halving_chunk(8, 256) == 8

    def test_validate_divisible(self):
        validate_divisible("T", 128, 32)
        with pytest.raises(ValueError):
            validate_divisible("T", 128, 48)
        with pytest.raises(ValueError):
            validate_divisible("T", 128, 0)

    def test_pick_d_block(self):
        assert pick_d_block(256) == 256
        assert pick_d_block(1024) == 512
        with pytest.raises(ValueError):
            pick_d_block(768)

    def test_cumsum_rows_matches_cumsum(self):
        rng = np.random.default_rng(0)
        for rows in (1, 7, 8, 33):
            x = jnp.asarray(rng.standard_normal((rows, 16)).astype(np.float32))
            np.testing.assert_allclose(
                np.asarray(cumsum_rows(x, rows)),
                np.cumsum(np.asarray(x), axis=0),
                rtol=1e-5, atol=1e-5,
            )

    def test_shift_rows(self):
        x = jnp.arange(12.0).reshape(4, 3)
        out = np.asarray(shift_rows(x, 2, -1.0))
        np.testing.assert_array_equal(out[:2], -1.0)
        np.testing.assert_array_equal(out[2:], np.asarray(x)[:2])
