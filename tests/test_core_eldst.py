"""Tests for the eLDST unit (fromThreadOrMem): load-once, forward-many."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import forward_stats, from_thread_or_mem

jax.config.update("jax_platform_name", "cpu")


def ref_eldst(mem, pred, delta, window=None, const=0):
    """Direct transcription of the recurrence (paper §4.2)."""
    n = mem.shape[0]
    win = window if window is not None else n
    out = np.full_like(np.asarray(mem), const)
    for t in range(n):
        if pred[t]:
            out[t] = mem[t]
        else:
            src = t - delta
            if src >= 0 and t // win == src // win:
                out[t] = out[src]
    return out


class TestFromThreadOrMem:
    def test_single_loader_broadcast_chain(self):
        # Thread 0 loads; everyone else forwards (matmul column pattern).
        mem = jnp.arange(10.0, 20.0)
        pred = jnp.zeros(10, bool).at[0].set(True)
        out = from_thread_or_mem(mem, pred, delta=1)
        np.testing.assert_array_equal(out, np.full(10, 10.0))

    def test_strided_loaders(self):
        # Every 4th thread loads (window=4, delta=1): matmul tile pattern.
        mem = jnp.arange(12.0)
        pred = jnp.asarray([t % 4 == 0 for t in range(12)])
        out = from_thread_or_mem(mem, pred, delta=1, window=4)
        expected = np.repeat([0.0, 4.0, 8.0], 4)
        np.testing.assert_array_equal(out, expected)

    def test_delta_gt_one_interleaved_chains(self):
        # delta=2: even and odd chains are independent.
        mem = jnp.arange(8.0)
        pred = jnp.asarray([True, True, False, False, False, False, False, False])
        out = from_thread_or_mem(mem, pred, delta=2)
        np.testing.assert_array_equal(out, [0, 1, 0, 1, 0, 1, 0, 1])

    def test_const_when_no_producer(self):
        mem = jnp.arange(4.0)
        pred = jnp.asarray([False, False, True, False])
        out = from_thread_or_mem(mem, pred, delta=1, const=-9.0)
        np.testing.assert_array_equal(out, [-9, -9, 2, 2])

    def test_window_resets_forwarding(self):
        mem = jnp.arange(8.0)
        pred = jnp.zeros(8, bool).at[0].set(True)
        out = from_thread_or_mem(mem, pred, delta=1, window=4, const=0.0)
        np.testing.assert_array_equal(out, [0, 0, 0, 0, 0, 0, 0, 0])
        pred2 = jnp.zeros(8, bool).at[0].set(True).at[4].set(True)
        out2 = from_thread_or_mem(mem, pred2, delta=1, window=4, const=-1.0)
        np.testing.assert_array_equal(out2, [0, 0, 0, 0, 4, 4, 4, 4])

    def test_vector_payload(self):
        mem = jnp.arange(12.0).reshape(6, 2)
        pred = jnp.zeros(6, bool).at[0].set(True)
        out = from_thread_or_mem(mem, pred, delta=1)
        np.testing.assert_array_equal(out, np.tile([0.0, 1.0], (6, 1)))

    def test_rejects_nonpositive_delta(self):
        with pytest.raises(ValueError):
            from_thread_or_mem(jnp.arange(4.0), jnp.ones(4, bool), delta=0)

    @given(
        n=st.integers(2, 48),
        delta=st.integers(1, 8),
        window=st.one_of(st.none(), st.integers(2, 12)),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_matches_recurrence(self, n, delta, window, seed):
        rng = np.random.default_rng(seed)
        mem = rng.standard_normal(n).astype(np.float32)
        pred = rng.random(n) < 0.3
        out = from_thread_or_mem(
            jnp.asarray(mem), jnp.asarray(pred), delta, window=window, const=5.0
        )
        np.testing.assert_array_equal(
            np.asarray(out), ref_eldst(mem, pred, delta, window, 5.0)
        )

    def test_matmul_load_reduction_nkm_to_nm(self):
        # Paper §3.3: N*K*M naive loads -> N*M with forwarding.  Model the A
        # operand of a (N,K)x(K,M) matmul: N*M threads each need K values of
        # their row; only threads with ty==0 load.
        n_, k_, m_ = 4, 5, 6
        pred = jnp.asarray([ty == 0 for tx in range(n_) for ty in range(m_)])
        stats = forward_stats(np.asarray(pred), delta=1)
        assert stats.loads_issued == n_   # one loader per row
        assert stats.loads_forwarded == n_ * m_ - n_
        # Per-element traffic across the K-loop:
        naive_loads = n_ * k_ * m_
        direct_loads = n_ * k_
        assert naive_loads // direct_loads == m_


class TestJitAndGrad:
    def test_jit(self):
        f = jax.jit(lambda m, p: from_thread_or_mem(m, p, 2, window=6))
        mem = jnp.arange(12.0)
        pred = jnp.asarray([t % 6 < 2 for t in range(12)])
        np.testing.assert_array_equal(
            f(mem, pred), ref_eldst(np.asarray(mem), np.asarray(pred), 2, 6)
        )

    def test_grad_flows_to_loaded_values(self):
        # d(sum(out))/d(mem) counts how many threads consume each load.
        mem = jnp.arange(4.0)
        pred = jnp.asarray([True, False, False, False])
        g = jax.grad(lambda m: from_thread_or_mem(m, pred, 1).sum())(mem)
        np.testing.assert_array_equal(g, [4.0, 0.0, 0.0, 0.0])
