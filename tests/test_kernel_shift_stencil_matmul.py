"""token_shift, stencil2d, matmul_fwd kernels vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul_fwd.kernel import matmul_fwd_pallas
from repro.kernels.matmul_fwd.ref import matmul_ref
from repro.kernels.stencil2d.kernel import stencil2d_pallas
from repro.kernels.stencil2d.ref import stencil2d_ref
from repro.kernels.token_shift.kernel import token_shift_pallas
from repro.kernels.token_shift.ref import token_shift_ref

jax.config.update("jax_platform_name", "cpu")


class TestTokenShift:
    @pytest.mark.parametrize("shape,taps", [
        ((1, 64, 128), 2),
        ((2, 128, 128), 4),
        ((1, 256, 256), 4),
        ((2, 64, 384), 8),
    ])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, taps, dtype):
        b, t, d = shape
        rng = np.random.default_rng(taps * 1000 + t)
        x = jnp.asarray(rng.standard_normal(shape).astype(np.float32)).astype(dtype)
        w = jnp.asarray(rng.standard_normal((taps, d)).astype(np.float32)).astype(dtype)
        out = token_shift_pallas(x, w, chunk=min(64, t), interpret=True)
        ref = token_shift_ref(x, w)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
        )

    def test_chunk_boundary_carry(self):
        # Values must flow across chunk boundaries through the VMEM token
        # buffer: compare chunked vs whole-sequence execution.
        b, t, d = 1, 128, 128
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((4, d)).astype(np.float32))
        o_small = token_shift_pallas(x, w, chunk=16, interpret=True)
        o_big = token_shift_pallas(x, w, chunk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(o_small), np.asarray(o_big), rtol=1e-6)

    def test_identity_tap(self):
        x = jnp.ones((1, 32, 128), jnp.float32)
        w = jnp.zeros((2, 128), jnp.float32).at[0].set(1.0)
        out = token_shift_pallas(x, w, chunk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.ones((1, 32, 128)))

    def test_rejects_too_many_taps(self):
        x = jnp.ones((1, 32, 128))
        with pytest.raises(ValueError):
            token_shift_pallas(x, jnp.ones((9, 128)), interpret=True)


class TestStencil2d:
    @pytest.mark.parametrize("h,w,block_h", [(128, 128, 32), (256, 384, 128), (64, 512, 64)])
    def test_matches_ref(self, h, w, block_h):
        rng = np.random.default_rng(h + w)
        x = jnp.asarray(rng.standard_normal((h, w)).astype(np.float32))
        c = jnp.asarray(rng.standard_normal(5).astype(np.float32))
        out = stencil2d_pallas(x, c, block_h=block_h, interpret=True)
        ref = stencil2d_ref(x, c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

    def test_boundary_constant(self):
        x = jnp.ones((64, 128), jnp.float32)
        c = jnp.asarray([0.0, 1.0, 1.0, 1.0, 1.0], jnp.float32)
        out = stencil2d_pallas(x, c, block_h=32, boundary=5.0, interpret=True)
        ref = stencil2d_ref(x, c, boundary=5.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
        # Interior = 4 neighbors of 1.0; corner = 2 real + 2 boundary(5.0).
        assert np.asarray(out)[5, 5] == pytest.approx(4.0)
        assert np.asarray(out)[0, 0] == pytest.approx(1 + 1 + 5 + 5)

    def test_hotspot_style_update(self):
        # One Jacobi step keeps a constant field constant (row-sum-1 coeffs).
        x = jnp.full((128, 256), 3.0, jnp.float32)
        c = jnp.asarray([0.6, 0.1, 0.1, 0.1, 0.1], jnp.float32)
        out = stencil2d_pallas(x, c, block_h=64, boundary=3.0, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.full((128, 256), 3.0), rtol=1e-6)


class TestMatmulFwd:
    @pytest.mark.parametrize("m,k,n,bm,bn,bk", [
        (128, 128, 128, 128, 128, 128),
        (256, 512, 128, 128, 128, 256),
        (512, 256, 384, 256, 128, 128),
    ])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_matches_ref(self, m, k, n, bm, bn, bk, dtype):
        rng = np.random.default_rng(m * n)
        a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32)).astype(dtype)
        b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32)).astype(dtype)
        out = matmul_fwd_pallas(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
        ref = matmul_ref(a, b)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol * k
        )

    def test_traffic_reduction_law(self):
        # §3.3 at tile granularity: bigger tiles -> less HBM traffic.
        from repro.kernels.matmul_fwd.ops import tile_traffic

        small = tile_traffic(1024, 1024, 1024, 128, 128, 128)
        big = tile_traffic(1024, 1024, 1024, 512, 512, 128)
        assert big.dram_bytes < small.dram_bytes
        naive_bytes = 2 * 1024**3 * 2
        assert small.dram_bytes < naive_bytes / 20
