"""flash attention kernel vs jnp oracle: causal/full/window x GQA sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.local_attention.kernel import flash_attention_pallas
from repro.launch.roofline import cost_analysis_dict
from repro.kernels.local_attention.ref import attention_ref

jax.config.update("jax_platform_name", "cpu")


def _qkv(b, hq, hkv, t, s, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, hq, t, d)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32)).astype(dtype)
    return q, k, v


def _check(out, ref, dtype):
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("t", [128, 256, 384])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_causal_full(t, dtype):
    q, k, v = _qkv(1, 2, 2, t, t, 128, dtype, seed=t)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    _check(out, ref, dtype)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_gqa_groups(hq, hkv):
    q, k, v = _qkv(2, hq, hkv, 256, 256, 128, np.float32, seed=hq * 10 + hkv)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    _check(out, ref, np.float32)


@pytest.mark.parametrize("window", [128, 256, 512])
def test_sliding_window(window):
    t = 768
    q, k, v = _qkv(1, 2, 1, t, t, 128, np.float32, seed=window)
    out = flash_attention_pallas(q, k, v, causal=True, window=window, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    _check(out, ref, np.float32)


def test_window_larger_than_seq_equals_causal():
    q, k, v = _qkv(1, 2, 2, 256, 256, 128, np.float32, seed=7)
    out = flash_attention_pallas(q, k, v, causal=True, window=4096, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    _check(out, ref, np.float32)


def test_non_causal_full_cross_attention():
    # Encoder / cross-attention: t != s, no mask.
    q, k, v = _qkv(2, 4, 4, 128, 384, 64, np.float32, seed=11)
    out = flash_attention_pallas(q, k, v, causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    _check(out, ref, np.float32)


def test_unpadded_lengths():
    # T, S not multiples of the block size -> padding + masking path.
    q, k, v = _qkv(1, 2, 2, 200, 200, 64, np.float32, seed=13)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    _check(out, ref, np.float32)


def test_decode_alignment():
    # Decode: 1 query against a long KV cache; diagonal at the cache end.
    q, k, v = _qkv(2, 4, 2, 1, 512, 64, np.float32, seed=17)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    _check(out, ref, np.float32)


def test_windowed_decode():
    q, k, v = _qkv(1, 2, 1, 1, 1024, 64, np.float32, seed=19)
    out = flash_attention_pallas(q, k, v, causal=True, window=256, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=256)
    _check(out, ref, np.float32)


def test_window_traffic_scales_with_window_not_seq():
    # Structural property: the kv-step count (grid dim 2) is O(window), not
    # O(T) — the transmission-window guarantee.
    from repro.kernels.local_attention import kernel as kmod

    t = 4096
    for window, expected in [(256, (256 + 128) // 128 + 2), (512, (512 + 128) // 128 + 2)]:
        n_kv_blocks = t // 128
        n_steps = min(n_kv_blocks, (window + 128) // 128 + 2)
        assert n_steps == expected
        assert n_steps < n_kv_blocks


class TestBlockwise:
    """attention_blockwise (dry-run lowering path) vs exact reference."""

    @pytest.mark.parametrize("t,window", [(300, None), (513, None), (700, 256), (1024, 128)])
    def test_matches_ref(self, t, window):
        from repro.kernels.local_attention.ref import attention_blockwise

        q, k, v = _qkv(1, 4, 2, t, t, 64, np.float32, seed=t)
        out = attention_blockwise(q, k, v, causal=True, window=window, block=128)
        ref = attention_ref(q, k, v, causal=True, window=window)
        _check(out, ref, np.float32)

    def test_non_causal(self):
        from repro.kernels.local_attention.ref import attention_blockwise

        q, k, v = _qkv(2, 2, 2, 200, 300, 64, np.float32, seed=5)
        out = attention_blockwise(q, k, v, causal=False, block=128)
        ref = attention_ref(q, k, v, causal=False)
        _check(out, ref, np.float32)

    def test_decode_against_cache(self):
        from repro.kernels.local_attention.ref import attention_blockwise

        q, k, v = _qkv(1, 4, 4, 1, 777, 64, np.float32, seed=9)
        out = attention_blockwise(q, k, v, causal=True, block=256)
        ref = attention_ref(q, k, v, causal=True)
        _check(out, ref, np.float32)

    def test_windowed_flops_scale_with_window(self):
        # The banded sweep must not visit all kv blocks.  Measured in
        # unrolled-cost mode (rolled scans hide trip counts from
        # cost_analysis) with fresh closures (jit caches by fn identity).
        from repro.kernels.local_attention.ref import attention_blockwise
        from repro.core.lowering import unrolled_cost_mode
        import jax

        def make(t, window):
            q, k, v = _qkv(1, 1, 1, t, t, 64, np.float32, seed=1)

            def f(a, b, c):
                return attention_blockwise(
                    a, b, c, causal=True, window=window, block=256
                )

            with unrolled_cost_mode():
                compiled = jax.jit(f).lower(q, k, v).compile()
                return cost_analysis_dict(compiled)["flops"]

        f_small = make(4096, 256)
        f_big = make(4096, 2048)
        assert f_big > 2.5 * f_small  # grows with window
