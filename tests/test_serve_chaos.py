"""Fault-isolation drills for the serve engine: injected NaN / dropped
dispatch / hang / request drop / preemption, each asserting the blast
radius is one slot — every unaffected request's stream bit-identical to a
fault-free run — plus snapshot/restore resume parity, request-lifecycle
outcomes (deadline / shed), and unit tests for the watchdog generation
fence and straggler warmup."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.ft.watchdog import StepTimeout, StepWatchdog, StragglerDetector
from repro.model import model as M
from repro.serve.chaos import ChaosInjector, EnginePreempted
from repro.serve.engine import Request, ServeEngine

jax.config.update("jax_platform_name", "cpu")

ARCHS = ["rwkv6-1.6b", "gemma3-1b", "recurrentgemma-2b"]
SPEC = [(5, 9), (12, 3), (7, 14), (3, 6), (9, 11)]


def _setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    return cfg, params, np.random.default_rng(seed)


def _requests(rng, cfg, spec=SPEC):
    return [
        Request(
            tokens=rng.integers(0, cfg.vocab_size, (pl,)).astype(np.int32),
            max_new_tokens=nn,
        )
        for pl, nn in spec
    ]


def _assert_streams_equal(base, outs):
    for i, (b, o) in enumerate(zip(base, outs)):
        np.testing.assert_array_equal(
            np.asarray(b), np.asarray(o),
            err_msg=f"request {i} diverged from fault-free run")


class TestQuarantineRecovery:
    """NaN-in-state: quarantined in-window, recovered by re-prefill, and
    — the acceptance bar — every request's greedy stream (including the
    victim's) bit-identical to the fault-free run."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_nan_poison_recovers_bit_identical(self, arch):
        cfg, params, rng = _setup(arch)
        reqs = _requests(rng, cfg)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        base = eng.serve(reqs, slots=3, seed=0)
        assert all(r.outcome in ("ok", "eos") for r in base)

        chaos = ChaosInjector(seed=1, nan_at=(2,))
        outs = eng.serve(reqs, slots=3, seed=0, chaos=chaos)
        assert chaos.counters["nan"] == 1
        stats = eng.last_serve_stats
        assert stats["quarantines"] == 1 and stats["recoveries"] == 1
        victims = [r for r in outs if r.recoveries > 0]
        assert len(victims) == 1 and victims[0].outcome == "recovered"
        _assert_streams_equal(base, outs)

    def test_two_faults_same_request_allowed(self):
        cfg, params, rng = _setup(ARCHS[0])
        reqs = _requests(rng, cfg)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        base = eng.serve(reqs, slots=3, seed=0)
        chaos = ChaosInjector(seed=3, nan_at=(1, 3))
        outs = eng.serve(reqs, slots=3, seed=0, chaos=chaos)
        assert eng.last_serve_stats["quarantines"] == 2
        assert sum(r.recoveries for r in outs) == 2
        _assert_streams_equal(base, outs)


class TestDispatchFaults:
    """Dropped and hung dispatches: retried (hang via the watchdog's
    cooperative-cancel fence), with zero effect on any token stream —
    injection fires before the jit consumes its donated buffers."""

    def test_drop_and_hang_retry_bit_identical(self):
        cfg, params, rng = _setup(ARCHS[0])
        reqs = _requests(rng, cfg)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        base = eng.serve(reqs, slots=3, seed=0)

        chaos = ChaosInjector(seed=1, hang_at=(1,), drop_at=(3,),
                              hang_poll_s=0.001)
        outs = eng.serve(reqs, slots=3, seed=0, chaos=chaos,
                         watchdog_timeout_s=0.3)
        stats = eng.last_serve_stats
        assert stats["watchdog_timeouts"] == 1
        assert stats["dispatch_drops"] == 1
        assert stats["dispatch_retries"] == 2
        assert all(r.outcome in ("ok", "eos") for r in outs)
        _assert_streams_equal(base, outs)

    def test_retry_budget_exhaustion_raises(self):
        cfg, params, rng = _setup(ARCHS[0])
        reqs = _requests(rng, cfg, [(5, 4)])
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        chaos = ChaosInjector(seed=1, drop_rate=1.0)
        with pytest.raises(RuntimeError, match="after .* retries"):
            eng.serve(reqs, slots=1, seed=0, chaos=chaos,
                      max_dispatch_retries=2, retry_backoff_s=0.001)
        assert eng.last_serve_stats["dispatch_retries"] == 3


class TestSnapshotRestore:
    """Preempt mid-serve, restore from the snapshot, finish with token
    streams bit-identical to the uninterrupted run — the fold_in(req_id,
    token_idx) key scheme means no RNG state needs to survive."""

    def test_preempt_restore_bit_identical(self, tmp_path):
        cfg, params, rng = _setup(ARCHS[0])
        reqs = _requests(rng, cfg)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        base = eng.serve(reqs, slots=3, seed=0, temperature=0.8, top_k=5)

        chaos = ChaosInjector(seed=1, preempt_after=2)
        with pytest.raises(EnginePreempted):
            eng.serve(reqs, slots=3, seed=0, temperature=0.8, top_k=5,
                      snapshot_every=1, snapshot_dir=str(tmp_path),
                      chaos=chaos)
        interrupted = eng.last_serve_stats
        assert interrupted["snapshots"] >= 1

        outs = eng.serve(reqs, slots=3, seed=0, temperature=0.8, top_k=5,
                         restore_from=str(tmp_path))
        resumed = eng.last_serve_stats
        # The restored run continues the counters, not restarts them.
        assert resumed["decode_dispatches"] > interrupted["decode_dispatches"]
        _assert_streams_equal(base, outs)

    def test_restore_rejects_mismatched_serve(self, tmp_path):
        cfg, params, rng = _setup(ARCHS[0])
        reqs = _requests(rng, cfg)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        with pytest.raises(EnginePreempted):
            eng.serve(reqs, slots=3, seed=0, snapshot_every=1,
                      snapshot_dir=str(tmp_path),
                      chaos=ChaosInjector(preempt_after=1))
        with pytest.raises(ValueError, match="snapshot meta"):
            eng.serve(reqs, slots=3, seed=7, restore_from=str(tmp_path))


class TestRequestLifecycle:
    """Typed outcomes for the non-fault exits: deadline kills, queue
    shedding, chaos request drops — none of which may disturb neighbors."""

    def test_deadline_kills_only_the_expired_request(self):
        cfg, params, rng = _setup(ARCHS[0])
        reqs = _requests(rng, cfg)
        reqs[0] = Request(tokens=reqs[0].tokens,
                          max_new_tokens=reqs[0].max_new_tokens,
                          deadline_ms=0.0)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        outs = eng.serve(reqs, slots=3, seed=0)
        assert outs[0].outcome == "deadline"
        assert eng.last_serve_stats["deadline_hits"] == 1
        assert all(r.outcome in ("ok", "eos") for r in outs[1:])

    def test_bounded_queue_sheds_latest_arrivals(self):
        cfg, params, rng = _setup(ARCHS[0])
        reqs = _requests(rng, cfg)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        base = eng.serve(reqs[:3], slots=2, seed=0)
        outs = eng.serve(reqs, slots=2, seed=0, max_queue=1)
        # Capacity = 2 slots + 1 queued: requests 3 and 4 are shed.
        assert [r.outcome for r in outs[3:]] == ["shed", "shed"]
        assert all(len(r) == 0 for r in outs[3:])
        assert eng.last_serve_stats["shed"] == 2
        _assert_streams_equal(base, outs[:3])

    def test_chaos_request_drop_frees_slot(self):
        cfg, params, rng = _setup(ARCHS[0])
        reqs = _requests(rng, cfg)
        eng = ServeEngine(cfg, params, max_len=96, decode_window=4)
        base = eng.serve(reqs, slots=2, seed=0)
        chaos = ChaosInjector(seed=2, req_drop_at=(2,))
        outs = eng.serve(reqs, slots=2, seed=0, chaos=chaos)
        dropped = [i for i, r in enumerate(outs) if r.outcome == "dropped"]
        assert len(dropped) == 1
        assert eng.last_serve_stats["req_drops"] == 1
        for i, (b, o) in enumerate(zip(base, outs)):
            if i not in dropped:
                np.testing.assert_array_equal(np.asarray(b), np.asarray(o))


class TestWatchdogFence:
    """Satellite: a hung step's stale thread must not race the restart."""

    def test_stale_result_discarded(self):
        wd = StepWatchdog(timeout_s=0.05)
        release = threading.Event()

        def slow():
            release.wait(2.0)
            return "stale"

        with pytest.raises(StepTimeout):
            wd.run(slow)
        assert wd.timeouts == 1
        # The retried step wins; the abandoned thread's result is fenced.
        assert wd.run(lambda: "fresh") == "fresh"
        release.set()
        deadline = time.monotonic() + 2.0
        while wd.stale_discarded == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert wd.stale_discarded == 1

    def test_cancelled_flips_for_abandoned_step(self):
        wd = StepWatchdog(timeout_s=0.05)
        seen = {}

        def slow():
            fence = wd.cancelled
            deadline = time.monotonic() + 2.0
            while not fence() and time.monotonic() < deadline:
                time.sleep(0.005)
            seen["cancelled"] = fence()

        with pytest.raises(StepTimeout):
            wd.run(slow)
        deadline = time.monotonic() + 2.0
        while "cancelled" not in seen and time.monotonic() < deadline:
            time.sleep(0.01)
        assert seen.get("cancelled") is True

    def test_stale_exception_not_raised_into_restart(self):
        wd = StepWatchdog(timeout_s=0.05)

        def slow_then_boom():
            time.sleep(0.2)
            raise RuntimeError("stale boom")

        with pytest.raises(StepTimeout):
            wd.run(slow_then_boom)
        # A fresh run must not see the abandoned step's exception.
        assert wd.run(lambda: 42) == 42


class TestStragglerWarmup:
    """Satellite: the first (compile-time) observation must not seed the
    EWMA baseline."""

    def test_compile_step_skipped(self):
        det = StragglerDetector(threshold=2.0, warmup=1)
        assert det.observe(100.0) is False       # jit compile: discarded
        assert det.observe(1.0) is False         # seeds the baseline
        assert det.baseline_s == 1.0
        assert det.observe(1.1) is False
        assert det.observe(5.0) is True          # real straggler
        assert det.flagged == 1

    def test_reset_reenters_warmup(self):
        det = StragglerDetector(threshold=2.0, warmup=1)
        det.observe(100.0)
        det.observe(1.0)
        det.reset()
        assert det.baseline_s is None
        # Post-restart re-trace: the new first observation is discarded
        # instead of being compared against the dead baseline.
        assert det.observe(50.0) is False
        assert det.observe(1.0) is False
        assert det.baseline_s == 1.0


class TestChaosInjector:
    def test_pinned_faults_fire_exactly_once(self):
        chaos = ChaosInjector(seed=0, drop_at=(5,))
        # A retried dispatch keeps its index: the pin must not re-fire or
        # the retry loop would never converge.
        assert chaos._hit("drop", 5, 0.0) is True
        assert chaos._hit("drop", 5, 0.0) is False
        assert chaos._hit("drop", 6, 0.0) is False

    def test_fixed_seed_replays_schedule(self):
        a = ChaosInjector(seed=9, drop_rate=0.3)
        b = ChaosInjector(seed=9, drop_rate=0.3)
        draws_a = [a._hit("drop", i, a.drop_rate) for i in range(32)]
        draws_b = [b._hit("drop", i, b.drop_rate) for i in range(32)]
        assert draws_a == draws_b and any(draws_a)
