"""Multi-device tests for ICI-level elevator primitives.

The main pytest process must see exactly 1 CPU device (the dry-run alone may
spawn 512), so these tests re-invoke python in a subprocess with
``--xla_force_host_platform_device_count=8`` and assert inside it.
(The tier-1 lane 2 in scripts/tier1.sh additionally runs the in-process
device-gated tests with 8 fake devices.)

Two scripts: SCRIPT exercises the core primitives; SCRIPT_WKV is the
sequence-parallel WKV acceptance suite — forward and gradient parity of
``wkv_seqshard`` against the single-device fused path on 8 devices, a
jaxpr audit proving only O(Dh²) segment summaries (never token
activations) cross the ``seq`` axis — via the shared
``repro.analysis.collectives`` pass, which replaced the walker that used
to live inline here — the model-level ``prefill_seq`` dispatch and the
serve-engine long-context prefill step.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import (
        DIAG_STATE, device_shift, halo_exchange, ring_pass, seq_carry_scan,
        device_linear_scan_carry, linear_scan, pipeline_apply,
    )

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.array(jax.devices()), ("x",))

    # --- device_shift: elevator across shards -------------------------------
    x = jnp.arange(8.0)  # one element per shard
    out = shard_map(lambda v: device_shift(v, "x", 1, fill=-1.0),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)
    np.testing.assert_array_equal(out, [-1, 0, 1, 2, 3, 4, 5, 6])

    out = shard_map(lambda v: device_shift(v, "x", -2, fill=9.0),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)
    np.testing.assert_array_equal(out, [2, 3, 4, 5, 6, 7, 9, 9])

    # --- ring_pass -----------------------------------------------------------
    out = shard_map(lambda v: ring_pass(v, "x", 1),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)
    np.testing.assert_array_equal(out, [7, 0, 1, 2, 3, 4, 5, 6])

    # --- halo_exchange: local-attention K/V neighborhoods --------------------
    seq = jnp.arange(32.0)   # 4 tokens per shard
    def halo_fn(v):
        h = halo_exchange(v, "x", left=2, right=1, fill=0.0)
        return h.reshape(1, -1)  # (1, 7) per shard -> stacked over shards
    out = shard_map(halo_fn, mesh=mesh, in_specs=P("x"), out_specs=P("x", None))(seq)
    # Shard 1 holds tokens [4..7]; halo = last 2 of shard 0 + first 1 of shard 2.
    np.testing.assert_array_equal(out[1], [2, 3, 4, 5, 6, 7, 8])
    # Shard 0 has no left producer -> elevator constant 0.
    np.testing.assert_array_equal(out[0], [0, 0, 0, 1, 2, 3, 4])

    # --- device_linear_scan_carry: cross-shard recurrence carries ------------
    T, D = 32, 3
    rng = np.random.default_rng(0)
    a = rng.uniform(0.6, 1.0, (T, D)).astype(np.float32)
    b = rng.standard_normal((T, D)).astype(np.float32)

    def chunk_scan_sharded(a_loc, b_loc):
        h_loc = linear_scan(a_loc, b_loc)          # local inclusive scan
        a_seg = jnp.prod(a_loc, axis=0)
        b_seg = h_loc[-1]
        ca, cb = device_linear_scan_carry(a_seg, b_seg, "x")
        # entering state = ca * h0 + cb with h0 = 0 -> cb
        a_cum = jnp.cumprod(a_loc, axis=0)
        return h_loc + a_cum * cb[None]

    out = shard_map(chunk_scan_sharded, mesh=mesh,
                    in_specs=(P("x"), P("x")), out_specs=P("x"))(
        jnp.asarray(a), jnp.asarray(b))
    ref = np.zeros_like(b)
    prev = np.zeros(D, np.float32)
    for t in range(T):
        prev = a[t] * prev + b[t]
        ref[t] = prev
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-4, atol=3e-4)

    # --- nonzero h0 entering shard 0 (the elevator boundary constant) --------
    h0 = rng.standard_normal(D).astype(np.float32)

    def chunk_scan_h0(a_loc, b_loc):
        h_loc = linear_scan(a_loc, b_loc)
        ca, cb = device_linear_scan_carry(
            jnp.prod(a_loc, axis=0), h_loc[-1], "x")
        enter = ca * h0 + cb
        return h_loc + jnp.cumprod(a_loc, axis=0) * enter[None]

    out = shard_map(chunk_scan_h0, mesh=mesh,
                    in_specs=(P("x"), P("x")), out_specs=P("x"))(
        jnp.asarray(a), jnp.asarray(b))
    ref = np.zeros_like(b)
    prev = h0.copy()
    for t in range(T):
        prev = a[t] * prev + b[t]
        ref[t] = prev
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-4, atol=3e-4)

    # --- reverse sweeps: the device-space reverse elevator -------------------
    A = rng.uniform(0.5, 1.0, (8, D)).astype(np.float32)
    B = rng.standard_normal((8, D)).astype(np.float32)

    def rev_carry(a_, b_):
        ca, cb = device_linear_scan_carry(a_[0], b_[0], "x", reverse=True)
        return ca[None], cb[None]

    ca, cb = shard_map(rev_carry, mesh=mesh,
                       in_specs=(P("x", None), P("x", None)),
                       out_specs=(P("x", None), P("x", None)))(
        jnp.asarray(A), jnp.asarray(B))
    prev_a = np.ones(D, np.float32)
    prev_b = np.zeros(D, np.float32)
    for i in range(7, -1, -1):
        np.testing.assert_allclose(np.asarray(ca[i]), prev_a, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(cb[i]), prev_b, rtol=1e-5,
                                   atol=1e-5)
        prev_a = A[i] * prev_a
        prev_b = A[i] * prev_b + B[i]

    # --- DIAG_STATE monoid across devices: matrix state, diag decay ----------
    dh = 3
    Am = rng.uniform(0.5, 1.0, (8, dh)).astype(np.float32)
    Bm = rng.standard_normal((8, dh, dh)).astype(np.float32)
    h0m = rng.standard_normal((dh, dh)).astype(np.float32)

    def mat_carry(a_, b_):
        ca, cb = device_linear_scan_carry(a_[0], b_[0], "x",
                                          monoid=DIAG_STATE)
        return ca[None], cb[None]

    ca, cb = shard_map(mat_carry, mesh=mesh,
                       in_specs=(P("x", None), P("x", None, None)),
                       out_specs=(P("x", None), P("x", None, None)))(
        jnp.asarray(Am), jnp.asarray(Bm))
    prev = h0m.copy()
    for i in range(8):
        enter = np.asarray(ca[i])[:, None] * h0m + np.asarray(cb[i])
        np.testing.assert_allclose(enter, prev, rtol=1e-4, atol=1e-4)
        prev = Am[i][:, None] * prev + Bm[i]

    # --- seq_carry_scan: sequential chain across shards ----------------------
    vals = jnp.arange(1.0, 9.0)  # one per shard
    def chunk_fn(carry, v):
        s = carry + v.sum()
        return s, jnp.zeros_like(v) + s
    def run_seq(v):
        c, y = seq_carry_scan(chunk_fn, jnp.asarray(0.0), v, "x")
        return c.reshape(1), y  # per-shard carry, stacked over shards
    carry, ys = shard_map(
        run_seq, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P("x")))(vals)
    np.testing.assert_allclose(np.asarray(ys), np.cumsum(np.arange(1.0, 9.0)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(carry)[-1], 36.0, rtol=1e-6)

    # --- seq_carry_scan reverse: the chain runs last-shard -> first ----------
    def run_seq_rev(v):
        c, y = seq_carry_scan(chunk_fn, jnp.asarray(0.0), v, "x",
                              reverse=True)
        return c.reshape(1), y
    carry, ys = shard_map(
        run_seq_rev, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P("x")))(vals)
    want = np.cumsum(np.arange(1.0, 9.0)[::-1])[::-1]
    np.testing.assert_allclose(np.asarray(ys), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(carry)[0], 36.0, rtol=1e-6)

    # --- pipeline_apply: 8-stage pipeline == composed function ---------------
    n_micro, mb, d = 5, 2, 4
    xs = jnp.asarray(rng.standard_normal((n_micro, mb, d)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((8, d, d)).astype(np.float32) * 0.3)

    def stage_fn(wi, x):
        return jnp.tanh(x @ wi)

    def run(w_all, x_all):
        out = pipeline_apply(stage_fn, w_all[0], x_all, "x")
        # Result is valid on the last stage; broadcast it.
        last = jax.lax.axis_index("x") == 7
        return jax.lax.psum(jnp.where(last, out, 0.0), "x")

    out = shard_map(run, mesh=mesh, in_specs=(P("x"), P()), out_specs=P())(w, xs)
    ref = np.asarray(xs)
    for i in range(8):
        ref = np.tanh(ref @ np.asarray(w[i]))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)

    print("MULTIDEVICE_OK")
    """
)


SCRIPT_WKV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import types
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.kernels.wkv.ops import wkv_fused
    from repro.kernels.wkv.seqpar import wkv_seqshard

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.array(jax.devices()), ("seq",))

    b, h, t, dh = 2, 2, 128, 8
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.85, 0.999, (b, h, t, dh)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((h, dh)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((b, h, dh, dh)).astype(np.float32))

    def shard(*args):
        return wkv_seqshard(*args, mesh=mesh, seq_axis="seq", chunk=8,
                            use_kernel=False)
    def single(*args):
        return wkv_fused(*args, chunk=8, use_kernel=False)

    # --- forward parity on 8 devices, nonzero h0 -----------------------------
    out1, s1 = single(r, k, v, w, u, h0)
    out2, s2 = shard(r, k, v, w, u, h0)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out1),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1),
                               rtol=3e-4, atol=3e-4)

    # --- gradient parity: the custom VJP composes with the device sweep ------
    co = jnp.asarray(rng.standard_normal((b, h, t, dh)).astype(np.float32))
    cs = jnp.asarray(rng.standard_normal((b, h, dh, dh)).astype(np.float32))

    def loss(fn):
        def f(*args):
            o, s = fn(*args)
            return (o * co).sum() + (s * cs).sum()
        return f

    g1 = jax.grad(loss(single), argnums=tuple(range(6)))(r, k, v, w, u, h0)
    g2 = jax.grad(loss(shard), argnums=tuple(range(6)))(r, k, v, w, u, h0)
    for name, a_, b_ in zip("r k v w u h0".split(), g1, g2):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a_),
                                   rtol=3e-3, atol=3e-3, err_msg=name)

    # --- jaxpr audit: only segment summaries cross the seq axis --------------
    # Every collective over the mesh (ppermute hops of the carry, the final
    # masked psum) must move O(Dh^2) summaries; a token-sized operand
    # (B, H, T/n, Dh) would mean the protocol regressed to a gather.  The
    # walker that used to live inline here is now the shared static-audit
    # pass (repro.analysis.collectives) — same budget, same gather ban.
    from repro.analysis.collectives import audit_collectives, has_reverse_hops
    from repro.analysis.findings import errors, format_table

    summary_size = b * h * dh * dh          # the (Dh, Dh) state summary

    fwd_jaxpr = jax.make_jaxpr(shard)(r, k, v, w, u, h0)
    bwd_jaxpr = jax.make_jaxpr(
        jax.grad(loss(shard), argnums=tuple(range(6))))(r, k, v, w, u, h0)
    for what, closed in (("forward", fwd_jaxpr), ("backward", bwd_jaxpr)):
        bad = errors(audit_collectives(
            closed, axis="seq", max_elements=summary_size, what=what))
        assert not bad, format_table(bad)
    # The transposed carry is the device-space *reverse* elevator: the
    # backward must contain ppermute hops running high->low shard index.
    assert has_reverse_hops(bwd_jaxpr, "seq"), (
        "backward jaxpr has no reverse-direction ppermute hops")

    # --- model level: apply_rwkv_block under prefill_seq rules ---------------
    from repro.model import recurrent as rec
    from repro.model.sharding import make_rules, sharding_context

    mesh2 = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    rules = make_rules(mesh2, "prefill_seq")
    assert rules["seq"] == "model", rules

    d = 128
    mk = lambda shape, scale=0.1: jnp.asarray(
        rng.standard_normal(shape).astype(np.float32) * scale)
    params = {
        "mu": mk((5, d)),
        "w_r": mk((d, d)), "w_k": mk((d, d)),
        "w_v": mk((d, d)), "w_g": mk((d, d)),
        "w_decay_base": mk((d,)),
        "w_decay_lora_a": mk((d, 64)),
        "w_decay_lora_b": mk((64, d)),
        "u_bonus": mk((d,)),
        "w_o": mk((d, d)),
        "out_norm": {"scale": jnp.ones((d,), jnp.float32)},
    }
    cfg = types.SimpleNamespace(fsdp_gather_weights=False, norm_eps=1e-6)
    x = mk((2, 64, d), scale=1.0)

    out_plain, _ = rec.apply_rwkv_block(params, x, cfg, chunk=16)
    with mesh2, sharding_context(mesh2, rules):
        out_seq, _ = rec.apply_rwkv_block(params, x, cfg, chunk=16)
    np.testing.assert_allclose(np.asarray(out_seq), np.asarray(out_plain),
                               rtol=3e-4, atol=3e-4)

    def block_loss(p, x_, seq):
        if seq:
            with mesh2, sharding_context(mesh2, rules):
                out, _ = rec.apply_rwkv_block(p, x_, cfg, chunk=16)
        else:
            out, _ = rec.apply_rwkv_block(p, x_, cfg, chunk=16)
        return (out * out).sum()

    gp = jax.grad(block_loss)(params, x, False)
    gs = jax.grad(block_loss)(params, x, True)
    err = jax.tree.map(
        lambda a_, b_: float(np.max(np.abs(np.asarray(a_) - np.asarray(b_)))),
        gp, gs)
    worst = max(jax.tree.leaves(err))
    assert worst < 5e-3, err

    # --- serve engine: long-context prefill takes the seq-parallel rules -----
    from repro.configs.registry import get_config
    from repro.model import model as M
    from repro.serve.engine import make_prefill_step, make_seq_prefill_step

    cfg_m = get_config("rwkv6-1.6b").reduced()
    params_m = M.init_params(cfg_m, jax.random.key(0))
    tokens = jnp.asarray(
        rng.integers(0, cfg_m.vocab_size, (2, 64)), jnp.int32)
    plain = make_prefill_step(cfg_m)(params_m, tokens)
    seqp = make_seq_prefill_step(cfg_m, mesh2, min_len=32)(params_m, tokens)
    np.testing.assert_allclose(np.asarray(seqp), np.asarray(plain),
                               rtol=2e-3, atol=2e-3)
    # Short prompts stay on the plain rules (no seq sharding below min_len).
    short = jnp.asarray(rng.integers(0, cfg_m.vocab_size, (2, 16)), jnp.int32)
    seqp_short = make_seq_prefill_step(cfg_m, mesh2, min_len=32)(
        params_m, short)
    plain_short = make_prefill_step(cfg_m)(params_m, short)
    np.testing.assert_allclose(np.asarray(seqp_short),
                               np.asarray(plain_short), rtol=2e-3, atol=2e-3)

    print("MULTIDEVICE_WKV_OK")
    """
)


def _run(script: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root",
             # The script forces host-platform devices; skip TPU probing
             # (30-retry metadata fetches) in containers with libtpu baked in.
             "JAX_PLATFORMS": "cpu"},
        timeout=600,
    )


def test_multidevice_primitives():
    res = _run(SCRIPT)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "MULTIDEVICE_OK" in res.stdout


def test_multidevice_wkv_seqshard():
    res = _run(SCRIPT_WKV)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "MULTIDEVICE_WKV_OK" in res.stdout
