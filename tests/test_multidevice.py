"""Multi-device tests for ICI-level elevator primitives.

The main pytest process must see exactly 1 CPU device (the dry-run alone may
spawn 512), so these tests re-invoke python in a subprocess with
``--xla_force_host_platform_device_count=8`` and assert inside it.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import (
        device_shift, halo_exchange, ring_pass, seq_carry_scan,
        device_linear_scan_carry, linear_scan, pipeline_apply,
    )

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.array(jax.devices()), ("x",))

    # --- device_shift: elevator across shards -------------------------------
    x = jnp.arange(8.0)  # one element per shard
    out = shard_map(lambda v: device_shift(v, "x", 1, fill=-1.0),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)
    np.testing.assert_array_equal(out, [-1, 0, 1, 2, 3, 4, 5, 6])

    out = shard_map(lambda v: device_shift(v, "x", -2, fill=9.0),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)
    np.testing.assert_array_equal(out, [2, 3, 4, 5, 6, 7, 9, 9])

    # --- ring_pass -----------------------------------------------------------
    out = shard_map(lambda v: ring_pass(v, "x", 1),
                    mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x)
    np.testing.assert_array_equal(out, [7, 0, 1, 2, 3, 4, 5, 6])

    # --- halo_exchange: local-attention K/V neighborhoods --------------------
    seq = jnp.arange(32.0)   # 4 tokens per shard
    def halo_fn(v):
        h = halo_exchange(v, "x", left=2, right=1, fill=0.0)
        return h.reshape(1, -1)  # (1, 7) per shard -> stacked over shards
    out = shard_map(halo_fn, mesh=mesh, in_specs=P("x"), out_specs=P("x", None))(seq)
    # Shard 1 holds tokens [4..7]; halo = last 2 of shard 0 + first 1 of shard 2.
    np.testing.assert_array_equal(out[1], [2, 3, 4, 5, 6, 7, 8])
    # Shard 0 has no left producer -> elevator constant 0.
    np.testing.assert_array_equal(out[0], [0, 0, 0, 1, 2, 3, 4])

    # --- device_linear_scan_carry: cross-shard recurrence carries ------------
    T, D = 32, 3
    rng = np.random.default_rng(0)
    a = rng.uniform(0.6, 1.0, (T, D)).astype(np.float32)
    b = rng.standard_normal((T, D)).astype(np.float32)

    def chunk_scan_sharded(a_loc, b_loc):
        h_loc = linear_scan(a_loc, b_loc)          # local inclusive scan
        a_seg = jnp.prod(a_loc, axis=0)
        b_seg = h_loc[-1]
        ca, cb = device_linear_scan_carry(a_seg, b_seg, "x")
        # entering state = ca * h0 + cb with h0 = 0 -> cb
        a_cum = jnp.cumprod(a_loc, axis=0)
        return h_loc + a_cum * cb[None]

    out = shard_map(chunk_scan_sharded, mesh=mesh,
                    in_specs=(P("x"), P("x")), out_specs=P("x"))(
        jnp.asarray(a), jnp.asarray(b))
    ref = np.zeros_like(b)
    prev = np.zeros(D, np.float32)
    for t in range(T):
        prev = a[t] * prev + b[t]
        ref[t] = prev
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-4, atol=3e-4)

    # --- seq_carry_scan: sequential chain across shards ----------------------
    vals = jnp.arange(1.0, 9.0)  # one per shard
    def chunk_fn(carry, v):
        s = carry + v.sum()
        return s, jnp.zeros_like(v) + s
    def run_seq(v):
        c, y = seq_carry_scan(chunk_fn, jnp.asarray(0.0), v, "x")
        return c.reshape(1), y  # per-shard carry, stacked over shards
    carry, ys = shard_map(
        run_seq, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P("x")))(vals)
    np.testing.assert_allclose(np.asarray(ys), np.cumsum(np.arange(1.0, 9.0)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(carry)[-1], 36.0, rtol=1e-6)

    # --- pipeline_apply: 8-stage pipeline == composed function ---------------
    n_micro, mb, d = 5, 2, 4
    xs = jnp.asarray(rng.standard_normal((n_micro, mb, d)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((8, d, d)).astype(np.float32) * 0.3)

    def stage_fn(wi, x):
        return jnp.tanh(x @ wi)

    def run(w_all, x_all):
        out = pipeline_apply(stage_fn, w_all[0], x_all, "x")
        # Result is valid on the last stage; broadcast it.
        last = jax.lax.axis_index("x") == 7
        return jax.lax.psum(jnp.where(last, out, 0.0), "x")

    out = shard_map(run, mesh=mesh, in_specs=(P("x"), P()), out_specs=P())(w, xs)
    ref = np.asarray(xs)
    for i in range(8):
        ref = np.tanh(ref @ np.asarray(w[i]))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)

    print("MULTIDEVICE_OK")
    """
)


def test_multidevice_primitives():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root",
             # The script forces host-platform devices; skip TPU probing
             # (30-retry metadata fetches) in containers with libtpu baked in.
             "JAX_PLATFORMS": "cpu"},
        timeout=600,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "MULTIDEVICE_OK" in res.stdout
