"""elevator_scan Pallas kernel vs pure-jnp oracle: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.elevator_scan.kernel import elevator_scan_pallas
from repro.kernels.elevator_scan.ops import elevator_scan
from repro.kernels.elevator_scan.ref import elevator_scan_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == jnp.bfloat16:
        return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32).astype(dtype)
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


SHAPES = [
    (1, 8, 128),
    (2, 64, 128),
    (1, 256, 256),
    (3, 128, 384),
    (2, 512, 128),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_kernel_matches_ref(shape, dtype):
    b, t, d = shape
    seed = hash((shape, str(dtype))) % 2**31
    rng = np.random.default_rng(seed)
    # Decay in (0.5, 1] — the RG-LRU/RWKV regime.
    a = jnp.asarray(rng.uniform(0.5, 1.0, shape).astype(np.float32)).astype(dtype)
    x = _rand(shape, dtype, seed + 1)
    chunk = min(t, 64)
    out = elevator_scan_pallas(a, x, chunk=chunk, interpret=True)
    ref = elevator_scan_ref(a, x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_h0_carry_boundary():
    b, t, d = 2, 64, 128
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.8, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    out = elevator_scan_pallas(a, x, h0, chunk=16, interpret=True)
    ref = elevator_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_chunk_invariance():
    # The VMEM carry must make chunking invisible (cascade correctness).
    b, t, d = 1, 256, 128
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(0.6, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    outs = [
        np.asarray(elevator_scan_pallas(a, x, chunk=c, interpret=True))
        for c in (8, 32, 128, 256)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-5, atol=2e-5)


def test_prefix_sum_special_case():
    # Paper Fig. 6: a == 1 -> prefix sum.
    b, t, d = 1, 128, 128
    x = jnp.ones((b, t, d), jnp.float32)
    out = elevator_scan_pallas(jnp.ones_like(x), x, chunk=32, interpret=True)
    expected = np.broadcast_to(np.arange(1.0, t + 1)[None, :, None], (b, t, d))
    np.testing.assert_allclose(np.asarray(out), expected, rtol=1e-6)


def test_ops_dispatch_matches():
    b, t, d = 2, 128, 128
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    jnp_path = elevator_scan(a, x, h0, use_kernel=False)
    kernel_path = elevator_scan(a, x, h0, use_kernel=True)
    ref = elevator_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(jnp_path), np.asarray(ref), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(kernel_path), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_jnp_forms_agree():
    # The per-backend jnp forms (linear scan on CPU, log-depth associative
    # scan elsewhere) are the same math; h0 handling must match too.
    from repro.kernels.elevator_scan.ops import (
        elevator_scan_linear,
        elevator_scan_logdepth,
    )

    b, t, d = 2, 160, 96  # non-power-of-two T: no chunk structure assumed
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    for h0 in (None, jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))):
        lin = elevator_scan_linear(a, x, h0)
        log = elevator_scan_logdepth(a, x, h0)
        ref = elevator_scan_ref(a, x, h0)
        np.testing.assert_allclose(np.asarray(lin), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(np.asarray(log), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


def test_jnp_dispatch_differentiable():
    # The CPU linear path must stay differentiable (RG-LRU trains on it).
    b, t, d = 1, 64, 32
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))

    def loss(fn):
        return lambda a_, x_: (fn(a_, x_) ** 2).sum()

    ga, gx = jax.grad(loss(lambda a_, x_: elevator_scan(a_, x_, use_kernel=False)),
                      argnums=(0, 1))(a, x)
    ra, rx = jax.grad(loss(elevator_scan_ref), argnums=(0, 1))(a, x)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-4, atol=1e-4)


def test_rejects_bad_chunk():
    a = jnp.ones((1, 96, 128))
    with pytest.raises(ValueError):
        elevator_scan_pallas(a, a, chunk=64, interpret=True)


# ==========================================================================
# Decode micro-kernel: persistent h across a K-token window (ROADMAP (d))
# ==========================================================================

class TestElevatorDecode:
    """kernels/elevator_scan/decode: the RG-LRU analogue of wkv/decode."""

    def _inputs(self, b, t, d, seed=0):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, d)).astype(np.float32))
        x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
        h0 = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
        return a, x, h0

    @pytest.mark.parametrize("t", [1, 5, 37])
    def test_window_kernel_matches_ref(self, t):
        from repro.kernels.elevator_scan.decode import (
            elevator_decode_window_pallas,
        )

        a, x, h0 = self._inputs(2, t, 128, seed=t)
        got = elevator_decode_window_pallas(a, x, h0, interpret=True)
        want = elevator_scan_ref(a, x, h0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_window_carry_across_windows(self):
        # 16 + 16 + 5 chained windows == one 37-token sweep.
        from repro.kernels.elevator_scan.decode import (
            elevator_decode_window_pallas,
        )

        a, x, h0 = self._inputs(2, 37, 128, seed=7)
        want = elevator_scan_ref(a, x, h0)
        outs, h = [], h0
        for lo, hi in ((0, 16), (16, 32), (32, 37)):
            o = elevator_decode_window_pallas(
                a[:, lo:hi], x[:, lo:hi], h, interpret=True)
            outs.append(o)
            h = o[:, -1]
        got = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_grads_match_autodiff_of_ref(self):
        from repro.kernels.elevator_scan.decode import elevator_decode_diff

        a, x, h0 = self._inputs(2, 9, 128, seed=11)

        def loss_k(a_, x_, h_):
            return (elevator_decode_diff(True, True, a_, x_, h_) ** 2).sum()

        def loss_r(a_, x_, h_):
            return (elevator_scan_ref(a_, x_, h_) ** 2).sum()

        gk = jax.grad(loss_k, argnums=(0, 1, 2))(a, x, h0)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(a, x, h0)
        for u, v in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-4, atol=1e-4)

    def test_dispatch_routes_decode_to_window_kernel(self, monkeypatch):
        # decode=True windows <= the threshold must take the decode kernel
        # (not the chunked kernel, not jnp); longer sweeps fall through.
        from repro.kernels.elevator_scan import decode as dec_mod
        from repro.kernels.elevator_scan import ops as es_ops

        monkeypatch.setattr(es_ops, "on_tpu", lambda: True)
        monkeypatch.setattr(es_ops, "interpret_default", lambda: True)
        calls = []
        real = dec_mod.elevator_decode_window_pallas
        monkeypatch.setattr(
            es_ops, "elevator_decode_diff",
            lambda i, p, a, x, h: calls.append("decode")
            or real(a, x, h, interpret=True))
        real_chunk = es_ops.elevator_scan_pallas
        monkeypatch.setattr(
            es_ops, "elevator_scan_pallas",
            lambda *a_, **kw: calls.append("chunked")
            or real_chunk(*a_, **kw))

        a, x, h0 = self._inputs(1, 1, 128, seed=3)
        got = elevator_scan(a, x, h0, decode=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(elevator_scan_ref(a, x, h0)),
                                   rtol=1e-6, atol=1e-6)
        assert calls == ["decode"], calls

        # t == 1 infers decode (the old forced-jnp path, now kernelized).
        calls.clear()
        elevator_scan(a, x, h0)
        assert calls == ["decode"], calls

        # A long stateful sweep (cache prefill) takes the chunked kernel.
        calls.clear()
        a2, x2, h2 = self._inputs(1, 256, 128, seed=4)
        elevator_scan(a2, x2, h2, decode=True)
        assert calls == ["chunked"], calls

    def test_apply_rglru_block_stateful_reaches_decode_kernel(self, monkeypatch):
        # End-to-end: the model block's stateful (serving) call must
        # dispatch the persistent-state decode path under TPU rules —
        # the old code pinned t==1 to the unfused jnp path.
        from repro.configs.registry import get_config
        from repro.kernels.elevator_scan import decode as dec_mod
        from repro.kernels.elevator_scan import ops as es_ops
        from repro.model import model as M
        from repro.model import recurrent as rec

        monkeypatch.setattr(es_ops, "on_tpu", lambda: True)
        monkeypatch.setattr(es_ops, "interpret_default", lambda: True)
        calls = []
        real = dec_mod.elevator_decode_window_pallas
        monkeypatch.setattr(
            es_ops, "elevator_decode_diff",
            lambda i, p, a, x, h: calls.append("decode")
            or real(a, x, h, interpret=True))

        cfg = get_config("recurrentgemma-2b").reduced()
        params = M.init_params(cfg, jax.random.key(0))
        state = M.init_decode_state(cfg, batch=1, max_len=32)
        tok = jnp.zeros((1, 1), jnp.int32)
        logits, _ = M.decode_step(params, cfg, state, tok, jnp.int32(0))
        assert calls and all(c == "decode" for c in calls), calls
        assert bool(jnp.isfinite(logits).all())
