"""elevator_scan Pallas kernel vs pure-jnp oracle: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.elevator_scan.kernel import elevator_scan_pallas
from repro.kernels.elevator_scan.ops import elevator_scan
from repro.kernels.elevator_scan.ref import elevator_scan_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == jnp.bfloat16:
        return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32).astype(dtype)
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


SHAPES = [
    (1, 8, 128),
    (2, 64, 128),
    (1, 256, 256),
    (3, 128, 384),
    (2, 512, 128),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_kernel_matches_ref(shape, dtype):
    b, t, d = shape
    seed = hash((shape, str(dtype))) % 2**31
    rng = np.random.default_rng(seed)
    # Decay in (0.5, 1] — the RG-LRU/RWKV regime.
    a = jnp.asarray(rng.uniform(0.5, 1.0, shape).astype(np.float32)).astype(dtype)
    x = _rand(shape, dtype, seed + 1)
    chunk = min(t, 64)
    out = elevator_scan_pallas(a, x, chunk=chunk, interpret=True)
    ref = elevator_scan_ref(a, x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_h0_carry_boundary():
    b, t, d = 2, 64, 128
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.8, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    out = elevator_scan_pallas(a, x, h0, chunk=16, interpret=True)
    ref = elevator_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_chunk_invariance():
    # The VMEM carry must make chunking invisible (cascade correctness).
    b, t, d = 1, 256, 128
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(0.6, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    outs = [
        np.asarray(elevator_scan_pallas(a, x, chunk=c, interpret=True))
        for c in (8, 32, 128, 256)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-5, atol=2e-5)


def test_prefix_sum_special_case():
    # Paper Fig. 6: a == 1 -> prefix sum.
    b, t, d = 1, 128, 128
    x = jnp.ones((b, t, d), jnp.float32)
    out = elevator_scan_pallas(jnp.ones_like(x), x, chunk=32, interpret=True)
    expected = np.broadcast_to(np.arange(1.0, t + 1)[None, :, None], (b, t, d))
    np.testing.assert_allclose(np.asarray(out), expected, rtol=1e-6)


def test_ops_dispatch_matches():
    b, t, d = 2, 128, 128
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    jnp_path = elevator_scan(a, x, h0, use_kernel=False)
    kernel_path = elevator_scan(a, x, h0, use_kernel=True)
    ref = elevator_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(jnp_path), np.asarray(ref), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(kernel_path), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_jnp_forms_agree():
    # The per-backend jnp forms (linear scan on CPU, log-depth associative
    # scan elsewhere) are the same math; h0 handling must match too.
    from repro.kernels.elevator_scan.ops import (
        elevator_scan_linear,
        elevator_scan_logdepth,
    )

    b, t, d = 2, 160, 96  # non-power-of-two T: no chunk structure assumed
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    for h0 in (None, jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))):
        lin = elevator_scan_linear(a, x, h0)
        log = elevator_scan_logdepth(a, x, h0)
        ref = elevator_scan_ref(a, x, h0)
        np.testing.assert_allclose(np.asarray(lin), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(np.asarray(log), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


def test_jnp_dispatch_differentiable():
    # The CPU linear path must stay differentiable (RG-LRU trains on it).
    b, t, d = 1, 64, 32
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))

    def loss(fn):
        return lambda a_, x_: (fn(a_, x_) ** 2).sum()

    ga, gx = jax.grad(loss(lambda a_, x_: elevator_scan(a_, x_, use_kernel=False)),
                      argnums=(0, 1))(a, x)
    ra, rx = jax.grad(loss(elevator_scan_ref), argnums=(0, 1))(a, x)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-4, atol=1e-4)


def test_rejects_bad_chunk():
    a = jnp.ones((1, 96, 128))
    with pytest.raises(ValueError):
        elevator_scan_pallas(a, a, chunk=64, interpret=True)
