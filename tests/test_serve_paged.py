"""Paged-KV serve tests: paged-vs-dense bit-identity (greedy and
sampled), recurrent-state prefix sharing vs cold admission, page-pool
starvation / capacity shedding, snapshot+restore of the page tables
under chaos preemption, and PagedController unit invariants."""

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.model import model as M
from repro.serve import paging as P
from repro.serve.chaos import ChaosInjector, EnginePreempted
from repro.serve.engine import Request, ServeEngine

jax.config.update("jax_platform_name", "cpu")

ARCHS = ["rwkv6-1.6b", "gemma3-1b", "recurrentgemma-2b"]
SPEC = [(5, 9), (12, 3), (7, 14), (3, 6), (9, 11)]


def _setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    return cfg, params, np.random.default_rng(seed)


def _requests(rng, cfg, spec=SPEC):
    return [
        Request(
            tokens=rng.integers(0, cfg.vocab_size, (pl,)).astype(np.int32),
            max_new_tokens=nn,
        )
        for pl, nn in spec
    ]


def _engines(cfg, params, **paged_kw):
    dense = ServeEngine(cfg, params, max_len=96, decode_window=4)
    paged = ServeEngine(cfg, params, max_len=96, decode_window=4,
                        paged=True, **paged_kw)
    return dense, paged


def _assert_streams_equal(a, b):
    assert len(a) == len(b)
    for i, (x, y) in enumerate(zip(a, b)):
        assert x.outcome == y.outcome, (i, x.outcome, y.outcome)
        np.testing.assert_array_equal(x.tokens, y.tokens, err_msg=f"req {i}")


class TestPagedParity:
    """Acceptance: pooled pages + page-table gathers must be an exact
    storage-layout change — every stream bit-identical to the dense
    engine, greedy and sampled, on all three arch families."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_greedy_bit_identical_to_dense(self, arch):
        cfg, params, rng = _setup(arch)
        reqs = _requests(rng, cfg)
        dense, paged = _engines(cfg, params)
        _assert_streams_equal(dense.serve(reqs, slots=2),
                              paged.serve(reqs, slots=2))
        assert paged.last_serve_stats["admissions"] >= 2   # slots recycled
        assert paged.last_paged_stats["page_table_violations"] == 0

    @pytest.mark.parametrize("arch", ARCHS)
    def test_sampled_bit_identical_to_dense(self, arch):
        cfg, params, rng = _setup(arch)
        reqs = _requests(rng, cfg)
        dense, paged = _engines(cfg, params)
        kw = dict(slots=2, temperature=0.8, top_k=5, seed=3)
        _assert_streams_equal(dense.serve(reqs, **kw),
                              paged.serve(reqs, **kw))

    def test_quarantine_recovery_parity(self):
        """A NaN-poisoned slot quarantines and recovers on the paged
        engine exactly as on dense: the victim's resumed stream and every
        neighbor bit-identical to the fault-free run."""
        for arch in ("rwkv6-1.6b", "gemma3-1b"):   # rec- and KV-poison paths
            cfg, params, rng = _setup(arch)
            reqs = _requests(rng, cfg)
            _, paged = _engines(cfg, params)
            base = paged.serve(reqs, slots=2, seed=0)
            _, faulted = _engines(cfg, params)
            outs = faulted.serve(reqs, slots=2, seed=0,
                                 chaos=ChaosInjector(seed=1, nan_at=(1,)))
            assert faulted.last_serve_stats["quarantines"] >= 1
            assert any(r.outcome == "recovered" for r in outs)
            for b, o in zip(base, outs):
                np.testing.assert_array_equal(b.tokens, o.tokens)
            assert faulted.last_paged_stats["page_table_violations"] == 0


class TestPrefixSharing:
    """Recurrent-state prefix sharing: a registered prefix's WKV S /
    RG-LRU h and KV pages enter each admitted slot as the read-side dual
    of the reset path — streams bit-identical to cold admission."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_shared_prefix_matches_cold(self, arch):
        cfg, params, rng = _setup(arch, seed=1)
        prefix = rng.integers(0, cfg.vocab_size, (40,)).astype(np.int32)
        sfx = [rng.integers(0, cfg.vocab_size, (k,)).astype(np.int32)
               for k in (5, 9, 3, 7)]
        cold = [Request(tokens=np.concatenate([prefix, s]),
                        max_new_tokens=8) for s in sfx]
        dense, paged = _engines(cfg, params)
        pid = paged.register_prefix(prefix)
        warm = [Request(tokens=np.concatenate([prefix, s]),
                        max_new_tokens=8, prefix_id=pid) for s in sfx]
        _assert_streams_equal(dense.serve(cold, slots=2),
                              paged.serve(warm, slots=2))
        assert paged.last_serve_stats["prefix_admissions"] == len(sfx)
        assert paged.last_paged_stats["shared_pages"] >= 1

    def test_prefix_validation(self):
        cfg, params, rng = _setup("gemma3-1b")
        dense, paged = _engines(cfg, params)
        with pytest.raises(ValueError, match="paged"):
            dense.register_prefix(np.arange(40, dtype=np.int32))
        with pytest.raises(ValueError, match="page"):
            paged.register_prefix(np.arange(8, dtype=np.int32))   # < 1 page
        pid = paged.register_prefix(np.arange(40, dtype=np.int32))
        with pytest.raises(ValueError, match="extend"):
            paged.serve([Request(tokens=np.zeros(50, np.int32),
                                 max_new_tokens=4, prefix_id=pid)])
        with pytest.raises(ValueError, match="unknown prefix"):
            paged.serve([Request(tokens=np.arange(50, dtype=np.int32),
                                 max_new_tokens=4, prefix_id=99)])
        with pytest.raises(ValueError, match="paged engine"):
            dense.serve([Request(tokens=np.arange(50, dtype=np.int32),
                                 max_new_tokens=4, prefix_id=pid)])


class TestPoolPressure:
    """Tight pools: admission waits for freed pages (head-of-line, no
    starvation) and requests that can never fit are shed, not deadlocked
    — with streams still bit-identical to dense."""

    def test_starved_pool_recycles_and_stays_exact(self):
        cfg, params, rng = _setup("gemma3-1b")
        reqs = _requests(rng, cfg)
        # Worst request needs ceil((12+14)/32) = 1 page... make pages
        # scarce enough that 2 slots contend: one private page per node.
        dense, paged = _engines(cfg, params, pool_pages=1)
        _assert_streams_equal(dense.serve(reqs, slots=2),
                              paged.serve(reqs, slots=2))
        assert paged.last_serve_stats["page_waits"] >= 1

    def test_impossible_request_is_shed(self):
        cfg, params, rng = _setup("gemma3-1b")
        _, paged = _engines(cfg, params, pool_pages=1)
        big = Request(tokens=rng.integers(0, cfg.vocab_size, (40,))
                      .astype(np.int32), max_new_tokens=40)   # needs 3 pages
        small = Request(tokens=rng.integers(0, cfg.vocab_size, (5,))
                        .astype(np.int32), max_new_tokens=6)
        outs = paged.serve([big, small], slots=2)
        assert outs[0].outcome == "shed" and outs[0].size == 0
        assert outs[1].outcome in ("ok", "eos")


class TestPagedSnapshotRestore:
    """Preempt a paged serve mid-run, restore, finish bit-identically —
    page tables, pool contents, and owner bookkeeping all survive."""

    @pytest.mark.parametrize("arch", ["gemma3-1b", "rwkv6-1.6b"])
    def test_preempt_restore_bit_identical(self, arch, tmp_path):
        cfg, params, rng = _setup(arch)
        reqs = _requests(rng, cfg)
        _, paged = _engines(cfg, params)
        base = paged.serve(reqs, slots=3, seed=0, temperature=0.8, top_k=5)

        _, eng = _engines(cfg, params)
        with pytest.raises(EnginePreempted):
            eng.serve(reqs, slots=3, seed=0, temperature=0.8, top_k=5,
                      snapshot_every=1, snapshot_dir=str(tmp_path),
                      chaos=ChaosInjector(seed=1, preempt_after=2))
        assert eng.last_serve_stats["snapshots"] >= 1
        outs = eng.serve(reqs, slots=3, seed=0, temperature=0.8, top_k=5,
                         restore_from=str(tmp_path))
        _assert_streams_equal(base, outs)
        assert eng.last_paged_stats["page_table_violations"] == 0

    def test_restore_rejects_paging_mismatch(self, tmp_path):
        cfg, params, rng = _setup("gemma3-1b")
        reqs = _requests(rng, cfg)
        _, eng = _engines(cfg, params)
        with pytest.raises(EnginePreempted):
            eng.serve(reqs, slots=3, seed=0, snapshot_every=1,
                      snapshot_dir=str(tmp_path),
                      chaos=ChaosInjector(preempt_after=1))
        dense = ServeEngine(cfg, params, max_len=96, decode_window=4)
        with pytest.raises(ValueError, match="snapshot meta"):
            dense.serve(reqs, slots=3, seed=0, restore_from=str(tmp_path))


class TestPagedController:
    """Host-side allocator invariants, independent of any model."""

    def _ctl(self, private=8, shared_map=None):
        cfg = get_config("gemma3-1b").reduced()
        state = M.abstract_decode_state(
            cfg, batch=2, max_len=96, insert_window=32,
            paged=M.PageSpec(page_size=32, private_pages=private,
                             shared_pages=sum(
                                 n for _, n in (shared_map or {}).values())),
        )
        return P.PagedController(cfg, state, batch=2, max_len=96,
                                 shared_map=shared_map)

    def test_alloc_free_roundtrip_and_rollback(self):
        ctl = self._ctl(private=2)
        a = ctl.try_admit(0, 64, None, 0)          # 2 pages on 96-view nodes
        assert a is not None
        free_before = [len(f) for f in ctl.free]
        assert ctl.try_admit(1, 96, None, 0) is None   # needs 3, has 0
        assert [len(f) for f in ctl.free] == free_before   # rollback
        ctl.free_slot(0)
        assert ctl.try_admit(1, 64, None, 0) is not None
        for owner in ctl.owners:
            assert not (owner == 0).any()          # slot 0 owns nothing

    def test_table_rows_and_scrub_exclude_shared(self):
        ctl = self._ctl(private=8, shared_map={7: (1, 1)})
        tables, scrubs = ctl.try_admit(0, 96, 7, 32)
        for g, row, scrub in zip(ctl.geoms, tables, scrubs):
            assert row.shape == (g.nl,)
            mapped = row[row >= 0]
            assert len(set(mapped.tolist())) == len(mapped)   # no dup pages
            if g.role == "share":
                assert row[0] == 1 and scrub[0] == -1   # shared: not scrubbed
            assert (scrub[1:] == row[1:]).all()

    def test_peak_tracks_high_water(self):
        ctl = self._ctl(private=8)
        base = ctl.peak_mapped_bytes
        ctl.try_admit(0, 96, None, 0)
        ctl.try_admit(1, 96, None, 0)
        high = ctl.peak_mapped_bytes
        assert high > base
        ctl.free_slot(0)
        ctl.free_slot(1)
        assert ctl.peak_mapped_bytes == high       # high-water, not current
        assert ctl.mapped_bytes() < high


def test_paged_pool_cost_model():
    from repro.core.cost_model import serve_paged_pool, serve_prefix_admission

    peak, dense = serve_paged_pool([48, 200, 24], [80, 56, 16],
                                   slots=2, page_size=32)
    assert 0 < peak <= dense
    shared, cold = serve_prefix_admission(1000, 24, 8, page_size=32)
    assert shared < cold
    # The bench acceptance: a 1k-token shared prefix makes admission at
    # least 3x cheaper than re-prefilling it per request.
    assert cold / shared >= 3.0
    with pytest.raises(ValueError):
        serve_paged_pool([4], [0, 1], slots=1, page_size=32)
    with pytest.raises(ValueError):
        serve_prefix_admission(10, 0, 1, 32)
