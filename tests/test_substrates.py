"""Tests: optimizer, compression, data pipeline, checkpoint, fault tolerance,
training convergence on a tiny model, serve engine."""

import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import checkpoint as ckpt
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, batch_specs, make_batch
from repro.ft.watchdog import (
    NodeFailure,
    StepTimeout,
    StepWatchdog,
    StragglerDetector,
    run_with_restarts,
)
from repro.model import model as M
from repro.optim import adamw
from repro.optim.compression import (
    compressed_gradients,
    compression_ratio,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)
from repro.serve.engine import ServeEngine
from repro.train.step import TrainState, init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")


class TestAdamW:
    def test_quadratic_convergence(self):
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw.init_state(params)
        cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0, total_steps=200)
        loss = lambda p: jnp.sum(jnp.square(p["w"]))
        for _ in range(150):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.apply_updates(params, g, state, cfg)
        assert float(loss(params)) < 1e-2

    def test_clip_norm(self):
        params = {"w": jnp.zeros(4)}
        state = adamw.init_state(params)
        cfg = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=0)
        g = {"w": jnp.full(4, 100.0)}
        _, _, metrics = adamw.apply_updates(params, g, state, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
        assert float(adamw.schedule(cfg, jnp.int32(0))) == 0.0
        assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(adamw.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


class TestCompression:
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(10, 3000))
    @settings(max_examples=25, deadline=None)
    def test_quantize_roundtrip_error_bounded(self, seed, n):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32)) * 10
        q, scale, shape, pad = quantize_int8(x)
        deq = dequantize_int8(q, scale, shape, pad)
        # Error bounded by half a quantization bucket per element.
        bound = np.repeat(np.asarray(scale), 256)[: x.size].reshape(x.shape) * 0.5 + 1e-6
        assert np.all(np.abs(np.asarray(deq - x)) <= bound)

    def test_error_feedback_preserves_sum(self):
        # With error feedback, the *accumulated* compressed gradient tracks
        # the accumulated true gradient (residual never lost).
        rng = np.random.default_rng(0)
        g_true = [jnp.asarray(rng.standard_normal(512).astype(np.float32)) for _ in range(20)]
        ef = init_error_feedback({"w": g_true[0]})
        total_c = jnp.zeros(512)
        for g in g_true:
            gc, ef = compressed_gradients({"w": g}, ef)
            total_c = total_c + gc["w"]
        total_t = sum(g_true)
        # Outstanding residual is the only difference.
        np.testing.assert_allclose(
            np.asarray(total_c + ef.residual["w"]), np.asarray(total_t), rtol=1e-4, atol=1e-4
        )

    def test_ratio_beats_bf16(self):
        grads = {"w": jnp.zeros((1024, 1024), jnp.float32)}
        assert compression_ratio(grads) < 0.27  # ~4x vs fp32


class TestDataPipeline:
    def test_deterministic_and_step_keyed(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
        b1 = make_batch(cfg, 7)
        b2 = make_batch(cfg, 7)
        b3 = make_batch(cfg, 8)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2)
        b = make_batch(cfg, 0)
        np.testing.assert_array_equal(
            np.asarray(b["labels"])[:, :-1], np.asarray(b["tokens"])[:, 1:]
        )

    def test_specs_match_batch(self):
        cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2)
        specs = batch_specs(cfg)
        b = make_batch(cfg, 0)
        for k in specs:
            assert specs[k].shape == b[k].shape
            assert specs[k].dtype == b[k].dtype

    def test_tokens_in_vocab(self):
        cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=4)
        b = make_batch(cfg, 3)
        assert int(b["tokens"].min()) >= 0
        assert int(b["tokens"].max()) < 100


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.int32(7)}}
        ckpt.save(tmp_path, 5, tree)
        restored, step = ckpt.restore(tmp_path, tree)
        assert step == 5
        np.testing.assert_array_equal(restored["a"], tree["a"])
        assert int(restored["b"]["c"]) == 7

    def test_latest_pointer_and_multiple_steps(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        ckpt.save(tmp_path, 1, tree)
        ckpt.save(tmp_path, 2, {"a": jnp.ones(2)})
        assert ckpt.latest_step(tmp_path) == 2
        restored, step = ckpt.restore(tmp_path, tree)
        assert step == 2
        np.testing.assert_array_equal(restored["a"], np.ones(2))

    def test_async_saver(self, tmp_path):
        saver = ckpt.AsyncSaver()
        saver.save_async(tmp_path, 3, {"x": jnp.full(4, 2.0)})
        saver.wait()
        restored, _ = ckpt.restore(tmp_path, {"x": jnp.zeros(4)})
        np.testing.assert_array_equal(restored["x"], np.full(4, 2.0))

    def test_elastic_restore_new_sharding(self, tmp_path):
        # Save unsharded, restore with an explicit (trivial) NamedSharding —
        # the elastic path used when the mesh changes between jobs.
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        ckpt.save(tmp_path, 1, tree, mesh_shape=(2, 2))
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        shardings = {"w": NamedSharding(mesh, P("data", "model"))}
        restored, _ = ckpt.restore(tmp_path, tree, shardings=shardings)
        np.testing.assert_array_equal(restored["w"], tree["w"])
        assert restored["w"].sharding == shardings["w"]


class TestFaultTolerance:
    def test_watchdog_timeout(self):
        import time

        wd = StepWatchdog(timeout_s=0.2)
        with pytest.raises(StepTimeout):
            wd.run(lambda: time.sleep(2.0))

    def test_watchdog_passthrough(self):
        wd = StepWatchdog(timeout_s=5.0)
        assert wd.run(lambda: 42) == 42

    def test_straggler_detector(self):
        det = StragglerDetector(threshold=2.0)
        for _ in range(10):
            det.observe(1.0)
        assert det.observe(5.0) is True
        assert det.observe(1.0) is False
        assert det.flagged == 1

    def test_restart_loop_survives_injected_failures(self, tmp_path):
        """Node failure at steps 7 and 13 -> restore -> completes 20 steps."""
        saved = {}

        def make_state():
            return {"x": jnp.float32(0.0)}

        fail_at = {7, 13}
        seen_failures = []

        def step_fn(state, step):
            if step in fail_at and step not in seen_failures:
                seen_failures.append(step)
                raise NodeFailure(f"injected at {step}")
            return {"x": state["x"] + 1.0}

        def save_fn(state, step):
            saved["state"], saved["step"] = state, step

        def restore_fn():
            if "state" not in saved:
                return None
            return saved["state"], saved["step"]

        state, stats = run_with_restarts(
            make_state=make_state, step_fn=step_fn, save_fn=save_fn,
            restore_fn=restore_fn, num_steps=20, checkpoint_every=5,
            max_restarts=5,
        )
        assert stats["restarts"] == 2
        assert float(state["x"]) == 20.0  # no lost or repeated steps


class TestTrainingEndToEnd:
    def test_loss_decreases_tiny_model(self):
        cfg = get_config("qwen2-0.5b").reduced()
        import dataclasses

        cfg = dataclasses.replace(cfg, num_layers=2, microbatch=2)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=1)
        state = init_train_state(cfg, jax.random.key(0))
        step_fn = jax.jit(make_train_step(
            cfg, adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
        ))
        losses = []
        for i in range(30):
            state, metrics = step_fn(state, make_batch(dcfg, i))
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses[::6]

    def test_microbatch_equals_full_batch_grads(self):
        cfg = get_config("qwen2-0.5b").reduced()
        import dataclasses

        cfg1 = dataclasses.replace(cfg, num_layers=1, microbatch=1)
        cfg4 = dataclasses.replace(cfg, num_layers=1, microbatch=4)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
        batch = make_batch(dcfg, 0)
        s1 = init_train_state(cfg1, jax.random.key(0))
        s4 = TrainState(s1.params, s1.opt, s1.ef)
        n1, m1 = make_train_step(cfg1)(s1, batch)
        n4, m4 = make_train_step(cfg4)(s4, batch)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
        l1 = jax.tree.leaves(n1.params)
        l4 = jax.tree.leaves(n4.params)
        for a, b in zip(l1, l4):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


class TestServeEngine:
    def test_generate_shapes_and_determinism(self):
        cfg = get_config("gemma3-1b").reduced()
        params = M.init_params(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, max_len=64)
        prompts = jnp.asarray([[3, 5, 7], [11, 2, 9]], jnp.int32)
        out1 = eng.generate(prompts, num_new_tokens=4)
        out2 = eng.generate(prompts, num_new_tokens=4)
        assert out1.shape == (2, 7)
        np.testing.assert_array_equal(out1, out2)
        assert int(out1.max()) < cfg.vocab_size
