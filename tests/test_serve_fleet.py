"""Replica-fleet drills: snapshot handoff after a replica kill (greedy
and sampled, dense and paged) asserting every in-flight stream finishes
on survivors bit-identical to a fault-free single-engine run; silent
bitflip corruption detected by the checksum chain within the spot-check
cadence with a ``recovered`` outcome; shared-fleet-queue wait counted
against ``deadline_ms``; the AsyncSaver background-failure surface; the
bitflip / replica-kill pinned fire-exactly-once injector contract; the
ReplicaMonitor escalation policy; the ``serve_fleet_drain`` cost model;
and ``read_snapshot_host`` handoff validation."""

import shutil
import tempfile
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as C
from repro.configs.registry import get_config
from repro.core.cost_model import serve_fleet_drain
from repro.model import model as M
from repro.serve import health as H
from repro.model.recurrent import RecState
from repro.serve.chaos import ChaosInjector, ReplicaKilled, bitflip_slot_state
from repro.serve.engine import OUTCOMES, Request, ServeEngine
from repro.serve.fleet import FleetRouter, read_snapshot_host

jax.config.update("jax_platform_name", "cpu")

SPEC = [(5, 9), (12, 3), (7, 14), (3, 6), (9, 11)]


def _setup(arch, seed=0):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    return cfg, params, np.random.default_rng(seed)


def _requests(rng, cfg, spec=SPEC):
    return [
        Request(
            tokens=rng.integers(0, cfg.vocab_size, (pl,)).astype(np.int32),
            max_new_tokens=nn,
        )
        for pl, nn in spec
    ]


def _engine(cfg, params, paged=False):
    return ServeEngine(cfg, params, max_len=96, decode_window=4, paged=paged)


def _assert_streams_equal(base, outs):
    assert len(base) == len(outs)
    for i, (b, o) in enumerate(zip(base, outs)):
        np.testing.assert_array_equal(
            np.asarray(b), np.asarray(o),
            err_msg=f"request {i} diverged from the fault-free run")


def _run_fleet(cfg, params, reqs, *, paged=False, chaos=None, n_rep=3,
               snapshot_every=1, checksum_every=2, **kw):
    engines = [_engine(cfg, params, paged=paged) for _ in range(n_rep)]
    root = tempfile.mkdtemp(prefix="fleet_test_")
    try:
        fl = FleetRouter(
            engines, reqs, slots=2, snapshot_every=snapshot_every,
            snapshot_root=root, checksum_every=checksum_every,
            chaos=chaos, **kw)
        outs = fl.run()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return fl, outs


class TestSnapshotHandoffParity:
    """Acceptance drill: 3 replicas, one killed mid-decode, its live
    memory discarded — every in-flight request finishes on the survivors
    bit-identical to a fault-free single-engine run, greedy and sampled,
    dense and paged."""

    @pytest.mark.parametrize("temperature,top_k", [(0.0, 0), (0.8, 16)])
    def test_replica_kill_dense(self, temperature, top_k):
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg)
        base = _engine(cfg, params).serve(
            reqs, slots=2, seed=0, temperature=temperature, top_k=top_k,
            recoverable=True)
        chaos = [None, ChaosInjector(seed=7, replica_kill_at=(1,)), None]
        fl, outs = _run_fleet(cfg, params, reqs, chaos=chaos, seed=0,
                              temperature=temperature, top_k=top_k)
        assert fl.stats["replica_deaths"] == 1
        assert (fl.stats["handoffs"]
                + fl.stats["handoff_requeued_fresh"]) >= 1
        assert fl.monitors[1].state == H.DEAD
        assert all(o.outcome in ("ok", "eos", "recovered") for o in outs)
        _assert_streams_equal(base, outs)

    def test_replica_kill_paged(self):
        cfg, params, rng = _setup("gemma3-1b")
        reqs = _requests(rng, cfg)
        base = _engine(cfg, params, paged=True).serve(
            reqs, slots=2, seed=0, recoverable=True)
        chaos = [None, ChaosInjector(seed=7, replica_kill_at=(1,)), None]
        fl, outs = _run_fleet(cfg, params, reqs, paged=True, chaos=chaos,
                              seed=0)
        assert fl.stats["replica_deaths"] == 1
        assert all(o.outcome in ("ok", "eos", "recovered") for o in outs)
        _assert_streams_equal(base, outs)

    def test_handoff_resumes_accepted_prefix(self):
        """A killed replica's snapshot prefix is charged as a recovery:
        at least one orphan resumes mid-stream (outcome ``recovered``)
        rather than re-running from scratch."""
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg)
        chaos = [None, ChaosInjector(seed=7, replica_kill_at=(2,)), None]
        fl, outs = _run_fleet(cfg, params, reqs, chaos=chaos, seed=0)
        assert fl.stats["replica_deaths"] == 1
        if fl.stats["handoffs"]:
            rec = [o for o in outs if o.outcome == "recovered"]
            assert rec and all(o.recoveries >= 1 for o in rec)

    def test_fault_free_fleet_matches_single_engine(self):
        """Routing itself must be invisible: with no chaos the fleet's
        streams equal the single recoverable engine's, replica by
        request."""
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg)
        base = _engine(cfg, params).serve(reqs, slots=2, seed=0,
                                          recoverable=True)
        fl, outs = _run_fleet(cfg, params, reqs, seed=0)
        assert fl.stats["replica_deaths"] == 0
        assert fl.stats["handoffs"] == 0
        assert fl.stats["assignments"] == len(reqs)
        _assert_streams_equal(base, outs)


class TestBitflipDetection:
    """Silent corruption: one flipped state bit is invisible to the
    ``isfinite`` quarantine but breaks the uint32 checksum chain — it
    must be detected within the spot-check cadence, rolled back, and
    recovered bit-identical."""

    def test_bitflip_detected_and_recovered(self):
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg)
        base = _engine(cfg, params).serve(reqs, slots=2, seed=0,
                                          recoverable=True)
        inj = ChaosInjector(seed=7, bitflip_at=(1,))
        fl, outs = _run_fleet(cfg, params, reqs, chaos=[inj, None, None],
                              checksum_every=2, seed=0)
        assert inj.counters["bitflip"] == 1
        per_rep = fl.stats_by_replica()
        assert sum(s["corruptions"] for s in per_rep) >= 1
        assert any(o.outcome == "recovered" for o in outs)
        assert all(o.outcome in ("ok", "eos", "recovered") for o in outs)
        _assert_streams_equal(base, outs)


class TestSharedQueueDeadline:
    """``deadline_ms`` counts from arrival at the FLEET, not from
    replica admission: a request that ages out while still in the shared
    queue dies there with the same typed ``deadline`` outcome the engine
    uses — no replica ever sees it."""

    def test_expiry_in_shared_queue(self):
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg)
        box = [0.0]
        eng = _engine(cfg, params)
        fl = FleetRouter([eng], reqs, slots=2, deadline_ms=100.0,
                         clock=lambda: box[0])
        box[0] = 0.2                       # 200 ms in the shared queue
        fl.step_round()
        outs = fl.run()
        assert "deadline" in OUTCOMES
        assert all(o.outcome == "deadline" for o in outs)
        assert all(o.size == 0 for o in outs)
        assert fl.stats["shared_deadline_hits"] == len(reqs)
        # No replica ever dispatched for them.
        assert fl.stats_by_replica()[0]["decode_dispatches"] == 0

    def test_per_request_deadline_only_kills_the_expired(self):
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg, spec=SPEC[:4])
        # One slot pair, 4-deep local cap: the 5th request waits in the
        # shared queue, where its (tiny) per-request deadline expires
        # while the others decode on their own clocks (no deadline).
        late = Request(
            tokens=rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32),
            max_new_tokens=8, deadline_ms=1.0)
        box = [0.0]
        eng = _engine(cfg, params)
        fl = FleetRouter([eng], reqs + [late], slots=2,
                         clock=lambda: box[0])
        fl.step_round()                    # assigns the first 4, decodes
        box[0] = 0.05                      # 50 ms: only `late` is expired
        outs = fl.run()
        assert outs[-1].outcome == "deadline"
        assert all(o.outcome in ("ok", "eos") for o in outs[:-1])
        assert fl.stats["shared_deadline_hits"] == 1


class TestAsyncSaverFailure:
    """A failed background snapshot write must surface on the next
    ``save_async``/``wait`` — a handoff source that failed silently is
    worse than none."""

    def test_background_failure_surfaces(self, tmp_path, monkeypatch):
        def boom(directory, step, tree, mesh_shape=None):
            raise OSError("disk gone")

        monkeypatch.setattr(C, "save", boom)
        saver = C.AsyncSaver()
        saver.save_async(tmp_path, 0, {"x": np.zeros(2)})
        with pytest.raises(C.AsyncSaverError):
            saver.wait()
        # The error is delivered once; the saver is reusable after.
        saver.wait()

    def test_failure_surfaces_on_next_save(self, tmp_path, monkeypatch):
        calls = []

        def boom(directory, step, tree, mesh_shape=None):
            calls.append(step)
            raise OSError("disk gone")

        monkeypatch.setattr(C, "save", boom)
        saver = C.AsyncSaver()
        saver.save_async(tmp_path, 0, {"x": np.zeros(2)})
        with pytest.raises(C.AsyncSaverError):
            saver.save_async(tmp_path, 1, {"x": np.zeros(2)})
        assert calls == [0]

    def test_stalled_writer_surfaces_and_is_abandoned(
            self, tmp_path, monkeypatch):
        """A writer that hangs (dead NFS mount, wedged device sync) must
        surface as an AsyncSaverError within the join budget — and its
        eventual late completion is generation-fenced, never delivered
        to a saver that has already moved on."""
        release = threading.Event()
        entered = threading.Event()

        def stall(directory, step, tree, mesh_shape=None):
            entered.set()
            release.wait(10.0)

        monkeypatch.setattr(C, "save", stall)
        saver = C.AsyncSaver()
        saver.save_async(tmp_path, 0, {"x": np.zeros(2)})
        assert entered.wait(5.0)
        with pytest.raises(C.AsyncSaverError, match="stalled"):
            saver.wait(timeout_s=0.05)
        assert saver.stalls == 1
        # Unblock the abandoned writer: its result must be discarded
        # against the bumped generation, not raised or recorded.
        release.set()
        deadline = time.monotonic() + 5.0
        while saver.stale_discarded == 0 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert saver.stale_discarded == 1
        # The saver stays usable: a fresh wait() is clean.
        saver.wait()


class TestInjectorContracts:
    """Pinned ``bitflip_at`` / ``replica_kill_at`` fire exactly once
    (a retried dispatch keeps its index and must converge), and the
    schedule replays under a fixed seed."""

    @staticmethod
    def _state(b=2):
        # The injector flips bits in typed state nodes (RecState h here);
        # plain arrays are passed through untouched.
        return {"layer0": RecState(h=jnp.ones((b, 4, 4), jnp.float32),
                                   conv=jnp.zeros((b, 2, 4), jnp.float32))}

    def test_pinned_bitflip_fires_exactly_once(self):
        inj = ChaosInjector(seed=0, bitflip_at=(3,))
        state = self._state()
        active = np.array([True, True])
        same, slot = inj.maybe_bitflip(state, active, 2, [0, 1])
        assert slot is None and same is state
        flipped, slot = inj.maybe_bitflip(state, active, 3, [0, 1])
        assert slot is not None
        assert not np.array_equal(np.asarray(flipped["layer0"].h),
                                  np.asarray(state["layer0"].h))
        again, slot2 = inj.maybe_bitflip(state, active, 3, [0, 1])
        assert slot2 is None and again is state
        assert inj.counters["bitflip"] == 1

    def test_pinned_replica_kill_fires_exactly_once(self):
        inj = ChaosInjector(seed=0, replica_kill_at=(5,))
        inj.check_replica_kill(4)
        with pytest.raises(ReplicaKilled):
            inj.check_replica_kill(5)
        inj.check_replica_kill(5)          # retry at the same index: no-op
        assert inj.counters["replica_kill"] == 1
        assert inj.events == [("replica_kill", 5, None)]

    def test_bitflip_schedule_replays_under_fixed_seed(self):
        state = self._state()
        active = np.array([True, True])

        def schedule(seed):
            inj = ChaosInjector(seed=seed, bitflip_rate=0.4)
            out = []
            for i in range(20):
                flipped, slot = inj.maybe_bitflip(state, active, i, [0, 1])
                out.append((i, slot,
                            None if slot is None
                            else np.asarray(flipped["layer0"].h).tobytes()))
            return out

        a, b = schedule(11), schedule(11)
        assert a == b
        assert any(slot is not None for _, slot, _ in a)

    def test_bitflip_slot_state_is_deterministic_and_local(self):
        state = self._state(b=3)
        f1 = bitflip_slot_state(state, 1)
        f2 = bitflip_slot_state(state, 1)
        h0, h1, h2 = (np.asarray(s["layer0"].h) for s in (state, f1, f2))
        np.testing.assert_array_equal(h1, h2)
        # Rows other than the flipped slot are untouched; the flip is a
        # single low mantissa bit, so the value stays finite-but-wrong.
        np.testing.assert_array_equal(h1[[0, 2]], h0[[0, 2]])
        assert not np.array_equal(h1[1], h0[1])
        assert np.isfinite(h1).all()
        np.testing.assert_array_equal(np.asarray(f1["layer0"].conv),
                                      np.asarray(state["layer0"].conv))


class TestReplicaMonitor:
    """The escalation policy is deterministic and clock-free: every
    transition is drivable from observation deltas alone."""

    def test_fault_rate_degrades_then_heals(self):
        mon = H.ReplicaMonitor(window=4)
        assert mon.state == H.HEALTHY and mon.routable
        assert mon.observe(faults=1) == H.DEGRADED
        assert not mon.routable
        assert "fault rate" in mon.reason
        # Clean observations dilute the windowed rate below the limit.
        mon.observe()
        assert mon.observe() == H.HEALTHY
        assert mon.routable
        assert mon.transitions[-1] == (H.HEALTHY, "clean observation window")

    def test_consecutive_stragglers_degrade(self):
        mon = H.ReplicaMonitor(straggler_limit=3)
        assert mon.observe(straggler=True) == H.HEALTHY
        assert mon.observe(straggler=True) == H.HEALTHY
        assert mon.observe(straggler=True) == H.DEGRADED
        assert "stragglers" in mon.reason
        # A non-straggler dispatch breaks the run and heals.
        assert mon.observe() == H.HEALTHY

    def test_watchdog_timeout_ages_out_of_window(self):
        mon = H.ReplicaMonitor(window=3, dead_after_degraded=10)
        assert mon.observe(watchdog_timeout=True) == H.DEGRADED
        assert mon.observe() == H.DEGRADED      # still in the window
        assert mon.observe() == H.DEGRADED
        assert mon.observe() == H.HEALTHY       # timeout aged out

    def test_persistent_degradation_dies(self):
        mon = H.ReplicaMonitor(window=2, dead_after_degraded=3)
        assert mon.observe(faults=1) == H.DEGRADED
        assert mon.observe(faults=1) == H.DEGRADED
        assert mon.observe(faults=1) == H.DEAD
        assert "consecutive observations" in mon.reason
        # Dead is terminal: clean observations change nothing.
        assert mon.observe() == H.DEAD
        assert not mon.routable

    def test_mark_dead_is_idempotent_and_terminal(self):
        mon = H.ReplicaMonitor()
        mon.mark_dead("injected kill")
        mon.mark_dead("second call")
        assert mon.state == H.DEAD
        assert mon.reason == "injected kill"
        assert mon.transitions == [(H.DEAD, "injected kill")]
        assert mon.observe(faults=5) == H.DEAD

    def test_window_validation(self):
        with pytest.raises(ValueError):
            H.ReplicaMonitor(window=0)

    def test_concurrent_observation_no_torn_transitions(self):
        """Observer threads hammer ``observe()`` while readers race
        ``status()``: a reader must never see a non-healthy state with
        an empty reason (a torn state/reason pair), never see the
        monitor heal after DEAD, and once ``mark_dead`` fires the
        verdict is exactly (DEAD, its reason) forever."""
        mon = H.ReplicaMonitor(window=4, dead_after_degraded=10**9)
        stop = threading.Event()
        bad = []

        def reader():
            seen_dead = False
            while not stop.is_set():
                state, reason = mon.status()
                if state == H.DEAD:
                    seen_dead = True
                    if reason != "external death":
                        bad.append(("dead-with-wrong-reason", reason))
                elif seen_dead:
                    bad.append(("healed-after-dead", state))
                if state != H.HEALTHY and not reason:
                    bad.append(("state-without-reason", state))

        def observer(seed):
            rng = np.random.default_rng(seed)
            for _ in range(400):
                mon.observe(faults=int(rng.integers(0, 2)),
                            straggler=bool(rng.integers(0, 2)))

        readers = [threading.Thread(target=reader) for _ in range(2)]
        observers = [threading.Thread(target=observer, args=(s,))
                     for s in range(3)]
        for t in readers + observers:
            t.start()
        for t in observers[:2]:
            t.join()
        mon.mark_dead("external death")
        observers[2].join()
        stop.set()
        for t in readers:
            t.join()
        assert bad == []
        assert mon.status() == (H.DEAD, "external death")
        assert mon.transitions[-1] == (H.DEAD, "external death")
        assert not mon.routable


class TestFleetDrainModel:
    """serve_fleet_drain: recovery-aware least-loaded placement vs a
    depth-blind round-robin over survivors carrying recovery debt."""

    def test_aware_routes_around_recovery_debt(self):
        # Two survivors, one carrying 8 slot-steps of replay debt:
        # aware placement fills the idle survivor first.
        aware, blind = serve_fleet_drain([4, 4, 4, 4], [0, 8], window=4)
        assert aware == 12
        assert blind == 16
        assert aware <= blind

    def test_window_quantization(self):
        aware, blind = serve_fleet_drain([1], [0], window=4)
        assert aware == blind == 4

    def test_aware_never_worse_on_uniform_work(self):
        # With uniform work items (the window-quantized decode regime),
        # placing on the current minimum is exchange-argument optimal,
        # so aware <= blind for any survivor depths.  (Heterogeneous
        # work admits classic list-scheduling counterexamples; the
        # model's claim is about the quantized drain.)
        rng = np.random.default_rng(3)
        for _ in range(20):
            w = int(rng.integers(1, 12))
            work = [w] * int(rng.integers(1, 9))
            depths = rng.integers(0, 30, rng.integers(1, 4)).tolist()
            aware, blind = serve_fleet_drain(work, depths, window=4)
            assert aware <= blind

    def test_validation(self):
        with pytest.raises(ValueError):
            serve_fleet_drain([4], [], window=4)
        with pytest.raises(ValueError):
            serve_fleet_drain([4], [0], window=0)
        with pytest.raises(ValueError):
            serve_fleet_drain([0], [0], window=4)
        with pytest.raises(ValueError):
            serve_fleet_drain([4], [-1], window=4)


class TestReadSnapshotHost:
    """Handoff-source validation: a missing snapshot is a None (fresh
    re-run), a mismatched or corrupt one is a loud error — silently
    resuming the wrong streams is the one unacceptable outcome."""

    def _snapshot(self, tmp_path):
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg, spec=SPEC[:3])
        eng = _engine(cfg, params)
        outs = eng.serve(reqs, slots=2, snapshot_every=1,
                         snapshot_dir=str(tmp_path), recoverable=True)
        return outs, len(reqs)

    def test_no_snapshot_returns_none(self, tmp_path):
        assert read_snapshot_host(tmp_path, 5) is None

    def test_roundtrip_prefixes(self, tmp_path):
        outs, n = self._snapshot(tmp_path)
        snap = read_snapshot_host(tmp_path, n)
        assert snap is not None
        assert int(snap["meta"][3]) == n
        for i, o in enumerate(outs):
            got = snap["outputs"][i]
            np.testing.assert_array_equal(
                np.asarray(o)[: len(got)], np.asarray(got, np.int32),
                err_msg=f"snapshot output {i} is not an accepted prefix")
            assert snap["outcomes"][i] in (None,) + OUTCOMES

    def test_rejects_wrong_request_count(self, tmp_path):
        _, n = self._snapshot(tmp_path)
        with pytest.raises(ValueError, match="refusing"):
            read_snapshot_host(tmp_path, n + 1)

    def _tamper(self, tmp_path, mutate):
        step = C.latest_step(tmp_path)
        npz = Path(tmp_path) / f"step_{step}" / "arrays.npz"
        with np.load(npz) as data:
            arrays = {k: data[k] for k in data.files}
        mutate(arrays)
        np.savez(npz, **arrays)

    def test_rejects_malformed_meta(self, tmp_path):
        _, n = self._snapshot(tmp_path)
        self._tamper(tmp_path, lambda a: a.update(meta=a["meta"][:5]))
        with pytest.raises(ValueError, match="shape"):
            read_snapshot_host(tmp_path, n)

    def test_rejects_missing_meta(self, tmp_path):
        _, n = self._snapshot(tmp_path)
        self._tamper(tmp_path, lambda a: a.pop("meta"))
        with pytest.raises(ValueError, match="meta"):
            read_snapshot_host(tmp_path, n)

    def test_rejects_inconsistent_offsets(self, tmp_path):
        _, n = self._snapshot(tmp_path)

        def bump(a):
            off = a["host/out_off"].copy()
            off[-1] += 1
            a["host/out_off"] = off

        self._tamper(tmp_path, bump)
        with pytest.raises(ValueError, match="inconsistent"):
            read_snapshot_host(tmp_path, n)


class TestFleetRouterValidation:
    def test_constructor_validation(self):
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg, spec=SPEC[:2])
        eng = _engine(cfg, params)
        with pytest.raises(ValueError, match="at least one"):
            FleetRouter([], reqs)
        with pytest.raises(ValueError, match="snapshot_root"):
            FleetRouter([eng], reqs, snapshot_every=1)
        with pytest.raises(ValueError, match="per engine"):
            FleetRouter([eng], reqs, chaos=[None, None])

    def test_shared_queue_shed_beyond_capacity(self):
        cfg, params, rng = _setup("rwkv6-1.6b")
        reqs = _requests(rng, cfg)
        eng = _engine(cfg, params)
        fl = FleetRouter([eng], reqs, slots=2, max_queue=1)
        # 2 slots admit immediately + 1 may wait: the rest shed, latest
        # arrivals first (same policy as the single-engine queue bound).
        outs = fl.run()
        shed = [o for o in outs if o.outcome == "shed"]
        assert len(shed) == len(reqs) - 3
        assert fl.stats["shared_shed"] == len(shed)
        assert all(o.outcome in ("ok", "eos") for o in outs[:3])
