"""Fault tolerance: step watchdog, restart driver, straggler detection.

At thousand-node scale the failure model is: (a) hard node loss — the job
must restart from the last checkpoint on a (possibly smaller) mesh;
(b) hangs — a collective never completes because one participant stalled;
(c) stragglers — a slow node stretches every synchronous step.

This module implements the *driver-side* machinery, which is identical at
container scale and cluster scale:

  * :class:`StepWatchdog` — wall-clock deadline per step; a stuck step
    raises :class:`StepTimeout` in the driver, which triggers
    restart-from-checkpoint (the standard TPU preemption pattern).
  * :func:`run_with_restarts` — the outer resilience loop: run -> on
    failure restore latest checkpoint -> resume at the checkpointed step
    (the stateless data pipeline re-keys itself by step, so no data is
    skipped or repeated).
  * :class:`StragglerDetector` — EWMA of step times; flags steps slower
    than ``threshold×`` the moving median so the scheduler can evict/
    replace the slow host.  Mitigation at the collective level comes from
    gradient compression (fewer bytes on the slow link) and the point-to-
    point elevator collectives (a straggler delays only its neighbors'
    edges, not a global barrier — the paper's barrier-free argument at
    cluster scale).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable


_lock_factory: Callable[[], Any] = threading.Lock


def make_lock():
    """Construct a mutex for host-tier shared state.

    Every lock in the serve/ft/checkpoint stack comes from here so the
    deterministic interleaving drill (:mod:`repro.serve.interleave`) can
    swap in instrumented locks that force a preemption window at every
    acquire/release — the runtime witness for the static lock-discipline
    audit (``repro.analysis.hostsafety``).
    """
    return _lock_factory()


def set_lock_factory(factory: Callable[[], Any] | None):
    """Install (or, with ``None``, reset) the lock constructor used by
    :func:`make_lock`.  Returns the previous factory so callers can
    restore it."""
    global _lock_factory
    prev = _lock_factory
    _lock_factory = threading.Lock if factory is None else factory
    return prev


class StepTimeout(RuntimeError):
    pass


class NodeFailure(RuntimeError):
    """Raised by failure-injection hooks in tests / chaos drills."""


class StepWatchdog:
    """Deadline enforcement for (potentially hanging) steps.

    A Python thread cannot be killed, so a timed-out step's worker keeps
    running after :class:`StepTimeout` is raised — and with donated device
    buffers in flight, an abandoned step that later completes would race
    the restarted one.  Every ``run`` therefore opens a new *generation*:
    on timeout the generation is fenced off, the stale thread's eventual
    result or exception is discarded (``stale_discarded`` counts them),
    and the stale thread can notice it was abandoned via
    :attr:`cancelled` — a callable the watched ``fn`` may poll at safe
    points (e.g. *before* consuming donated buffers) to bail out
    cooperatively instead of mutating state the restarted step now owns.
    """

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._gen = 0
        self._lock = make_lock()
        self.stale_discarded = 0
        self.timeouts = 0
        # Heartbeat: every completed run() bumps ``beats`` and stamps
        # ``last_beat`` — the liveness signal a replica health monitor
        # reads (a replica whose watchdog stops beating while its queue
        # is non-empty is wedged, not idle).
        self.beats = 0
        self.last_beat: float | None = None
        # Re-bound at each run(); True once that run has been abandoned.
        self.cancelled: Callable[[], bool] = lambda: False

    def run(self, fn: Callable[[], Any]) -> Any:
        with self._lock:
            self._gen += 1
            gen = self._gen
            # Published under the lock: a previous generation's worker
            # polling the *old* closure still compares against the bumped
            # ``_gen``, and sees the rebind or the bump, never neither.
            self.cancelled = lambda: gen != self._gen
        outcome: list[tuple[bool, Any]] = []

        def target():
            try:
                value = fn()
                ok = True
            except BaseException as e:  # noqa: BLE001 — propagated below
                value, ok = e, False
            with self._lock:
                if gen != self._gen:        # fenced: step was abandoned
                    self.stale_discarded += 1
                    return
                outcome.append((ok, value))

        t = threading.Thread(target=target, daemon=True)
        t.start()
        t.join(self.timeout_s)
        with self._lock:
            if not outcome:
                # Hung: advance the generation *under the lock*, so a
                # worker racing to finish right now either already
                # appended (seen below) or sees the fence and discards.
                self._gen += 1
                self.timeouts += 1
                hung = True
            else:
                hung = False
        if hung:
            raise StepTimeout(
                f"step exceeded {self.timeout_s}s (hung collective?)"
            )
        ok, value = outcome[0]
        if not ok:
            raise value
        self.beats += 1
        self.last_beat = time.monotonic()
        return value


@dataclasses.dataclass
class StragglerDetector:
    """EWMA straggler flagging.

    ``warmup`` observations are discarded before the baseline seeds: the
    first step of any jitted loop includes compile time, and folding it
    into the EWMA poisons the baseline (a 100× compile step makes every
    real step look fast forever — or, after a restart re-traces, makes
    the first real step look like a straggler).  :meth:`reset` drops the
    baseline so a restarted run re-warms instead of comparing against a
    dead configuration's step times.
    """

    threshold: float = 2.0
    alpha: float = 0.1
    warmup: int = 1
    _ewma: float | None = None
    _seen: int = 0
    flagged: int = 0

    def observe(self, step_time_s: float) -> bool:
        """Returns True if this step is a straggler."""
        self._seen += 1
        if self._seen <= self.warmup:
            return False
        if self._ewma is None:
            self._ewma = step_time_s
            return False
        is_straggler = step_time_s > self.threshold * self._ewma
        # Slow samples update the EWMA less (don't let stragglers poison it).
        a = self.alpha * (0.25 if is_straggler else 1.0)
        self._ewma = (1 - a) * self._ewma + a * step_time_s
        if is_straggler:
            self.flagged += 1
        return is_straggler

    def reset(self):
        """Drop the baseline (and re-enter warmup): call after a restart,
        where the first step re-pays jit compile time."""
        self._ewma = None
        self._seen = 0

    @property
    def baseline_s(self) -> float | None:
        return self._ewma


def run_with_restarts(
    *,
    make_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    save_fn: Callable[[Any, int], None],
    restore_fn: Callable[[], tuple[Any, int] | None],
    num_steps: int,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    watchdog_timeout_s: float = 3600.0,
    on_event: Callable[[str], None] | None = None,
) -> tuple[Any, dict]:
    """The resilience loop: survive StepTimeout / NodeFailure via restore.

    Returns (final_state, stats).  ``step_fn(state, step) -> state``.
    """
    log = on_event or (lambda msg: None)
    watchdog = StepWatchdog(watchdog_timeout_s)
    straggler = StragglerDetector()
    restarts = 0
    stats = {"restarts": 0, "stragglers": 0, "steps_run": 0}

    restored = restore_fn()
    if restored is not None:
        state, start = restored
        log(f"restored checkpoint at step {start}")
    else:
        state, start = make_state(), 0

    step = start
    while step < num_steps:
        try:
            t0 = time.monotonic()
            state = watchdog.run(lambda: step_fn(state, step))
            dt = time.monotonic() - t0
            stats["steps_run"] += 1
            if straggler.observe(dt):
                stats["stragglers"] += 1
                log(f"straggler at step {step}: {dt:.3f}s vs ~{straggler.baseline_s:.3f}s")
            step += 1
            if step % checkpoint_every == 0 or step == num_steps:
                save_fn(state, step)
        except (StepTimeout, NodeFailure) as e:
            restarts += 1
            stats["restarts"] = restarts
            log(f"failure at step {step}: {e}; restart {restarts}/{max_restarts}")
            if restarts > max_restarts:
                raise
            restored = restore_fn()
            if restored is None:
                state, step = make_state(), 0
            else:
                state, step = restored
            # The restarted run re-traces: its first step pays compile
            # time again, and the old baseline belongs to a dead process
            # configuration — re-warm instead of flagging it.
            straggler.reset()
    return state, stats
