"""Training step: microbatched grad accumulation, CE loss, AdamW, options.

The step is a pure function (TrainState, batch) -> (TrainState, metrics),
jittable and shardable; microbatching runs as a ``lax.scan`` over
grad-accumulation chunks so activation memory scales with the microbatch,
not the global batch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from repro.core.lowering import scan_unroll

from repro.model import model as M
from repro.model.sharding import constrain
from repro.optim import adamw
from repro.optim.compression import (
    ErrorFeedbackState,
    compressed_gradients,
    init_error_feedback,
)


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    ef: ErrorFeedbackState | None = None


def init_train_state(cfg, key, opt_cfg=None, *, compress=False) -> TrainState:
    params = M.init_params(cfg, key)
    return TrainState(
        params=params,
        opt=adamw.init_state(params),
        ef=init_error_feedback(params) if compress else None,
    )


def abstract_train_state(cfg, *, compress=False) -> TrainState:
    params = M.abstract_params(cfg)
    ef = None
    if compress:
        ef = ErrorFeedbackState(
            residual=jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params
            )
        )
    return TrainState(params=params, opt=adamw.abstract_state(params), ef=ef)


def train_state_pspecs(cfg, rules) -> TrainState:
    pspecs = M.param_pspecs(cfg, rules)
    ef = ErrorFeedbackState(residual=pspecs)
    return TrainState(params=pspecs, opt=adamw.state_pspecs(pspecs), ef=None)


def _model_kwargs(cfg, batch):
    kw = {}
    if "frontend_embeds" in batch:
        kw["frontend_embeds"] = batch["frontend_embeds"]
    if "positions" in batch:
        kw["positions"] = batch["positions"]
    if "enc_embeds" in batch:
        kw["enc_tokens_embeds"] = batch["enc_embeds"]
    return kw


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        logits = M.forward(params, cfg, batch["tokens"], **_model_kwargs(cfg, batch))
        return cross_entropy(logits, batch["labels"])

    return loss_fn


def _split_micro(batch, n_micro: int):
    def split(x):
        b = x.shape[0]
        if b % n_micro:
            raise ValueError(f"batch {b} not divisible by microbatch {n_micro}")
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    # `positions` for M-RoPE is (3, B, S): batch axis is 1.
    out = {}
    for k, v in batch.items():
        if k == "positions" and v.ndim == 3 and v.shape[0] == 3:
            b = v.shape[1]
            out[k] = jnp.moveaxis(
                v.reshape(3, n_micro, b // n_micro, v.shape[2]), 1, 0
            )
        else:
            out[k] = split(v)
    return out


def make_train_step(
    cfg,
    opt_cfg: adamw.AdamWConfig | None = None,
    *,
    compress: bool = False,
    accum_dtype=jnp.float32,
):
    """Build the jittable train step for ``cfg``."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    loss_fn = make_loss_fn(cfg)
    n_micro = max(1, cfg.microbatch)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        params = state.params

        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = _split_micro(batch, n_micro)

            def accum(carry, mb):
                loss_acc, grads_acc = carry
                loss_i, grads_i = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), grads_acc, grads_i
                )
                return (loss_acc + loss_i, grads_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.float32(0.0), zero), micro, unroll=scan_unroll()
            )
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        ef = state.ef
        if compress and ef is not None:
            grads, ef = compressed_gradients(grads, ef)

        new_params, new_opt, opt_metrics = adamw.apply_updates(
            params, grads, state.opt, opt_cfg
        )
        metrics = {"loss": loss, **opt_metrics}
        return TrainState(new_params, new_opt, ef), metrics

    return train_step


def make_jitted_train_step(
    cfg,
    opt_cfg: adamw.AdamWConfig | None = None,
    *,
    compress: bool = False,
    accum_dtype=jnp.float32,
    donate: bool = True,
):
    """The canonical jitted train step: ``TrainState`` donated.

    Params, optimizer moments, and error-feedback residuals are all
    replaced wholesale every step, so the state pytree is the textbook
    donation target — without it XLA copies two full model-sized trees
    (params + moments) through HBM per step.  Launchers should use this
    instead of wrapping :func:`make_train_step` in a bare ``jax.jit``
    (which is exactly the forgot-``donate_argnums`` regression the
    donation pass in :mod:`repro.analysis` guards against).
    """
    step = make_train_step(
        cfg, opt_cfg, compress=compress, accum_dtype=accum_dtype
    )
    return jax.jit(step, donate_argnums=(0,) if donate else ())


def audit_jit_entrypoints(cfg, *, batch: int | None = None, seq: int = 16):
    """Registration hook for :mod:`repro.analysis.donation`: the train
    step jit with abstract state/batch (nothing executes)."""
    from repro.analysis.donation import JitEntry

    b = batch if batch is not None else 2 * max(1, cfg.microbatch)
    bt = {
        "tokens": jax.ShapeDtypeStruct((b, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, seq), jnp.int32),
    }
    if cfg.is_enc_dec:
        bt["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return [
        JitEntry(
            "train.step", make_jitted_train_step(cfg),
            (abstract_train_state(cfg), bt),
            "src/repro/train/step.py:make_jitted_train_step",
            donated="TrainState", donate_argnums=(0,),
            donor="make_jitted_train_step",
        ),
    ]
