"""Paged KV storage for the serve engine: page pools, per-slot page
tables, and recurrent-state prefix sharing.

The dense engine gives every slot a ``max_len`` KV ring per attention
layer — slot count, not tokens in flight, caps concurrency, and a shared
system prompt is re-prefilled per request.  This module replaces that
static partitioning with pooled, dynamically-mapped storage (the paper's
argument against staging values through a statically-partitioned
scratchpad, applied to the serving layer):

* :class:`PagedController` owns one page pool per KV state node
  (physical pages of ``page_size`` tokens, a multiple of the 32-token
  admit bucket) and hands out / reclaims pages on the admission/recycle
  path of ``ServeEngine.serve()``.  A request's whole page need
  (``prompt + budget`` positions) is reserved at admission — no
  mid-window allocation, so decode windows never touch the allocator.
* :func:`apply_admission` is the device-side dual, run inside the admit
  jit right after ``_reset_slot_rows``: it installs the new page-table
  rows, scrubs freshly-mapped private pages of non-finite garbage (the
  paged rendering of the reset-path NaN scrub), and — for prefix
  admissions — copies the registered prefix's recurrent state (WKV S /
  RG-LRU h, conv tails) and local-ring content into the admitted rows:
  the read-side dual of ``_reset_slot_rows``.
* Full-view nodes (``s_view == max_len``: global attention, or a local
  ring capped at ``max_len``) can never wrap, so pages below a slot's
  start length are never written — those nodes *share* the prefix's
  pages read-only across every admitted slot.  Wrapping rings are
  written in place, so their prefix content is *copied* into the slot's
  private pages instead.

Freed pages never leak data into live streams: a freed page stays
mapped at most in an inactive (quarantined) slot's table row, every
position it could alias is rejected by the positional masks in
``_decode_attention`` (exact-0 attention weights), and the page is
scrubbed at its next admission before it becomes reachable again.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.model.attention import NULL_PAGE, KVCache, PagedKVCache
from repro.model.recurrent import RecState

_STATE_NODES = (KVCache, PagedKVCache, RecState)

#: ``owner`` codes below 0 (>= 0 is the owning slot index).
FREE, NULL, SHARED = -1, -2, -3


def _is_node(x) -> bool:
    return isinstance(x, _STATE_NODES)


def flatten_nodes(state):
    """State as a flat list of typed nodes + treedef (one deterministic
    walk shared by the host controller and the device admission op, so
    per-node metadata can never misalign)."""
    return jax.tree.flatten(state, is_leaf=_is_node)


def split_entry(entry_state):
    """Split a batch-1 *dense* decode state (a prefilled prefix) into the
    admit-jit operand lists: recurrent nodes in walk order, and per-KV-
    node ``(k, v)`` content pairs (the dense cache views)."""
    nodes, _ = flatten_nodes(entry_state)
    rec = [n for n in nodes if isinstance(n, RecState)]
    kv = [(n.k, n.v) for n in nodes if isinstance(n, KVCache)]
    return rec, kv


# --------------------------------------------------------------------------
# Host side: geometry + allocator
# --------------------------------------------------------------------------


@dataclasses.dataclass
class NodeGeom:
    """Static geometry of one paged KV node."""

    layers: int          # stacked multiplicity (1 = unstacked)
    s_view: int          # dense-equivalent sequence extent
    page_size: int
    nl: int              # logical pages per slot (ceil(s_view / page_size))
    pool_pages: int      # physical pages (incl. null + shared)
    role: str            # "share" (never wraps) | "copy" (wrapping ring)
    page_bytes: int      # k+v bytes of ONE page across stacked layers


class PagedController:
    """Host-side page bookkeeping for one ``serve()`` call.

    One ``owner`` array per KV node (page -> slot, or FREE / NULL /
    SHARED): the single source of truth the page tables are built from,
    what the snapshot saves, and what :meth:`audit` checks device tables
    against.
    """

    def __init__(self, cfg, abstract_state, *, batch: int, max_len: int,
                 shared_map: dict[int, tuple[int, int]] | None = None):
        nodes, _ = flatten_nodes(abstract_state)
        self.batch = int(batch)
        self.max_len = int(max_len)
        self.kv_index: list[int] = []
        self.geoms: list[NodeGeom] = []
        for i, node in enumerate(nodes):
            if not isinstance(node, PagedKVCache):
                continue
            stacked = node.k.ndim == 5
            layers = int(node.k.shape[0]) if stacked else 1
            ps = int(node.page_size)
            s = int(node.s_view)
            hkv, dh = int(node.k.shape[-2]), int(node.k.shape[-1])
            item = jnp.dtype(node.k.dtype).itemsize
            self.kv_index.append(i)
            self.geoms.append(NodeGeom(
                layers=layers, s_view=s, page_size=ps, nl=-(-s // ps),
                pool_pages=int(node.k.shape[-4]),
                role="share" if s == self.max_len else "copy",
                page_bytes=layers * ps * hkv * dh * item * 2,
            ))
        #: prefix id -> (first shared page id, page count); shared ids are
        #: the same across every "share" node (their pools reserve the
        #: same shared region), only the page *content* differs per node.
        self.shared_map = dict(shared_map or {})
        self.shared_total = sum(n for _, n in self.shared_map.values())
        self.owners: list[np.ndarray] = []
        self.free: list[list[int]] = []
        for g in self.geoms:
            owner = np.full(g.pool_pages, FREE, np.int32)
            owner[NULL_PAGE] = NULL
            if g.role == "share":
                owner[1:1 + self.shared_total] = SHARED
            self.owners.append(owner)
            self.free.append(sorted(np.nonzero(owner == FREE)[0].tolist(),
                                    reverse=True))
        self.peak_mapped_bytes = self.mapped_bytes()
        self.violations: list[str] = []

    # -- byte accounting -------------------------------------------------

    @property
    def roles(self) -> tuple[str, ...]:
        return tuple(g.role for g in self.geoms)

    def pool_bytes(self) -> int:
        """Physically allocated pool bytes (what the paged state holds)."""
        return sum(g.page_bytes * g.pool_pages for g in self.geoms)

    def dense_bytes(self) -> int:
        """What the dense engine allocates for the same geometry:
        ``slots × s_view`` positions per node."""
        return sum(
            g.page_bytes * self.batch * g.nl for g in self.geoms
        )

    def mapped_bytes(self) -> int:
        """Bytes of pages currently mapped (tokens in flight + shared)."""
        total = 0
        for g, owner in zip(self.geoms, self.owners):
            total += g.page_bytes * int(np.sum(owner >= 0))
            if g.role == "share":
                total += g.page_bytes * self.shared_total
        return total

    # -- allocation ------------------------------------------------------

    def pages_needed(self, total_positions: int, start_len: int):
        """Per-node (logical pages used, shared pages used, private pages
        to allocate) for a request reaching ``total_positions``."""
        out = []
        for g in self.geoms:
            used = -(-min(int(total_positions), g.s_view) // g.page_size)
            sh = (min(start_len // g.page_size, used)
                  if g.role == "share" else 0)
            out.append((used, sh, used - sh))
        return out

    def fits_capacity(self, total_positions: int, start_len: int) -> bool:
        """Whether the request could EVER be admitted (an empty pool has
        enough private pages) — the shed-vs-wait admission decision."""
        return all(
            priv <= len(owner) - 1 - np.sum(owner == SHARED)
            and priv <= np.sum(
                (owner == FREE) | (owner >= 0))
            for (_, _, priv), owner in zip(
                self.pages_needed(total_positions, start_len), self.owners)
        )

    def try_admit(self, slot: int, total_positions: int, prefix_id,
                  start_len: int):
        """Reserve the request's full page need and build its per-node
        table rows.  Returns ``(tables, scrubs)`` — per-node ``(nl,)``
        int32 rows (-1 = unmapped; scrub rows exclude shared pages) — or
        ``None`` (pool pressure: caller retries after a recycle)."""
        need = self.pages_needed(total_positions, start_len)
        grabbed: list[list[int]] = []
        for (used, sh, priv), free in zip(need, self.free):
            if priv > len(free):
                for ids, fr in zip(grabbed, self.free):
                    fr.extend(reversed(ids))
                return None
            grabbed.append([free.pop() for _ in range(priv)])
        tables, scrubs = [], []
        for g, owner, (used, sh, priv), ids in zip(
                self.geoms, self.owners, need, grabbed):
            row = np.full(g.nl, -1, np.int32)
            if sh:
                start, _ = self.shared_map[prefix_id]
                row[:sh] = np.arange(start, start + sh, dtype=np.int32)
            row[sh:used] = np.asarray(ids, np.int32)
            for pid_ in ids:
                owner[pid_] = slot
            scrub = row.copy()
            scrub[:sh] = -1
            tables.append(row)
            scrubs.append(scrub)
        self.peak_mapped_bytes = max(self.peak_mapped_bytes,
                                     self.mapped_bytes())
        return tables, scrubs

    def free_slot(self, slot: int):
        """Return every page ``slot`` owns to the free lists (host
        bookkeeping only — the device table row goes stale, which is
        safe: the slot is inactive, and a page is scrubbed at its next
        admission before any live query can reach it)."""
        for owner, free in zip(self.owners, self.free):
            mine = np.nonzero(owner == slot)[0]
            owner[mine] = FREE
            free.extend(int(p) for p in mine[::-1])

    # -- audit + snapshot -------------------------------------------------

    def audit(self, state, active: np.ndarray, slot_req) -> list[str]:
        """Page-table well-formedness against the live device state:
        no page double-mapped by two active slots, no freed/null page
        reachable from an active slot's row, mapped rows owned
        consistently, and every owned page's owner actually live.
        Appends to (and returns) ``self.violations``."""
        nodes, _ = flatten_nodes(state)
        msgs = []
        for gi, (ni, g, owner) in enumerate(
                zip(self.kv_index, self.geoms, self.owners)):
            node = nodes[ni]
            tbl = np.asarray(node.page_table)
            if tbl.ndim == 3:
                tbl = tbl[0]
            seen: dict[int, int] = {}
            for slot in range(self.batch):
                if not active[slot]:
                    continue
                for page in tbl[slot]:
                    page = int(page)
                    if page < 0:
                        continue
                    if page == NULL_PAGE:
                        msgs.append(
                            f"node{gi}: active slot {slot} maps the null "
                            f"page")
                        continue
                    code = int(owner[page])
                    if code == FREE:
                        msgs.append(
                            f"node{gi}: active slot {slot} reaches freed "
                            f"page {page}")
                    elif code >= 0 and code != slot:
                        msgs.append(
                            f"node{gi}: page {page} double-mapped by "
                            f"active slots {code} and {slot}")
                    if page in seen and seen[page] != slot and code != SHARED:
                        msgs.append(
                            f"node{gi}: page {page} appears in rows "
                            f"{seen[page]} and {slot}")
                    seen[page] = slot
            for page in np.nonzero(owner >= 0)[0]:
                s = int(owner[page])
                if slot_req[s] < 0 and not active[s]:
                    msgs.append(
                        f"node{gi}: page {int(page)} leaked — owned by "
                        f"slot {s}, which holds no request")
        self.violations.extend(msgs)
        return msgs

    def snapshot_tree(self) -> dict[str, np.ndarray]:
        return {f"owner{i}": o.copy() for i, o in enumerate(self.owners)} | {
            "peak_mapped_bytes": np.int64(self.peak_mapped_bytes),
        }

    def restore(self, tree: dict[str, np.ndarray]):
        for i in range(len(self.owners)):
            self.owners[i] = np.asarray(tree[f"owner{i}"], np.int32).copy()
            self.free[i] = sorted(
                np.nonzero(self.owners[i] == FREE)[0].tolist(), reverse=True)
        self.peak_mapped_bytes = int(tree["peak_mapped_bytes"])


def upload_shared(state, controller: PagedController,
                  entries: dict[int, tuple[list, list]]):
    """Write each registered prefix's global-attention K/V into its
    reserved shared pages — once per serve, before any admission.  Share
    nodes have ``s_view == max_len`` (no wrap), so dense view position
    ``p`` of the prefix entry is exactly ring slot ``p``."""
    nodes, treedef = flatten_nodes(state)
    for gi, (ni, g) in enumerate(
            zip(controller.kv_index, controller.geoms)):
        if g.role != "share":
            continue
        node = nodes[ni]
        pool_k, pool_v = node.k, node.v
        for pid, (start, nsh) in sorted(controller.shared_map.items()):
            _, kv = entries[pid]
            ck, cv = kv[gi]

            def put(pool, content):
                # content: (1, Hkv, S, Dh) or stacked (L, 1, Hkv, S, Dh);
                # take the first nsh pages' worth of positions.
                span = nsh * g.page_size
                if content.ndim == 5:
                    src = content[:, 0, :, :span, :].transpose(0, 2, 1, 3)
                    src = src.reshape(content.shape[0], nsh, g.page_size,
                                      content.shape[2], content.shape[4])
                    return pool.at[:, start:start + nsh].set(
                        src.astype(pool.dtype))
                src = content[0, :, :span, :].transpose(1, 0, 2)
                src = src.reshape(nsh, g.page_size, content.shape[1],
                                  content.shape[3])
                return pool.at[start:start + nsh].set(src.astype(pool.dtype))

            pool_k, pool_v = put(pool_k, ck), put(pool_v, cv)
        nodes[ni] = PagedKVCache(pool_k, pool_v, node.page_table,
                                 node.length, node.s_view, node.page_size)
    return treedef.unflatten(nodes)


# --------------------------------------------------------------------------
# Device side: the admit-jit state surgery
# --------------------------------------------------------------------------


def _admit_kv_one(node: PagedKVCache, admit_row, prefix_rows, start_len,
                  table, scrub, content):
    """Unstacked per-node admission: install the new table rows, scrub
    freshly-mapped private pages of non-finite garbage, scatter prefix
    ring content into prefix rows (copy nodes), and set prefix rows'
    lengths to their start length."""
    b, nl = node.page_table.shape
    ps, s = node.page_size, node.s_view
    hkv, dh = node.k.shape[-2], node.k.shape[-1]
    new_table = jnp.where(admit_row[:, None], table, node.page_table)
    length = jnp.where(admit_row & prefix_rows, start_len, node.length)

    st = jnp.where(admit_row[:, None], scrub, -1)
    offs = jnp.arange(ps, dtype=jnp.int32)
    scrub_flat = jnp.where(
        st[:, :, None] >= 0, st[:, :, None] * ps + offs[None, None, :], -1
    ).reshape(-1)

    if content is not None:
        i = jnp.arange(s, dtype=jnp.int32)
        pages = jnp.take(new_table, i // ps, axis=1)            # (B, S)
        ok = (admit_row & prefix_rows)[:, None] & (pages >= 0)
        content_flat = jnp.where(
            ok, pages * ps + (i % ps)[None, :], -1).reshape(-1)

    def fix(pool, src_view):
        pf = pool.reshape(-1, hkv, dh)
        vals = jnp.take(pf, jnp.clip(scrub_flat, 0), axis=0)
        vals = jnp.where(jnp.isfinite(vals), vals,
                         jnp.zeros((), pool.dtype))
        pf = pf.at[scrub_flat].set(vals, mode="drop")
        if src_view is not None:
            src = jnp.broadcast_to(
                src_view[0].swapaxes(0, 1)[None], (b, s, hkv, dh))
            pf = pf.at[content_flat].set(
                src.reshape(b * s, hkv, dh).astype(pool.dtype), mode="drop")
        return pf.reshape(pool.shape)

    k = fix(node.k, None if content is None else content[0])
    v = fix(node.v, None if content is None else content[1])
    return PagedKVCache(k, v, new_table, length, s, ps)


def _admit_kv(node, admit_row, prefix_rows, start_len, table, scrub,
              content):
    if node.k.ndim == 4:
        return _admit_kv_one(node, admit_row, prefix_rows, start_len,
                             table, scrub, content)
    # Stacked (L, ...) node: same table for every layer, per-layer pools
    # and (for copy nodes) per-layer prefix content.
    fn = jax.vmap(
        lambda nd, ct: _admit_kv_one(nd, admit_row, prefix_rows, start_len,
                                     table, scrub, ct),
        in_axes=(0, None if content is None else 0),
    )
    return fn(node, content)


def _copy_rec(node: RecState, entry: RecState, rows):
    """Read-side dual of ``_reset_slot_rows``' recurrent zeroing: write
    the prefix entry's batch-1 WKV S / RG-LRU h / conv tails into the
    rows being admitted with a shared prefix (``jnp.where`` along batch —
    neighbors bit-identical, donation-friendly)."""
    extra = node.conv.ndim - 3

    def mix(leaf, src):
        m = rows.reshape((1,) * extra + (-1,) + (1,) * (leaf.ndim - extra - 1))
        return jnp.where(m, src.astype(leaf.dtype), leaf)

    return RecState(h=mix(node.h, entry.h), conv=mix(node.conv, entry.conv))


def apply_admission(state, roles, admit_row, prefix_rows, start_len,
                    tables, scrubs, rec_entries, ring_contents):
    """Device-side admission surgery (inside the admit jit, right after
    ``_reset_slot_rows``).  ``roles`` is the controller's static per-KV-
    node role tuple; ``tables``/``scrubs`` are per-KV-node ``(B, NL)``
    rows; ``rec_entries`` / ``ring_contents`` are the prefix entry's
    recurrent nodes and copy-node ``(k, v)`` views (zero-filled when the
    admission carries no prefix — ``prefix_rows`` gates every use)."""
    nodes, treedef = flatten_nodes(state)
    copy_rows = admit_row & prefix_rows
    kv_i = rec_i = copy_i = 0
    out = []
    for node in nodes:
        if isinstance(node, PagedKVCache):
            content = None
            if roles[kv_i] == "copy":
                content = ring_contents[copy_i]
                copy_i += 1
            out.append(_admit_kv(node, admit_row, prefix_rows, start_len,
                                 tables[kv_i], scrubs[kv_i], content))
            kv_i += 1
        elif isinstance(node, RecState):
            out.append(_copy_rec(node, rec_entries[rec_i], copy_rows))
            rec_i += 1
        else:
            out.append(node)
    return treedef.unflatten(out)
