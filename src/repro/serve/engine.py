"""Serving: prefill + decode steps and a batched greedy-decoding engine.

``make_prefill_step`` / ``make_decode_step`` are the lowering targets for
the ``prefill_*`` / ``decode_*`` / ``long_*`` shape cells; ``ServeEngine``
drives them for the runnable example (batched requests, greedy sampling).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.model import model as M


def make_prefill_step(cfg):
    """(params, tokens, **extras) -> logits (B, S, V).

    ``cfg.prefill_chunks > 1`` splits the request batch into chunks
    processed sequentially (a ``lax.scan``), bounding live activation /
    MoE-dispatch memory — sequences are independent, so this is exact.
    """
    from repro.core.lowering import scan_unroll

    def prefill_step(params, tokens, **kw):
        from repro.model.sharding import _CTX

        n = cfg.prefill_chunks
        b = tokens.shape[0]
        # Mesh-aware: never chunk below one sequence per data shard (chunked
        # batches that don't cover the batch-sharding axes lose parallelism
        # and force replication).
        if _CTX.mesh is not None and _CTX.rules is not None:
            data = _CTX.rules.get("batch")
            size = 1
            if data:
                for a in (data if isinstance(data, tuple) else (data,)):
                    size *= _CTX.mesh.shape[a]
            n = max(1, min(n, b // max(size, 1)))
        if n <= 1 or b % n:
            return M.forward(params, cfg, tokens, **kw)

        def split(x, batch_axis=0):
            return x.reshape(
                x.shape[:batch_axis] + (n, x.shape[batch_axis] // n)
                + x.shape[batch_axis + 1:]
            ).swapaxes(0, batch_axis) if batch_axis else x.reshape(
                (n, b // n) + x.shape[1:]
            )

        tk = split(tokens)
        kw_split = {}
        for key, v in kw.items():
            if key == "positions" and v.ndim == 3 and v.shape[0] == 3:
                kw_split[key] = jnp.moveaxis(
                    v.reshape(3, n, b // n, v.shape[2]), 1, 0
                )
            else:
                kw_split[key] = split(v)

        keys = sorted(kw_split)

        def chunk_fn(_, inputs):
            tok = inputs[0]
            kw_i = dict(zip(keys, inputs[1:]))
            return None, M.forward(params, cfg, tok, **kw_i)

        xs = (tk,) + tuple(kw_split[k] for k in keys)
        _, logits = jax.lax.scan(chunk_fn, None, xs, unroll=scan_unroll())
        return logits.reshape((b,) + logits.shape[2:])

    return prefill_step


# Prompts at/above this length route through the sequence-parallel rules
# by default; below it, sequence sharding costs more in summary hops than
# it saves in per-device work.
SEQ_PREFILL_MIN_T = 1024


def make_seq_prefill_step(cfg, mesh, *, min_len: int = SEQ_PREFILL_MIN_T):
    """Long-context prefill: run the base prefill under ``prefill_seq``
    sharding rules.

    With the sequence mapped to the model axis, recurrent blocks dispatch
    the sequence-parallel WKV path (:mod:`repro.kernels.wkv.seqpar`): each
    device sweeps its own sequence shard with the fused kernel and only
    the O(Dh²) (decay, state) segment summary crosses the ``seq`` axis —
    the prompt tokens are never re-gathered.  Prompts shorter than
    ``min_len`` fall back to the plain prefill rules, where sequence
    sharding would cost more in carry hops than it saves in per-device
    work.
    """
    from repro.model.sharding import make_rules, sharding_context

    base = make_prefill_step(cfg)
    seq_rules = make_rules(mesh, "prefill_seq")
    plain_rules = make_rules(mesh, "prefill")

    def prefill_step(params, tokens, **kw):
        rules = seq_rules if tokens.shape[1] >= min_len else plain_rules
        with mesh, sharding_context(mesh, rules):
            return base(params, tokens, **kw)

    return prefill_step


def make_decode_step(cfg):
    """(params, state, tokens (B,1), length ()) -> (logits, new_state)."""

    def decode_step(params, state, tokens, length, enc_out=None):
        return M.decode_step(params, cfg, state, tokens, length, enc_out=enc_out)

    return decode_step


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched greedy server: prefill token-by-token into the cache
    (correct for ring-buffer local layers too), then decode new tokens."""

    cfg: Any
    params: Any
    max_len: int = 256

    def __post_init__(self):
        cfg = self.cfg
        self._decode = jax.jit(
            lambda p, s, t, l: M.decode_step(p, cfg, s, t, l)
        )

    def generate(self, prompts: jax.Array, num_new_tokens: int) -> jax.Array:
        """prompts: (B, P) int32 -> (B, P + num_new_tokens)."""
        b, p_len = prompts.shape
        state = M.init_decode_state(self.cfg, batch=b, max_len=self.max_len)

        logits = None
        for i in range(p_len):
            logits, state = self._decode(
                self.params, state, prompts[:, i : i + 1], jnp.int32(i)
            )
        out = [prompts]
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for j in range(num_new_tokens):
            out.append(cur)
            if j == num_new_tokens - 1:
                break
            logits, state = self._decode(
                self.params, state, cur, jnp.int32(p_len + j)
            )
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return jnp.concatenate(out, axis=1)
