"""Serving: prefill + decode steps and a batched greedy-decoding engine.

``make_prefill_step`` / ``make_decode_step`` are the lowering targets for
the ``prefill_*`` / ``decode_*`` / ``long_*`` shape cells;
``make_cache_prefill_step`` fills the decode cache from a prompt in one
jit; ``ServeEngine`` drives them for the runnable example (batched
requests, greedy sampling) with a windowed, donated-state decode loop —
the serving rendering of the paper's loop-carried-value argument: the
decode state stays resident (device buffers donated in place, the WKV
state in VMEM within a window) instead of round-tripping per token.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.model import model as M


def make_prefill_step(cfg):
    """(params, tokens, **extras) -> logits (B, S, V).

    ``cfg.prefill_chunks > 1`` splits the request batch into chunks
    processed sequentially (a ``lax.scan``), bounding live activation /
    MoE-dispatch memory — sequences are independent, so this is exact.
    """
    from repro.core.lowering import scan_unroll

    def prefill_step(params, tokens, **kw):
        from repro.model.sharding import _CTX

        n = cfg.prefill_chunks
        b = tokens.shape[0]
        # Mesh-aware: never chunk below one sequence per data shard (chunked
        # batches that don't cover the batch-sharding axes lose parallelism
        # and force replication).
        if _CTX.mesh is not None and _CTX.rules is not None:
            data = _CTX.rules.get("batch")
            size = 1
            if data:
                for a in (data if isinstance(data, tuple) else (data,)):
                    size *= _CTX.mesh.shape[a]
            n = max(1, min(n, b // max(size, 1)))
        if n <= 1 or b % n:
            return M.forward(params, cfg, tokens, **kw)

        def split(x, batch_axis=0):
            return x.reshape(
                x.shape[:batch_axis] + (n, x.shape[batch_axis] // n)
                + x.shape[batch_axis + 1:]
            ).swapaxes(0, batch_axis) if batch_axis else x.reshape(
                (n, b // n) + x.shape[1:]
            )

        tk = split(tokens)
        kw_split = {}
        for key, v in kw.items():
            if key == "positions" and v.ndim == 3 and v.shape[0] == 3:
                kw_split[key] = jnp.moveaxis(
                    v.reshape(3, n, b // n, v.shape[2]), 1, 0
                )
            else:
                kw_split[key] = split(v)

        keys = sorted(kw_split)

        def chunk_fn(_, inputs):
            tok = inputs[0]
            kw_i = dict(zip(keys, inputs[1:]))
            return None, M.forward(params, cfg, tok, **kw_i)

        xs = (tk,) + tuple(kw_split[k] for k in keys)
        _, logits = jax.lax.scan(chunk_fn, None, xs, unroll=scan_unroll())
        return logits.reshape((b,) + logits.shape[2:])

    return prefill_step


# Prompts at/above this length route through the sequence-parallel rules
# by default; below it, sequence sharding costs more in summary hops than
# it saves in per-device work.
SEQ_PREFILL_MIN_T = 1024


def make_seq_prefill_step(cfg, mesh, *, min_len: int = SEQ_PREFILL_MIN_T):
    """Long-context prefill: run the base prefill under ``prefill_seq``
    sharding rules.

    With the sequence mapped to the model axis, recurrent blocks dispatch
    the sequence-parallel WKV path (:mod:`repro.kernels.wkv.seqpar`): each
    device sweeps its own sequence shard with the fused kernel and only
    the O(Dh²) (decay, state) segment summary crosses the ``seq`` axis —
    the prompt tokens are never re-gathered.  Prompts shorter than
    ``min_len`` fall back to the plain prefill rules, where sequence
    sharding would cost more in carry hops than it saves in per-device
    work.
    """
    from repro.model.sharding import make_rules, sharding_context

    base = make_prefill_step(cfg)
    seq_rules = make_rules(mesh, "prefill_seq")
    plain_rules = make_rules(mesh, "prefill")

    def prefill_step(params, tokens, **kw):
        rules = seq_rules if tokens.shape[1] >= min_len else plain_rules
        with mesh, sharding_context(mesh, rules):
            return base(params, tokens, **kw)

    return prefill_step


def make_decode_step(cfg):
    """(params, state, tokens (B,K), length ()) -> (logits, new_state).

    K >= 1: the window width rides straight through ``model.decode_step``
    (K == 1 is classic per-token decode)."""

    def decode_step(params, state, tokens, length, enc_out=None):
        return M.decode_step(params, cfg, state, tokens, length, enc_out=enc_out)

    return decode_step


def make_cache_prefill_step(cfg, mesh=None, *, min_len: int = SEQ_PREFILL_MIN_T,
                            last_only: bool = False):
    """One-jit prompt prefill *into the decode cache*.

    ``(params, state, tokens (B, P)) -> (logits (B, P, V), new_state)`` —
    the whole prompt goes through ``model.decode_step`` as a single window
    starting at position 0, so the KV caches and recurrent states fill in
    one dispatch instead of P sequential single-token calls (the WKV part
    takes the decode-window or chunked elevator kernel, not P state
    round-trips).  ``state`` is donated: XLA writes the caches in place.
    ``last_only=True`` returns logits for the final prompt position only
    ((B, 1, V)) — what a greedy serve loop consumes; the full (B, P, V)
    projection is for scoring callers.

    With ``mesh``, prompts of at least ``min_len`` tokens run under the
    ``prefill_seq`` sharding rules — the same routing rule as
    :func:`make_seq_prefill_step`, so long prompts compose with the
    sequence-parallel WKV path while the cache still fills in one jit;
    shorter prompts use the plain ``prefill`` rules.
    """
    from repro.model.sharding import make_rules, sharding_context

    def cache_prefill(params, state, tokens):
        return M.decode_step(params, cfg, state, tokens, jnp.int32(0),
                             last_only=last_only)

    if mesh is None:
        return jax.jit(cache_prefill, donate_argnums=(1,))
    # One jit wrapper per rules mode: the sharding context is read at
    # trace time, so a shared cache entry would freeze whichever rules
    # traced first.
    seq_jit = jax.jit(cache_prefill, donate_argnums=(1,))
    short_jit = jax.jit(cache_prefill, donate_argnums=(1,))
    seq_rules = make_rules(mesh, "prefill_seq")
    plain_rules = make_rules(mesh, "prefill")

    def prefill(params, state, tokens):
        fn, rules = (
            (seq_jit, seq_rules) if tokens.shape[1] >= min_len
            else (short_jit, plain_rules)
        )
        with mesh, sharding_context(mesh, rules):
            return fn(params, state, tokens)

    return prefill


@dataclasses.dataclass
class ServeEngine:
    """Batched greedy server: one-jit prompt prefill into the cache, then a
    scan-based decode loop over K-token windows with donated state.

    ``decode_window`` (K) is the number of tokens generated per decode
    dispatch: each dispatch is one jitted function whose body is a
    ``lax.scan`` over K single-token ``model.decode_step`` calls, with the
    decode state donated at the jit boundary — XLA aliases the KV caches
    and the (B, H, Dh, Dh) WKV states in place instead of copying them per
    step, and the per-dispatch Python/runtime overhead amortizes ~K×.
    ``generate`` issues exactly ``ceil(num_new_tokens / K)`` decode
    dispatches.

    ``mesh`` routes long prompts through the sequence-parallel prefill
    rules (see :func:`make_cache_prefill_step`).
    """

    cfg: Any
    params: Any
    max_len: int = 256
    decode_window: int = 8
    mesh: Any = None

    def __post_init__(self):
        cfg = self.cfg
        # Per-token fallback step (the decode_window=1 shape).  state is
        # donated here too: without it every step copies the full cache
        # pytree through HBM just to update one slot.
        self._decode = jax.jit(
            lambda p, s, t, l: M.decode_step(p, cfg, s, t, l),
            donate_argnums=(1,),
        )
        # last_only: generate() consumes only the final prompt position's
        # logits — don't materialize the (B, P, V) tensor at prefill.
        self._prefill = make_cache_prefill_step(cfg, self.mesh, last_only=True)
        self._windows = {}
        # Observability: decode dispatches issued by the last generate().
        self.last_decode_dispatches = 0

    def _window_step(self, k: int, last: bool):
        """Jitted K-token decode window, cached per (k, last).

        Emits the k tokens fed through the model and carries (state, next
        token, position).  The final window of a generation run stops one
        decode short — the last emitted token needs no successor — so it
        scans k-1 steps and appends the carried token.
        """
        fn = self._windows.get((k, last))
        if fn is None:
            cfg = self.cfg
            steps = k - 1 if last else k

            def win(p, state, cur, pos):
                def body(carry, _):
                    st, tok, ps = carry
                    logits, st = M.decode_step(p, cfg, st, tok, ps)
                    nxt = jnp.argmax(logits[:, -1], axis=-1)
                    nxt = nxt.astype(jnp.int32)[:, None]
                    return (st, nxt, ps + 1), tok

                (state, cur, pos), toks = jax.lax.scan(
                    body, (state, cur, pos), None, length=steps
                )
                toks = jnp.moveaxis(toks[..., 0], 0, 1)      # (B, steps)
                if last:
                    toks = jnp.concatenate([toks, cur], axis=1)
                return toks, state, cur, pos

            fn = jax.jit(win, donate_argnums=(1,))
            self._windows[(k, last)] = fn
        return fn

    def generate(self, prompts: jax.Array, num_new_tokens: int) -> jax.Array:
        """prompts: (B, P) int32 -> (B, P + num_new_tokens)."""
        b, p_len = prompts.shape
        k_w = max(1, int(self.decode_window))
        # insert_window sizes the local-attention ring slack for the widest
        # window any decode_step call inserts (the whole prompt at
        # prefill).  Bucketed to a multiple of 32 so the decode-state
        # shapes — and with them the cached window jits — don't recompile
        # for every distinct prompt length (extra slack is harmless: the
        # ring is capped at max_len either way).
        state = M.init_decode_state(
            self.cfg, batch=b, max_len=self.max_len,
            insert_window=max(k_w, -(-p_len // 32) * 32),
        )
        logits, state = self._prefill(self.params, state, prompts)
        self.last_decode_dispatches = 0
        if num_new_tokens <= 0:
            return prompts
        out = [prompts]
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        pos = jnp.int32(p_len)
        left = num_new_tokens
        while left > 0:
            k = min(k_w, left)
            fn = self._window_step(k, last=(k == left))
            toks, state, cur, pos = fn(self.params, state, cur, pos)
            self.last_decode_dispatches += 1
            out.append(toks)
            left -= k
        return jnp.concatenate(out, axis=1)
