"""Serving: prefill + decode steps, a batched greedy engine, and a
continuous-batching scheduler.

``make_prefill_step`` / ``make_decode_step`` are the lowering targets for
the ``prefill_*`` / ``decode_*`` / ``long_*`` shape cells;
``make_cache_prefill_step`` fills the decode cache from a prompt in one
jit; ``ServeEngine`` drives them for the runnable example with a
windowed, donated-state decode loop — the serving rendering of the
paper's loop-carried-value argument: the decode state stays resident
(device buffers donated in place, the WKV state in VMEM within a window)
instead of round-tripping per token.

``ServeEngine.generate`` is the *lockstep* loop: every request advances
one window at a time, padded to the longest — a workgroup-global barrier
at the serving layer, exactly the group-to-group pattern the paper argues
against.  ``ServeEngine.serve`` replaces it with per-request progress
(point-to-point hand-offs): each slot decodes at its own position, EOS
and per-request budgets are detected *inside* the jitted window, and a
freed slot is re-prefilled with the next queued request without touching
its neighbors' caches.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.watchdog import StepTimeout, StepWatchdog, StragglerDetector
from repro.model import model as M
from repro.model.attention import KVCache, PagedKVCache
from repro.model.recurrent import RecState
from repro.serve import paging

#: Optional dispatch-boundary hook, called as ``hook(phase, kind)`` with
#: phase ``"pre"``/``"post"`` around every fault-plumbed jit dispatch —
#: inside the watchdog worker thread when one is active.  The
#: deterministic interleaving drill (:mod:`repro.serve.interleave`)
#: installs a forced-preemption point here; production leaves it None.
dispatch_hook = None


def make_prefill_step(cfg):
    """(params, tokens, **extras) -> logits (B, S, V).

    ``cfg.prefill_chunks > 1`` splits the request batch into chunks
    processed sequentially (a ``lax.scan``), bounding live activation /
    MoE-dispatch memory — sequences are independent, so this is exact.
    """
    from repro.core.lowering import scan_unroll

    def prefill_step(params, tokens, **kw):
        from repro.model.sharding import _CTX

        n = cfg.prefill_chunks
        b = tokens.shape[0]
        # Mesh-aware: never chunk below one sequence per data shard (chunked
        # batches that don't cover the batch-sharding axes lose parallelism
        # and force replication).
        if _CTX.mesh is not None and _CTX.rules is not None:
            data = _CTX.rules.get("batch")
            size = 1
            if data:
                for a in (data if isinstance(data, tuple) else (data,)):
                    size *= _CTX.mesh.shape[a]
            n = max(1, min(n, b // max(size, 1)))
        if n <= 1 or b % n:
            return M.forward(params, cfg, tokens, **kw)

        def split(x, batch_axis=0):
            return x.reshape(
                x.shape[:batch_axis] + (n, x.shape[batch_axis] // n)
                + x.shape[batch_axis + 1:]
            ).swapaxes(0, batch_axis) if batch_axis else x.reshape(
                (n, b // n) + x.shape[1:]
            )

        tk = split(tokens)
        kw_split = {}
        for key, v in kw.items():
            if key == "positions" and v.ndim == 3 and v.shape[0] == 3:
                kw_split[key] = jnp.moveaxis(
                    v.reshape(3, n, b // n, v.shape[2]), 1, 0
                )
            else:
                kw_split[key] = split(v)

        keys = sorted(kw_split)

        def chunk_fn(_, inputs):
            tok = inputs[0]
            kw_i = dict(zip(keys, inputs[1:]))
            return None, M.forward(params, cfg, tok, **kw_i)

        xs = (tk,) + tuple(kw_split[k] for k in keys)
        _, logits = jax.lax.scan(chunk_fn, None, xs, unroll=scan_unroll())
        return logits.reshape((b,) + logits.shape[2:])

    return prefill_step


# Prompts at/above this length route through the sequence-parallel rules
# by default; below it, sequence sharding costs more in summary hops than
# it saves in per-device work.
SEQ_PREFILL_MIN_T = 1024


def make_seq_prefill_step(cfg, mesh, *, min_len: int = SEQ_PREFILL_MIN_T):
    """Long-context prefill: run the base prefill under ``prefill_seq``
    sharding rules.

    With the sequence mapped to the model axis, recurrent blocks dispatch
    the sequence-parallel WKV path (:mod:`repro.kernels.wkv.seqpar`): each
    device sweeps its own sequence shard with the fused kernel and only
    the O(Dh²) (decay, state) segment summary crosses the ``seq`` axis —
    the prompt tokens are never re-gathered.  Prompts shorter than
    ``min_len`` fall back to the plain prefill rules, where sequence
    sharding would cost more in carry hops than it saves in per-device
    work.
    """
    from repro.model.sharding import make_rules, sharding_context

    base = make_prefill_step(cfg)
    seq_rules = make_rules(mesh, "prefill_seq")
    plain_rules = make_rules(mesh, "prefill")

    def prefill_step(params, tokens, **kw):
        rules = seq_rules if tokens.shape[1] >= min_len else plain_rules
        with mesh, sharding_context(mesh, rules):
            return base(params, tokens, **kw)

    return prefill_step


def make_decode_step(cfg):
    """(params, state, tokens (B,K), length ()) -> (logits, new_state).

    K >= 1: the window width rides straight through ``model.decode_step``
    (K == 1 is classic per-token decode)."""

    def decode_step(params, state, tokens, length, enc_out=None):
        return M.decode_step(params, cfg, state, tokens, length, enc_out=enc_out)

    return decode_step


def make_cache_prefill_step(cfg, mesh=None, *, min_len: int = SEQ_PREFILL_MIN_T,
                            last_only: bool = False, max_len: int | None = None):
    """One-jit prompt prefill *into the decode cache*.

    ``(params, state, tokens (B, P)[, prompt_lengths (B,)]) ->
    (logits (B, P, V), new_state)`` — the whole prompt goes through
    ``model.decode_step`` as a single window starting at position 0, so
    the KV caches and recurrent states fill in one dispatch instead of P
    sequential single-token calls (the WKV part takes the decode-window
    or chunked elevator kernel, not P state round-trips).  ``state`` is
    donated: XLA writes the caches in place.  ``last_only=True`` returns
    logits for the final prompt position only ((B, 1, V)) — what a
    greedy serve loop consumes; the full (B, P, V) projection is for
    scoring callers.

    ``prompt_lengths`` masks ragged prompts: request b's tokens beyond
    ``prompt_lengths[b]`` are padding and contribute *nothing* to any
    state — pad tokens never enter the KV caches or the WKV/RG-LRU
    recurrent states (they used to, silently polluting every request
    shorter than the batch max), each request's cache length ends at its
    own prompt length, and with ``last_only`` the logits are taken at
    each request's final *valid* position.

    ``max_len`` (the position cap the state was built with) is forwarded
    to ``model.decode_step``'s ring-slack trace check.

    With ``mesh``, prompts of at least ``min_len`` tokens run under the
    ``prefill_seq`` sharding rules — the same routing rule as
    :func:`make_seq_prefill_step`, so long prompts compose with the
    sequence-parallel WKV path while the cache still fills in one jit;
    shorter prompts use the plain ``prefill`` rules.
    """
    from repro.model.sharding import make_rules, sharding_context

    def cache_prefill(params, state, tokens, prompt_lengths=None):
        mask = None
        if prompt_lengths is not None:
            p = tokens.shape[1]
            mask = (
                jnp.arange(p, dtype=jnp.int32)[None, :]
                < jnp.asarray(prompt_lengths, jnp.int32)[:, None]
            )
        return M.decode_step(params, cfg, state, tokens, jnp.int32(0),
                             last_only=last_only, token_mask=mask,
                             max_len=max_len)

    if mesh is None:
        return jax.jit(cache_prefill, donate_argnums=(1,))
    # One jit wrapper per rules mode: the sharding context is read at
    # trace time, so a shared cache entry would freeze whichever rules
    # traced first.
    seq_jit = jax.jit(cache_prefill, donate_argnums=(1,))
    short_jit = jax.jit(cache_prefill, donate_argnums=(1,))
    seq_rules = make_rules(mesh, "prefill_seq")
    plain_rules = make_rules(mesh, "prefill")

    def prefill(params, state, tokens, prompt_lengths=None):
        fn, rules = (
            (seq_jit, seq_rules) if tokens.shape[1] >= min_len
            else (short_jit, plain_rules)
        )
        with mesh, sharding_context(mesh, rules):
            return fn(params, state, tokens, prompt_lengths)

    return prefill


def audit_jit_entrypoints(cfg, *, batch: int = 2, max_len: int = 64,
                          decode_window: int = 4, prompt: int = 32):
    """Registration hook for :mod:`repro.analysis.donation`: every jit the
    serve engine dispatches, with abstract arguments sufficient to lower
    it (nothing executes — params and state are ShapeDtypeStructs).

    Adding a jit to the engine means adding it here; the donation pass
    audits exactly this list, so an unregistered jit is a review-visible
    gap rather than a silently un-audited one.
    """
    from repro.analysis.donation import JitEntry

    sds = jax.ShapeDtypeStruct
    eng = ServeEngine(cfg, params=M.abstract_params(cfg), max_len=max_len,
                      decode_window=decode_window)
    k = max(1, decode_window)
    p = _bucket32(prompt)
    params = eng.params
    state = M.abstract_decode_state(
        cfg, batch=batch, max_len=max_len,
        insert_window=max(k, _bucket32(prompt)),
    )
    i32, b = jnp.int32, batch
    vec = sds((b,), i32)
    key = sds((2,), jnp.uint32)
    here = "src/repro/serve/engine.py:ServeEngine"
    return [
        JitEntry(
            "serve.decode_step", eng._decode,
            (params, state, sds((b, 1), i32), sds((), i32)),
            f"{here}.__post_init__", donor="_decode",
        ),
        JitEntry(
            "serve.prefill", eng._prefill,
            (params, state, sds((b, p), i32), vec),
            "src/repro/serve/engine.py:make_cache_prefill_step",
            donor="make_cache_prefill_step",
        ),
        JitEntry(
            "serve.window", eng._window_step(k, last=False),
            (params, state, sds((b, 1), i32), vec),
            f"{here}._window_step", donor="_window_step",
        ),
        JitEntry(
            "serve.serve_window", eng._serve_window(k, 0.0, 0, None),
            (params, state, sds((b, 1), i32), vec, vec, vec,
             sds((b,), jnp.bool_), vec, key),
            f"{here}._serve_window", donor="_serve_window",
        ),
        JitEntry(
            "serve.admit", eng._admit_step(p, 0.0, 0, None),
            (params, state, sds((b, p), i32), sds((b,), jnp.bool_), vec,
             vec, vec, vec, vec, vec, sds((b,), jnp.bool_),
             sds((b, 1), i32), key),
            f"{here}._admit_step", donor="_admit_step",
        ),
        JitEntry(
            "serve.shadow_checksum", eng._shadow_csum, (state,),
            f"{here}.__post_init__", donated=None, donate_argnums=None,
        ),
    ] + _paged_jit_entrypoints(cfg, batch=batch, max_len=max_len,
                               decode_window=decode_window, prompt=prompt)


def _paged_jit_entrypoints(cfg, *, batch, max_len, decode_window, prompt):
    """Paged-engine jits for the donation audit: the decode window lowered
    against a pooled state (donation must alias the pools in place), and
    the paged admit with its page-table / prefix-entry operands."""
    from repro.analysis.donation import JitEntry

    sds = jax.ShapeDtypeStruct
    eng = ServeEngine(cfg, params=M.abstract_params(cfg), max_len=max_len,
                      decode_window=decode_window, paged=True)
    k = max(1, decode_window)
    p = _bucket32(prompt)
    iw = max(k, p)
    params = eng.params
    state = M.abstract_decode_state(
        cfg, batch=batch, max_len=max_len, insert_window=iw,
        paged=M.PageSpec(page_size=eng.page_size),
    )
    ctl = paging.PagedController(cfg, state, batch=batch, max_len=max_len)
    entry = M.abstract_decode_state(cfg, batch=1, max_len=max_len,
                                    insert_window=iw)
    rec, kv = paging.split_entry(entry)
    ring = [kv[i] for i, role in enumerate(ctl.roles) if role == "copy"]
    tables = [sds((batch, g.nl), jnp.int32) for g in ctl.geoms]
    i32, b = jnp.int32, batch
    vec = sds((b,), i32)
    bvec = sds((b,), jnp.bool_)
    key = sds((2,), jnp.uint32)
    here = "src/repro/serve/engine.py:ServeEngine"
    return [
        JitEntry(
            "serve.paged_window", eng._serve_window(k, 0.0, 0, None),
            (params, state, sds((b, 1), i32), vec, vec, vec, bvec, vec,
             key),
            f"{here}._serve_window", donor="_serve_window",
        ),
        JitEntry(
            "serve.paged_admit",
            eng._admit_step_paged(p, 0.0, 0, None, ctl.roles),
            (params, state, sds((b, p), i32), bvec, vec, vec, bvec,
             tables, tables, rec, ring, vec, vec, vec, vec, vec, bvec,
             sds((b, 1), i32), key),
            f"{here}._admit_step_paged", donor="_admit_step_paged",
        ),
    ]


@dataclasses.dataclass
class Request:
    """One serve request: a prompt, a generation budget, and an optional
    wall-clock deadline (milliseconds from serve start; ``None`` falls
    back to the serve-level default, which may itself be ``None`` = no
    deadline)."""

    tokens: Any                    # (P,) int prompt token ids
    max_new_tokens: int = 16
    deadline_ms: float | None = None
    #: Paged engines only: id from :meth:`ServeEngine.register_prefix`.
    #: The prompt must extend the registered prefix; its page-aligned head
    #: is admitted by sharing/copying the prefix entry instead of being
    #: re-prefilled.
    prefix_id: int | None = None


#: Terminal per-request outcomes (see :class:`RequestResult`):
#:   ok        — completed by exhausting its token budget
#:   eos       — completed by sampling ``eos_id``
#:   deadline  — killed at its wall-clock deadline (tokens are partial)
#:   shed      — rejected at admission: the bounded queue was full
#:   dropped   — chaos/client drop mid-flight (tokens are partial)
#:   recovered — completed (budget or EOS) after >= 1 quarantine+re-prefill
#:   corrupt   — checksum-detected silent corruption recurred past the
#:               recovery cap (tokens are the last verified prefix)
OUTCOMES = ("ok", "eos", "deadline", "shed", "dropped", "recovered",
            "corrupt")

#: ``last_serve_stats`` keys, in the (fixed) order they are packed into
#: the snapshot stats vector — append only, never reorder.
SERVE_STAT_KEYS = (
    "decode_dispatches", "admissions", "slot_steps", "quarantines",
    "recoveries", "dispatch_retries", "dispatch_drops",
    "watchdog_timeouts", "stragglers", "deadline_hits", "shed",
    "req_drops", "snapshots", "page_waits", "prefix_admissions",
    "corruptions", "checksum_spot_checks",
)

#: A request whose checksum-detected corruption recurs past this many
#: recovery attempts ends with the terminal ``corrupt`` outcome instead
#: of cycling forever (a persistently corrupting slot is a hardware
#: problem, not a retry problem).
MAX_CORRUPTION_RECOVERIES = 3


@dataclasses.dataclass
class RequestResult:
    """One served request's tokens plus its typed outcome.

    Array-like (``__array__`` / ``len`` / indexing / ``.size`` /
    ``.tolist``) so result lists drop into code written against the bare
    token-array contract; ``outcome`` and ``recoveries`` carry the
    fault-isolation story (how the request ended, and how many
    quarantine+re-prefill cycles it survived on the way).
    """

    tokens: np.ndarray
    outcome: str = "ok"
    recoveries: int = 0

    def __array__(self, dtype=None, copy=None):
        a = self.tokens if dtype is None else self.tokens.astype(dtype)
        return a.copy() if copy else a

    def __len__(self):
        return int(self.tokens.size)

    def __iter__(self):
        return iter(self.tokens)

    def __getitem__(self, i):
        return self.tokens[i]

    @property
    def size(self) -> int:
        return int(self.tokens.size)

    def tolist(self):
        return self.tokens.tolist()


def _bucket32(length: int) -> int:
    """Prompt-length bucket (next multiple of 32): one shared rounding for
    admission jit-cache keys and local-ring ``insert_window`` sizing, so
    the two can't silently diverge."""
    return -(-max(int(length), 1) // 32) * 32


def _reset_slot_rows(state, rows: jax.Array):
    """Zero the decode state of the slots marked in ``rows`` (B,) bool —
    and only those: neighbors' caches are untouched (a ``jnp.where`` per
    leaf along the batch axis, no reallocation, donation-friendly).

    Per-request cache lengths and recurrent states reset to zero; *finite*
    KV cache contents are left in place — with length 0 no stale slot is
    reachable (the positional masks in ``_decode_attention`` only admit
    slots whose absolute position is below the slot's own query
    positions, and those get overwritten by the new prompt's insert).
    Non-finite KV entries in the reset rows are scrubbed to zero: a
    masked slot contributes ``weight 0 × value``, which is exactly 0 for
    finite stale values but NaN for a poisoned row — masking hides stale
    data, it does not disarm NaNs, so quarantine recovery must scrub
    them (a no-op rewrite for healthy rows, bit-identical fault-free).
    """

    def fix(node):
        if isinstance(node, KVCache):
            extra = node.k.ndim - 4              # stacked (L, B, ...) or not
            m = rows.reshape((1,) * extra + (-1,))
            mk = rows.reshape((1,) * extra + (-1, 1, 1, 1))

            def scrub(a):
                return jnp.where(
                    mk & ~jnp.isfinite(a), jnp.zeros((), a.dtype), a
                )

            return KVCache(
                k=scrub(node.k), v=scrub(node.v),
                length=jnp.where(m, 0, node.length),
            )
        if isinstance(node, RecState):
            extra = node.conv.ndim - 3

            def zero(leaf):
                m = rows.reshape(
                    (1,) * extra + (-1,) + (1,) * (leaf.ndim - extra - 1)
                )
                return jnp.where(m, jnp.zeros((), leaf.dtype), leaf)

            return RecState(h=zero(node.h), conv=zero(node.conv))
        if isinstance(node, PagedKVCache):
            # Paged rendering of the reset: unmap the rows (and zero their
            # lengths) — pool *contents* stay put, since pages are shared
            # storage.  Stale finite data is unreachable (length 0 +
            # positional masks) and non-finite garbage is scrubbed when a
            # page is next mapped (:func:`repro.serve.paging._admit_kv_one`).
            extra = node.page_table.ndim - 2
            m = rows.reshape((1,) * extra + (-1,))
            return PagedKVCache(
                k=node.k, v=node.v,
                page_table=jnp.where(m[..., None], -1, node.page_table),
                length=jnp.where(m, 0, node.length),
                s_view=node.s_view, page_size=node.page_size,
            )
        raise TypeError(type(node))

    return jax.tree.map(
        fix, state,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache, RecState)),
    )


def _sample_tokens(logits, base_key, req_ids, tok_idx, temperature, top_k):
    """Sample one token per slot from ``logits`` (B, V).

    ``temperature <= 0`` is greedy argmax.  Otherwise temperature/top-k
    categorical with a per-slot PRNG key derived as
    ``fold_in(fold_in(base_key, req_ids[b]), tok_idx[b])`` — a pure
    function of (request id, token index), so a request's sampled stream
    is invariant to the decode window K, to which slot it landed in, and
    to what its batch neighbors are doing.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / float(temperature)
    if top_k and top_k < lg.shape[-1]:
        kth = jax.lax.top_k(lg, int(top_k))[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)

    def one(rid, n, row):
        key = jax.random.fold_in(jax.random.fold_in(base_key, rid), n)
        return jax.random.categorical(key, row)

    return jax.vmap(one)(req_ids, tok_idx, lg).astype(jnp.int32)


@dataclasses.dataclass
class ServeEngine:
    """Batched greedy server: one-jit prompt prefill into the cache, then a
    scan-based decode loop over K-token windows with donated state.

    ``decode_window`` (K) is the number of tokens generated per decode
    dispatch: each dispatch is one jitted function whose body is a
    ``lax.scan`` over K single-token ``model.decode_step`` calls, with the
    decode state donated at the jit boundary — XLA aliases the KV caches
    and the (B, H, Dh, Dh) WKV states in place instead of copying them per
    step, and the per-dispatch Python/runtime overhead amortizes ~K×.
    ``generate`` issues exactly ``ceil(num_new_tokens / K)`` decode
    dispatches.

    ``mesh`` routes long prompts through the sequence-parallel prefill
    rules (see :func:`make_cache_prefill_step`).

    ``serve(requests)`` is the continuous-batching scheduler on top of the
    same jitted pieces: per-request lengths, in-window sampling and EOS
    detection, and slot recycling (see :meth:`serve`).
    """

    cfg: Any
    params: Any
    max_len: int = 256
    decode_window: int = 8
    mesh: Any = None
    #: Paged KV storage: ``serve()`` replaces per-slot dense caches with
    #: page pools + per-slot page tables (see :mod:`repro.serve.paging`).
    #: ``page_size`` must be a multiple of the 32-token admit bucket;
    #: ``pool_pages`` caps allocatable private pages per KV node pool
    #: (``None`` = dense-equivalent capacity, which can never starve).
    paged: bool = False
    page_size: int = 32
    pool_pages: int | None = None

    def __post_init__(self):
        cfg = self.cfg
        if self.paged:
            if self.page_size < 32 or self.page_size % 32:
                raise ValueError(
                    f"page_size must be a positive multiple of the 32-token "
                    f"admit bucket, got {self.page_size}"
                )
            if self.mesh is not None:
                raise NotImplementedError(
                    "paged serving does not compose with a mesh yet"
                )
        # Per-token fallback step (the decode_window=1 shape).  state is
        # donated here too: without it every step copies the full cache
        # pytree through HBM just to update one slot.
        self._decode = jax.jit(
            lambda p, s, t, l: M.decode_step(p, cfg, s, t, l),
            donate_argnums=(1,),
        )
        # last_only: generate() consumes only the final prompt position's
        # logits — don't materialize the (B, P, V) tensor at prefill.
        self._prefill = make_cache_prefill_step(
            cfg, self.mesh, last_only=True, max_len=self.max_len
        )
        # Shadow checksum: the host-side spot check recomputes the state
        # checksum out-of-band and compares it to the last emitted one.
        # Read-only by construction — donating here would consume the
        # live decode state the serve loop still owns.
        self._shadow_csum = jax.jit(M.decode_state_checksum)
        self._windows = {}
        self._admits = {}
        self._admits_paged = {}
        self._serve_windows = {}
        # Prefix registry: id -> prompt tokens; entries cache the one-time
        # batch-1 prefill of a prefix's page-aligned head per insert
        # window (its recurrent states + dense KV views).
        self._prefixes: dict[int, np.ndarray] = {}
        self._prefix_entries: dict = {}
        self._null_entries: dict = {}
        self._next_prefix_id = 0
        # Observability: decode dispatches issued by the last generate().
        self.last_decode_dispatches = 0
        # serve() counters: decode dispatches / admission prefills /
        # total slot-steps scanned (incl. masked dead-slot steps).
        self.last_serve_stats: dict[str, int] = {}
        # Paged byte accounting from the last paged serve(): pool vs
        # dense-equivalent bytes, peak mapped bytes, audit violations.
        self.last_paged_stats: dict[str, int] = {}

    def _window_step(self, k: int, last: bool):
        """Jitted K-token decode window, cached per (k, last).

        Emits the k tokens fed through the model and carries (state, next
        token, position).  The final window of a generation run stops one
        decode short — the last emitted token needs no successor — so it
        scans k-1 steps and appends the carried token.
        """
        fn = self._windows.get((k, last))
        if fn is None:
            cfg = self.cfg
            steps = k - 1 if last else k

            def win(p, state, cur, pos):
                def body(carry, _):
                    st, tok, ps = carry
                    logits, st = M.decode_step(p, cfg, st, tok, ps)
                    nxt = jnp.argmax(logits[:, -1], axis=-1)
                    nxt = nxt.astype(jnp.int32)[:, None]
                    return (st, nxt, ps + 1), tok

                (state, cur, pos), toks = jax.lax.scan(
                    body, (state, cur, pos), None, length=steps
                )
                toks = jnp.moveaxis(toks[..., 0], 0, 1)      # (B, steps)
                if last:
                    toks = jnp.concatenate([toks, cur], axis=1)
                return toks, state, cur, pos

            fn = jax.jit(win, donate_argnums=(1,))
            self._windows[(k, last)] = fn
        return fn

    # ------------------------------------------------------------------
    # Continuous batching: admission + masked decode windows
    # ------------------------------------------------------------------

    def _admit_step(self, p: int, temperature: float, top_k: int,
                    eos_id: int | None):
        """Jitted slot admission, cached per (prompt bucket, sampling cfg).

        Re-prefills the admitted slots' prompts into the shared decode
        state without touching neighbors: admitted rows are zeroed
        (:func:`_reset_slot_rows`), then one masked ``decode_step`` call
        runs the whole (B, P) batch with a token mask that is all-False
        outside the admitted rows — so every other slot's KV cache,
        recurrent state, and length are bit-identical afterwards.  Also
        samples each admitted slot's next token at its ``tok_idx``:
        0 for a fresh request (its first token), n for a quarantine
        recovery whose "prompt" is the original prompt plus the n
        already-accepted tokens — the ``fold_in(req_id, token_idx)``
        sampling keys then guarantee the resumed stream is the one the
        fault interrupted.

        With an engine ``mesh`` the admission prefill runs under the same
        sharding rules :func:`make_cache_prefill_step` would pick for a
        prompt of this bucket (``prefill_seq`` at/above
        :data:`SEQ_PREFILL_MIN_T`, plain ``prefill`` below).
        """
        key = (p, temperature, top_k, eos_id)
        fn = self._admits.get(key)
        if fn is None:
            cfg, max_len = self.cfg, self.max_len

            def admit(params, state, tokens, admit_row, plen, tok_idx,
                      lengths, counts, budgets, req_ids, active, cur,
                      base_key):
                # Entry checksum: the state as handed to this dispatch,
                # *before* any mutation — the host chains it against the
                # previous dispatch's exit checksum to catch silent
                # corruption of non-admitted rows between dispatches.
                csum_in = M.decode_state_checksum(state)
                state = _reset_slot_rows(state, admit_row)
                mask = admit_row[:, None] & (
                    jnp.arange(p, dtype=jnp.int32)[None, :] < plen[:, None]
                )
                logits, state = M.decode_step(
                    params, cfg, state, tokens, jnp.int32(0),
                    token_mask=mask, last_only=True, max_len=max_len,
                )
                tok0 = _sample_tokens(
                    logits[:, -1], base_key, req_ids, tok_idx,
                    temperature, top_k,
                )
                lengths = jnp.where(admit_row, plen, lengths)
                counts = jnp.where(admit_row, tok_idx + 1, counts)
                done = counts >= budgets
                if eos_id is not None:
                    done |= tok0 == eos_id
                active = jnp.where(admit_row, ~done, active)
                cur = jnp.where(admit_row[:, None], tok0[:, None], cur)
                csum_out = M.decode_state_checksum(state)
                return (state, lengths, counts, active, cur, tok0,
                        csum_in, csum_out)

            fn = jax.jit(admit, donate_argnums=(1,))
            if self.mesh is not None:
                from repro.model.sharding import make_rules, sharding_context

                mesh = self.mesh
                rules = make_rules(
                    mesh,
                    "prefill_seq" if p >= SEQ_PREFILL_MIN_T else "prefill",
                )
                jitted = fn

                def fn(*args):
                    with mesh, sharding_context(mesh, rules):
                        return jitted(*args)

            self._admits[key] = fn
        return fn

    # -- paged admission + prefix sharing --------------------------------

    def register_prefix(self, tokens) -> int:
        """Register a shared prompt prefix (paged engines only).

        Returns an id for :attr:`Request.prefix_id`.  The prefix's
        page-aligned head (``floor(len / page_size) × page_size`` tokens)
        is prefilled once per serve; every request carrying the id is
        admitted by *sharing* the resulting KV pages (full-view nodes) /
        *copying* the ring content and recurrent states (wrapping local
        nodes, WKV S / RG-LRU h) instead of re-prefilling those tokens.
        Prompts must strictly extend the prefix.
        """
        if not self.paged:
            raise ValueError("prefix sharing requires a paged engine")
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if toks.size < self.page_size:
            raise ValueError(
                f"prefix of {toks.size} tokens is shorter than one "
                f"{self.page_size}-token page — nothing to share"
            )
        if toks.size >= self.max_len:
            raise ValueError(
                f"prefix of {toks.size} tokens leaves no room to decode "
                f"within max_len={self.max_len}"
            )
        pid = self._next_prefix_id
        self._next_prefix_id += 1
        self._prefixes[pid] = toks
        return pid

    def _prefix_entry(self, pid: int, insert_window: int):
        """(start_len, rec nodes, per-KV-node dense (k, v) views) for a
        registered prefix: one batch-1 dense prefill of its page-aligned
        head, cached per (prefix, insert window) — the shared state every
        prefix admission copies from (``insert_window`` is the serve's,
        which always covers the prefix: prompts extend it, and the window
        is bucketed from the longest prompt)."""
        key = (pid, insert_window)
        ent = self._prefix_entries.get(key)
        if ent is None:
            toks = self._prefixes[pid]
            start = (toks.size // self.page_size) * self.page_size
            st = M.init_decode_state(
                self.cfg, batch=1, max_len=self.max_len,
                insert_window=insert_window,
            )
            _, st = self._prefill(
                self.params, st, jnp.asarray(toks[:start])[None, :], None)
            rec, kv = paging.split_entry(st)
            ent = (start, rec, kv)
            self._prefix_entries[key] = ent
        return ent

    def _null_entry(self, insert_window: int):
        """Zero-filled prefix-entry operands (rec nodes + dense KV views)
        for admissions that carry no prefix — every use inside the jit is
        gated on ``prefix_rows``, so the zeros are never observable."""
        ent = self._null_entries.get(insert_window)
        if ent is None:
            st = M.init_decode_state(
                self.cfg, batch=1, max_len=self.max_len,
                insert_window=insert_window,
            )
            ent = paging.split_entry(st)
            self._null_entries[insert_window] = ent
        return ent

    def _admit_step_paged(self, p: int, temperature: float, top_k: int,
                          eos_id: int | None, roles: tuple):
        """Paged slot admission, cached per (suffix bucket, sampling cfg,
        KV-node roles).  The dense :meth:`_admit_step` plus the page-table
        surgery of :func:`repro.serve.paging.apply_admission`: admitted
        rows are unmapped and re-pointed at freshly reserved pages (which
        are scrubbed of non-finite garbage on the way in), prefix rows
        start from the shared entry's recurrent state / ring content at
        ``start_len``, and only the prompt *suffix* beyond ``start_len``
        is prefilled — the cost win the pool exists for.
        """
        key = (p, temperature, top_k, eos_id, roles)
        fn = self._admits_paged.get(key)
        if fn is None:
            cfg, max_len = self.cfg, self.max_len

            def admit(params, state, tokens, admit_row, plen, start_len,
                      prefix_rows, tables, scrubs, rec_entries,
                      ring_contents, tok_idx, lengths, counts, budgets,
                      req_ids, active, cur, base_key):
                csum_in = M.decode_state_checksum(state)
                state = _reset_slot_rows(state, admit_row)
                state = paging.apply_admission(
                    state, roles, admit_row, prefix_rows, start_len,
                    tables, scrubs, rec_entries, ring_contents,
                )
                mask = admit_row[:, None] & (
                    jnp.arange(p, dtype=jnp.int32)[None, :] < plen[:, None]
                )
                logits, state = M.decode_step(
                    params, cfg, state, tokens, start_len,
                    token_mask=mask, last_only=True, max_len=max_len,
                )
                tok0 = _sample_tokens(
                    logits[:, -1], base_key, req_ids, tok_idx,
                    temperature, top_k,
                )
                lengths = jnp.where(admit_row, start_len + plen, lengths)
                counts = jnp.where(admit_row, tok_idx + 1, counts)
                done = counts >= budgets
                if eos_id is not None:
                    done |= tok0 == eos_id
                active = jnp.where(admit_row, ~done, active)
                cur = jnp.where(admit_row[:, None], tok0[:, None], cur)
                csum_out = M.decode_state_checksum(state)
                return (state, lengths, counts, active, cur, tok0,
                        csum_in, csum_out)

            fn = jax.jit(admit, donate_argnums=(1,))
            self._admits_paged[key] = fn
        return fn

    def _serve_window(self, k: int, temperature: float, top_k: int,
                      eos_id: int | None):
        """Jitted continuous decode window, cached per (k, sampling cfg).

        A ``lax.scan`` of k single-token ``decode_step`` calls with
        per-slot lengths.  Each step: finished/empty slots are masked out
        of the model (``token_mask`` freezes their caches and recurrent
        states via ``jnp.where`` — bit-identical across the window), the
        next token is sampled in-window (temperature / top-k with the
        per-request PRNG key), and EOS / budget exhaustion flips the
        slot's ``active`` bit *inside the jit* — the host only sees the
        window-level result.

        Fault detection rides the same scan at zero extra dispatches: a
        per-slot finiteness flag (``isfinite`` reduced over the recurrent
        states — :func:`repro.model.model.decode_state_finite` — plus the
        slot's own logits row, which covers NaN KV rows the moment they
        are attended) *quarantines* a poisoned slot inside the jit: its
        ``active`` bit flips off, so the very next step's ``token_mask``
        freezes its state via the existing dead-slot machinery, its
        garbage token is never emitted, and — because every per-slot
        update is a ``jnp.where`` along batch — its neighbors' streams
        stay bit-identical.  The quarantine mask (B,) comes back to the
        host, which re-prefills the victim from its accepted prefix.

        Silent-corruption detection rides the same dispatch: the window
        emits a per-slot state checksum at *entry* (the state exactly as
        received) and at *exit*
        (:func:`repro.model.model.decode_state_checksum` — integer
        wraparound sums of the raw state bits, so the comparison is exact
        and reduction-order-free).  The host chains exit(n) == entry(n+1):
        anything that flips state bits between dispatches — a finite-but-
        wrong bit flip the ``isfinite`` quarantine can never see — breaks
        the chain at the very next window.

        Emits (tokens (k, B), emit-mask (k, B), quarantined (B,),
        entry/exit checksums (B,) uint32).
        """
        key = (k, temperature, top_k, eos_id)
        fn = self._serve_windows.get(key)
        if fn is None:
            cfg, max_len = self.cfg, self.max_len

            def win(params, state, cur, lengths, counts, budgets, active,
                    req_ids, base_key):
                csum_in = M.decode_state_checksum(state)
                quar0 = jnp.zeros_like(active)

                def body(carry, _):
                    state, cur, lengths, counts, active, quar = carry
                    logits, state = M.decode_step(
                        params, cfg, state, cur, lengths,
                        token_mask=active[:, None], last_only=True,
                        max_len=max_len,
                    )
                    lg = logits[:, -1]
                    finite = M.decode_state_finite(state) & jnp.all(
                        jnp.isfinite(lg.astype(jnp.float32)), axis=-1
                    )
                    bad = active & ~finite
                    quar = quar | bad
                    active = active & ~bad
                    nxt = _sample_tokens(
                        lg, base_key, req_ids, counts, temperature, top_k,
                    )
                    emit = active
                    lengths = lengths + emit.astype(jnp.int32)
                    counts = counts + emit.astype(jnp.int32)
                    done = counts >= budgets
                    if eos_id is not None:
                        done |= nxt == eos_id
                    active = active & ~done
                    cur = jnp.where(emit[:, None], nxt[:, None], cur)
                    return (
                        (state, cur, lengths, counts, active, quar),
                        (nxt, emit),
                    )

                (state, cur, lengths, counts, active, quar), (toks, emits) = (
                    jax.lax.scan(
                        body,
                        (state, cur, lengths, counts, active, quar0), None,
                        length=k,
                    )
                )
                csum_out = M.decode_state_checksum(state)
                return (state, cur, lengths, counts, active, quar, toks,
                        emits, csum_in, csum_out)

            fn = jax.jit(win, donate_argnums=(1,))
            self._serve_windows[key] = fn
        return fn

    def _dispatch(self, kind, fn, args, *, chaos, watchdog, straggler,
                  stats, max_retries, backoff_s, index):
        """One dispatch through the fault plumbing: chaos injection runs
        first, inside the watchdog thread, *before* the jitted ``fn``
        consumes its donated arguments — which is exactly what makes the
        retry safe: an injected drop raises pre-consumption, and an
        injected hang aborts cooperatively at the watchdog's generation
        fence without ever touching the buffers.  (A *real* device hang
        that dies inside the jit leaves donated buffers unusable; that is
        the snapshot/restore path's job, not the retry's.)  Retries back
        off exponentially from ``backoff_s``.
        """

        def call():
            hook = dispatch_hook
            if hook is not None:
                hook("pre", kind)
            if chaos is not None:
                chaos.before_dispatch(
                    kind, index,
                    cancelled=(watchdog.cancelled if watchdog is not None
                               else None),
                )
            out = fn(*args)
            if hook is not None:
                hook("post", kind)
            return out

        attempt = 0
        while True:
            try:
                t0 = time.monotonic()
                # hostsafety: ok(retry re-passes args only pre-consumption)
                # A retried dispatch passes the donated args tuple again —
                # legal because every retried failure (chaos drop, hang at
                # the watchdog fence) raises *before* fn consumes the
                # buffers; post-consumption faults go to snapshot/restore,
                # never back through this loop.
                out = watchdog.run(call) if watchdog is not None else call()
                if straggler is not None and kind == "window":
                    if straggler.observe(time.monotonic() - t0):
                        stats["stragglers"] += 1
                return out
            except StepTimeout:
                stats["watchdog_timeouts"] += 1
            except Exception as e:  # noqa: BLE001 — filtered below
                from repro.serve.chaos import DispatchDropped

                if not isinstance(e, DispatchDropped):
                    raise
                stats["dispatch_drops"] += 1
            attempt += 1
            stats["dispatch_retries"] += 1
            if attempt > max_retries:
                raise RuntimeError(
                    f"{kind} dispatch failed after {max_retries} retries"
                )
            time.sleep(backoff_s * (2 ** (attempt - 1)))

    def serve(self, requests, *, slots: int = 4, temperature: float = 0.0,
              top_k: int = 0, eos_id: int | None = None, seed: int = 0,
              deadline_ms: float | None = None,
              max_queue: int | None = None,
              watchdog_timeout_s: float | None = None,
              max_dispatch_retries: int = 3,
              retry_backoff_s: float = 0.02,
              snapshot_every: int = 0,
              snapshot_dir: str | None = None,
              restore_from: str | None = None,
              chaos: Any = None,
              recoverable: bool | None = None,
              checksum_every: int = 0):
        """Continuous-batching scheduler: decode ``requests`` through a
        fixed pool of ``slots`` batch slots with per-request progress —
        and with the blast radius of any failure confined to one slot.

        Each request (a :class:`Request`, or anything with ``tokens`` /
        ``max_new_tokens``) is admitted into a free slot (a single masked
        prefill that cannot touch neighbors' caches), decodes at its own
        position, and frees its slot the moment it hits ``eos_id`` or its
        own ``max_new_tokens`` — detected inside the jitted window, so a
        finished request never burns another dispatch waiting for the
        slowest batch member (the lockstep barrier :meth:`generate`
        pays).  Freed slots are recycled to the next queued request in
        arrival order.

        Fault isolation (the paper's point-to-point argument as a
        robustness property — a fault delays one slot's hand-off, never
        a batch-global barrier):

        * a slot whose state or logits go non-finite is **quarantined**
          inside the jitted window (see :meth:`_serve_window`) and
          **recovered** by re-admitting the request from its accepted
          prefix (prompt + tokens emitted so far) — the read-side dual of
          :func:`_reset_slot_rows`; with the per-(request, token-index)
          sampling keys the resumed stream is exactly the one the fault
          interrupted, and every other slot is bit-identical to a
          fault-free run;
        * ``deadline_ms`` (serve-wide default; ``Request.deadline_ms``
          overrides) kills requests past their wall-clock budget with a
          typed ``deadline`` outcome instead of letting them squat slots;
        * ``max_queue`` bounds the admission backlog: beyond ``slots``
          immediately-admissible requests, at most ``max_queue`` may
          wait; later arrivals are **shed** (typed outcome, no tokens)
          instead of queueing unboundedly;
        * failed / hung dispatches are retried with exponential backoff
          (``max_dispatch_retries``, ``retry_backoff_s``), a hang being
          detected by a per-dispatch
          :class:`~repro.ft.watchdog.StepWatchdog` when
          ``watchdog_timeout_s`` is set (straggler dispatches are
          EWMA-flagged in ``last_serve_stats['stragglers']``);
        * ``snapshot_every`` > 0 checkpoints the whole engine — slot
          table, queues, per-request progress, device state — to
          ``snapshot_dir`` every N decode dispatches
          (:mod:`repro.checkpoint.checkpoint`); ``restore_from`` resumes
          a preempted serve bit-identically (same requests/args/seed);
        * ``checksum_every`` > 0 arms silent-corruption detection: every
          dispatch emits per-slot entry/exit state checksums which the
          host chains (exit(n) must equal entry(n+1) — a finite-but-
          wrong bit flip breaks the chain at the next window even though
          ``isfinite`` never fires), plus a shadow recompute spot check
          every M windows; a mismatched slot is quarantined, its
          unverified window tokens are rolled back, and the request is
          re-admitted from its last verified prefix (outcome
          ``recovered``; ``corrupt`` once corruption recurs past
          :data:`MAX_CORRUPTION_RECOVERIES`).

        ``chaos`` accepts a :class:`repro.serve.chaos.ChaosInjector` to
        drill all of the above deterministically.  ``recoverable`` sizes
        the local-attention rings for worst-case recovery re-prefills
        (prompt + whole budget); it defaults on when chaos / snapshots /
        restore are in play and off otherwise, where the ring sizing —
        and hence fault-free streams — match the pre-fault-isolation
        engine exactly.

        Sampling: greedy at ``temperature`` 0 (the parity-testable mode),
        else temperature / top-k categorical, keyed per (request, token
        index) — a request's stream is reproducible under a fixed
        ``seed`` regardless of ``decode_window``, slot assignment, batch
        composition, or how many faults it survived.

        Returns a list of :class:`RequestResult` (array-like: the
        generated tokens, prompt not included) with typed ``outcome``
        (ok / eos / deadline / shed / dropped / recovered) and the
        per-request recovery count.  Stats land in ``last_serve_stats``.
        """
        session = ServeSession(
            self, requests, slots=slots, temperature=temperature,
            top_k=top_k, eos_id=eos_id, seed=seed, deadline_ms=deadline_ms,
            max_queue=max_queue, watchdog_timeout_s=watchdog_timeout_s,
            max_dispatch_retries=max_dispatch_retries,
            retry_backoff_s=retry_backoff_s, snapshot_every=snapshot_every,
            snapshot_dir=snapshot_dir, restore_from=restore_from,
            chaos=chaos, recoverable=recoverable,
            checksum_every=checksum_every)
        try:
            while session.busy:
                session.step()
        finally:
            session.close()
        return session.results()

    # -- engine snapshot / restore ---------------------------------------

    def _serve_meta(self, b, k_w, insert_window, n, seed, ctl):
        """Snapshot compatibility vector: geometry + paging config.  A
        restore must be a bit-identical re-run, so everything that shapes
        the jits or the page pools is pinned here."""
        return np.asarray(
            [b, k_w, insert_window, n, seed, int(ctl is not None),
             self.page_size,
             -1 if self.pool_pages is None else int(self.pool_pages),
             0 if ctl is None else ctl.shared_total],
            np.int64)

    def _snapshot_serve(self, snapshot_dir, stats, state, cur, lengths,
                        counts, budgets, req_ids, active, slot_req, pending,
                        recover_q, outputs, outcomes, recoveries,
                        b, k_w, insert_window, n, seed, ctl=None,
                        corruptions=None, saver=None):
        """Checkpoint the whole serve loop as ONE atomic tree: device
        state + slot table + queues + per-request progress + stats.

        Everything — including the ragged per-request outputs (flattened
        to ``out_flat`` + ``out_off`` offsets) — goes through one
        :func:`checkpoint.save`, so a crash mid-snapshot can never leave
        device state and bookkeeping describing different moments.  The
        RNG needs no saving: sampling keys are ``fold_in(req_id,
        token_idx)`` off ``PRNGKey(seed)``, both of which the restore
        re-derives, which is exactly what makes resumed streams
        bit-identical.
        """
        from repro.checkpoint import checkpoint as C

        out_off = np.zeros(n + 1, np.int64)
        for i, o in enumerate(outputs):
            out_off[i + 1] = out_off[i] + len(o)
        out_flat = np.asarray(
            [t for o in outputs for t in o], np.int32)
        codes = np.asarray(
            [-1 if oc is None else OUTCOMES.index(oc) for oc in outcomes],
            np.int32)
        host = {
            "slot_req": np.asarray(slot_req, np.int32),
            "pending": np.asarray(list(pending), np.int32),
            "recover_q": np.asarray(list(recover_q), np.int32),
            "out_flat": out_flat,
            "out_off": out_off,
            "outcome_codes": codes,
            "recoveries": np.asarray(recoveries, np.int64),
            "corruptions": np.asarray(
                corruptions if corruptions is not None else [0] * n,
                np.int64),
            "stats": np.asarray(
                [stats[k] for k in SERVE_STAT_KEYS], np.int64),
        }
        if ctl is not None:
            # Page-pool bookkeeping rides the same atomic tree: owner
            # arrays (page -> slot / FREE / SHARED) and the high-water
            # mark — the device page tables themselves are in ``state``.
            for key, val in ctl.snapshot_tree().items():
                host["pg_" + key] = val
        tree = {
            "device": {
                "state": state, "cur": cur, "lengths": lengths,
                "counts": counts, "budgets": budgets, "req_ids": req_ids,
                "active": active,
            },
            "host": host,
            "meta": self._serve_meta(b, k_w, insert_window, n, seed, ctl),
        }
        if saver is not None:
            # Fleet replicas snapshot through an AsyncSaver: the host copy
            # is taken synchronously (so the tree is still one atomic
            # moment) and the write overlaps the next windows.  A failed
            # background write surfaces here on the next snapshot.
            saver.save_async(snapshot_dir, stats["decode_dispatches"], tree)
        else:
            C.save(snapshot_dir, stats["decode_dispatches"], tree)

    def _restore_serve(self, restore_from, b, k_w, insert_window, n, seed,
                       state_template, ctl=None):
        """Resume a snapshotted serve.  The caller must pass the same
        requests / slots / decode_window / seed the snapshot was taken
        under (validated against the snapshot's meta); device arrays come
        back through :func:`checkpoint.restore` against a fresh template
        (restore is template-driven, so the host-side extras in the same
        file are simply not materialized on device), ragged host arrays
        are read straight from the snapshot's ``arrays.npz``.
        """
        from pathlib import Path

        from repro.checkpoint import checkpoint as C

        step = C.latest_step(restore_from)
        if step is None:
            raise FileNotFoundError(f"no serve snapshot under {restore_from}")
        template = {
            "device": {
                "state": state_template,
                "cur": jnp.zeros((b, 1), jnp.int32),
                "lengths": jnp.zeros((b,), jnp.int32),
                "counts": jnp.zeros((b,), jnp.int32),
                "budgets": jnp.zeros((b,), jnp.int32),
                "req_ids": jnp.zeros((b,), jnp.int32),
                "active": jnp.zeros((b,), bool),
            },
        }
        with np.load(Path(restore_from) / f"step_{step}"
                     / "arrays.npz") as data:
            meta = data["meta"]
            host = {k.split("/", 1)[1]: data[k] for k in data.files
                    if k.startswith("host/")}
        want = self._serve_meta(b, k_w, insert_window, n, seed, ctl)
        if not np.array_equal(meta, want):
            raise ValueError(
                f"snapshot meta {meta.tolist()} does not match this serve "
                f"call {want.tolist()} — restore needs the same requests, "
                "slots, decode_window, seed, and paging config"
            )
        if ctl is not None:
            ctl.restore({k[3:]: v for k, v in host.items()
                         if k.startswith("pg_")})
        tree, _ = C.restore(restore_from, template, step=step)
        d = tree["device"]
        outputs = [
            [int(t) for t in host["out_flat"]
             [host["out_off"][i]: host["out_off"][i + 1]]]
            for i in range(n)
        ]
        outcomes = [
            None if c < 0 else OUTCOMES[c]
            for c in host["outcome_codes"]
        ]
        stats = {k: int(v)
                 for k, v in zip(SERVE_STAT_KEYS, host["stats"])}
        corruptions = host.get("corruptions", np.zeros(n, np.int64))
        return (d["state"], d["cur"], d["lengths"], d["counts"],
                d["budgets"], d["req_ids"], d["active"],
                [int(s) for s in host["slot_req"]],
                collections.deque(int(i) for i in host["pending"]),
                collections.deque(int(i) for i in host["recover_q"]),
                outputs, outcomes,
                [int(r) for r in host["recoveries"]],
                [int(c) for c in corruptions], stats)

    def generate(self, prompts: jax.Array, num_new_tokens: int,
                 prompt_lengths=None) -> jax.Array:
        """prompts: (B, P) int32 -> (B, P + num_new_tokens).

        ``prompt_lengths`` (B,) marks ragged prompts: tokens at/beyond a
        request's length are padding — masked out of every cache and
        recurrent state at prefill — and generation continues from each
        request's own final position (the output keeps the dense layout:
        row b's generated tokens start at column P regardless of its
        prompt length).  Decoding itself stays lockstep; :meth:`serve` is
        the continuous scheduler.
        """
        b, p_len = prompts.shape
        k_w = max(1, int(self.decode_window))
        # insert_window sizes the local-attention ring slack for the widest
        # window any decode_step call inserts (the whole prompt at
        # prefill).  Bucketed to a multiple of 32 so the decode-state
        # shapes — and with them the cached window jits — don't recompile
        # for every distinct prompt length (extra slack is harmless: the
        # ring is capped at max_len either way).
        state = M.init_decode_state(
            self.cfg, batch=b, max_len=self.max_len,
            insert_window=max(k_w, _bucket32(p_len)),
        )
        logits, state = self._prefill(self.params, state, prompts,
                                      prompt_lengths)
        self.last_decode_dispatches = 0
        if num_new_tokens <= 0:
            return prompts
        out = [prompts]
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        pos = (
            jnp.int32(p_len) if prompt_lengths is None
            else jnp.asarray(prompt_lengths, jnp.int32)
        )
        left = num_new_tokens
        while left > 0:
            k = min(k_w, left)
            fn = self._window_step(k, last=(k == left))
            toks, state, cur, pos = fn(self.params, state, cur, pos)
            self.last_decode_dispatches += 1
            out.append(toks)
            left -= k
        return jnp.concatenate(out, axis=1)

class ServeSession:
    """One resumable continuous-batching serve loop — the engine-side half
    of a fleet replica.

    :meth:`ServeEngine.serve` is this object driven to completion.  A
    :class:`repro.serve.fleet.FleetRouter` instead constructs one session
    per replica engine and *interleaves* :meth:`step` calls across them:
    each ``step()`` is exactly one scheduler iteration (deadline sweep,
    admission, one decode window), so N sessions in one process make
    independent progress the same way lane 2's fake devices simulate a
    mesh.

    ``external=True`` starts the local queue empty: the session still
    sees the FULL request list — slot shapes, request ids, the
    insert-window bucket and the snapshot meta are then identical on
    every replica, which is the precondition for bit-identical streams
    under rescheduling and for snapshot handoff — but requests only
    enter via :meth:`enqueue` (router assignment) or
    :meth:`enqueue_handoff` (resume from a dead replica's snapshot).

    ``clock_origin`` anchors deadline arithmetic: a router passes one
    shared origin so ``deadline_ms`` counts the time a request spent
    waiting in the shared fleet queue, not just post-assignment decode
    time.  ``saver`` (a :class:`repro.checkpoint.checkpoint.AsyncSaver`)
    moves snapshot writes off the dispatch path.
    """

    def __init__(self, engine, requests, *, slots: int = 4,
                 temperature: float = 0.0, top_k: int = 0,
                 eos_id: int | None = None, seed: int = 0,
                 deadline_ms: float | None = None,
                 max_queue: int | None = None,
                 watchdog_timeout_s: float | None = None,
                 max_dispatch_retries: int = 3,
                 retry_backoff_s: float = 0.02,
                 snapshot_every: int = 0,
                 snapshot_dir: str | None = None,
                 restore_from: str | None = None,
                 chaos: Any = None,
                 recoverable: bool | None = None,
                 checksum_every: int = 0,
                 clock=time.monotonic,
                 clock_origin: float | None = None,
                 external: bool = False,
                 saver: Any = None):
        eng = engine
        self.eng = eng
        self.temperature = temperature
        self.top_k = top_k
        self.eos_id = eos_id
        self.seed = seed
        self.deadline_ms = deadline_ms
        self.max_retries = max_dispatch_retries
        self.backoff_s = retry_backoff_s
        self.snapshot_every = snapshot_every
        self.snapshot_dir = snapshot_dir
        self.chaos = chaos
        self.checksum_every = int(checksum_every)
        self.saver = saver
        self.external = external
        self._clock = clock
        self.closed = False

        reqs = [
            r if hasattr(r, "tokens") else Request(tokens=r)
            for r in requests
        ]
        self.reqs = reqs
        n = len(reqs)
        self.n = n
        b = max(1, min(int(slots), n)) if n else 1
        self.b = b
        k_w = max(1, int(eng.decode_window))
        self.k_w = k_w
        self.prompts_np = [np.asarray(r.tokens, np.int32).reshape(-1)
                           for r in reqs]
        self.p_lens = [int(a.size) for a in self.prompts_np]
        self.outputs: list[list[int]] = [[] for _ in range(n)]
        self.outcomes: list[str | None] = [None] * n
        self.recoveries = [0] * n
        self.corruptions = [0] * n
        self.stats = {k: 0 for k in SERVE_STAT_KEYS}
        #: Requests that reached a terminal outcome in THIS session, in
        #: completion order — a fleet router drains these after every
        #: step, so results delivered before a replica dies are never
        #: re-run.
        self.newly_done: collections.deque[int] = collections.deque()
        ps = int(eng.page_size)
        self.pid_of: list[int | None] = [None] * n
        self.start_of = [0] * n
        for i, (r, pl) in enumerate(zip(reqs, self.p_lens)):
            if pl < 1:
                raise ValueError("request prompt must be non-empty")
            if int(r.max_new_tokens) < 1:
                raise ValueError("max_new_tokens must be >= 1")
            pid = getattr(r, "prefix_id", None)
            if pid is not None:
                if not eng.paged:
                    raise ValueError(
                        "Request.prefix_id requires a paged engine")
                pre = eng._prefixes.get(pid)
                if pre is None:
                    raise ValueError(f"unknown prefix id {pid}")
                if (pl < pre.size
                        or not np.array_equal(self.prompts_np[i][:pre.size],
                                              pre)):
                    raise ValueError(
                        f"request {i}: prompt does not extend registered "
                        f"prefix {pid}")
                start = (pre.size // ps) * ps
                if pl > start:
                    self.pid_of[i], self.start_of[i] = pid, start
                # else the prompt IS the page-aligned prefix: the entry
                # leaves no suffix token to prefill from — admit cold.
            if pl + int(r.max_new_tokens) > eng.max_len:
                # A request that cannot fit the engine's position limit is
                # load to refuse, not a caller bug that should abort every
                # other request in the batch: typed shed outcome.
                self.outcomes[i] = "shed"
                self.stats["shed"] += 1
                self.newly_done.append(i)
        live = [i for i in range(n) if self.outcomes[i] is None]
        if recoverable is None:
            recoverable = (chaos is not None or restore_from is not None
                           or snapshot_every > 0 or external)
        # Recovery re-prefills replay prompt + accepted tokens in one
        # window: size the local-attention ring slack for the worst case
        # (a request quarantined on its last token) when recovery is in
        # play.  Off the recovery paths, keep the original sizing — ring
        # shapes feed attention reductions, so changing them for free
        # would perturb fault-free bit-parity with older baselines.
        worst = max(
            (self.p_lens[i] + int(reqs[i].max_new_tokens) if recoverable
             else self.p_lens[i])
            for i in live
        ) if live else 1
        insert_window = max(k_w, _bucket32(worst))
        self.insert_window = insert_window
        ctl = None
        if eng.paged:
            # One shared-page region per registered prefix in use this
            # serve: prefill each prefix's aligned head once (cached),
            # reserve its pages in every full-view pool, and upload the
            # K/V content before any admission.
            used_pids = sorted({self.pid_of[i] for i in live
                                if self.pid_of[i] is not None})
            shared_map, entries, nxt = {}, {}, 1
            for pid in used_pids:
                start, rec, kv = eng._prefix_entry(pid, insert_window)
                shared_map[pid] = (nxt, start // ps)
                nxt += start // ps
                entries[pid] = (rec, kv)
            spec = M.PageSpec(page_size=ps, private_pages=eng.pool_pages,
                              shared_pages=nxt - 1)
            state = M.init_decode_state(
                eng.cfg, batch=b, max_len=eng.max_len,
                insert_window=insert_window, paged=spec,
            )
            ctl = paging.PagedController(
                eng.cfg, state, batch=b, max_len=eng.max_len,
                shared_map=shared_map,
            )
            if entries:
                state = paging.upload_shared(state, ctl, entries)
            for i in live:
                if not ctl.fits_capacity(
                        self.p_lens[i] + int(reqs[i].max_new_tokens),
                        self.start_of[i]):
                    # Needs more private pages than the pool ever has:
                    # waiting can never help — shed, don't deadlock.
                    self.outcomes[i] = "shed"
                    self.stats["shed"] += 1
                    self.newly_done.append(i)
        else:
            state = M.init_decode_state(
                eng.cfg, batch=b, max_len=eng.max_len,
                insert_window=insert_window,
            )
        self.ctl = ctl
        self.state = state
        self.lengths = jnp.zeros((b,), jnp.int32)
        self.counts = jnp.zeros((b,), jnp.int32)
        self.budgets = jnp.zeros((b,), jnp.int32)
        self.req_ids = jnp.zeros((b,), jnp.int32)
        self.active = jnp.zeros((b,), bool)
        self.cur = jnp.zeros((b, 1), jnp.int32)
        self.base_key = jax.random.PRNGKey(seed)

        if external:
            self.pending: collections.deque[int] = collections.deque()
        else:
            self.pending = collections.deque(
                i for i in range(n) if self.outcomes[i] is None)
        self.recover_q: collections.deque[int] = collections.deque()
        self.slot_req = [-1] * b
        self.active_np = np.zeros(b, bool)

        self.watchdog = (StepWatchdog(watchdog_timeout_s)
                         if watchdog_timeout_s is not None else None)
        self.straggler = StragglerDetector(warmup=1)
        self.t_origin = clock_origin if clock_origin is not None else clock()
        self.any_deadline = (
            deadline_ms is not None
            or any(getattr(r, "deadline_ms", None) is not None
                   for r in reqs))

        # Checksum chain state: after any dispatch, _csum_base holds the
        # per-slot exit checksums the next dispatch's entry must match.
        self._csum_base = np.zeros(b, np.uint32)
        self._csum_have = False
        self._since_spot = 0

        if restore_from is not None:
            (self.state, self.cur, self.lengths, self.counts, self.budgets,
             self.req_ids, self.active, self.slot_req, self.pending,
             self.recover_q, self.outputs, self.outcomes, self.recoveries,
             self.corruptions, self.stats) = eng._restore_serve(
                restore_from, b, k_w, insert_window, n, seed, state, ctl)
            self.active_np = np.array(self.active)
        elif max_queue is not None and not external:
            # Bounded admission queue: b requests admit immediately, at
            # most max_queue wait; shed the later arrivals (typed
            # outcome), never queue unboundedly.
            cap = b + max(0, int(max_queue))
            while len(self.pending) > cap:
                ri = self.pending.pop()
                self.outcomes[ri] = "shed"
                self.stats["shed"] += 1
                self.newly_done.append(ri)

    # -- queue interface (router-facing) --------------------------------

    @property
    def busy(self) -> bool:
        """True while this session has local work (queued or in-flight)."""
        return bool(self.pending or self.recover_q or self.active_np.any())

    def enqueue(self, ri: int):
        """Assign request ``ri`` (an index into the full request list) to
        this session's local queue."""
        if self.outcomes[ri] is not None:
            raise ValueError(f"request {ri} already terminal "
                             f"({self.outcomes[ri]})")
        self.pending.append(ri)

    def enqueue_handoff(self, ri: int, accepted) -> None:
        """Resume request ``ri`` from another replica's snapshot: seed its
        output with the ``accepted`` token prefix and queue it through the
        recovery path (re-prefill of prompt + accepted tokens).  The
        per-(request, token-index) sampling keys make the continuation
        bit-identical to the stream the dead replica was producing."""
        self.outputs[ri] = [int(t) for t in accepted]
        self.outcomes[ri] = None
        self.recoveries[ri] += 1
        self.stats["recoveries"] += 1
        self.recover_q.append(ri)

    def queue_depth(self) -> int:
        """Queued + in-flight request count (router load signal)."""
        return (len(self.pending) + len(self.recover_q)
                + int(self.active_np.sum()))

    def recovery_debt_steps(self, window: int = 1) -> int:
        """Modeled decode steps this session must spend on re-prefills
        before its recovery queue is clean (router placement bias — see
        :func:`repro.core.cost_model.serve_recovery_steps`)."""
        from repro.core import cost_model

        total = 0
        for ri in self.recover_q:
            isolated, _ = cost_model.serve_recovery_steps(
                [self.p_lens[ri]], [len(self.outputs[ri])], 0,
                window=window)
            total += isolated
        return total

    def drain_done(self) -> list[int]:
        """Pop and return requests that reached a terminal outcome since
        the last drain."""
        out = []
        while self.newly_done:
            out.append(self.newly_done.popleft())
        return out

    # -- outcome helpers -------------------------------------------------

    def _req_deadline(self, ri):
        d = getattr(self.reqs[ri], "deadline_ms", None)
        return self.deadline_ms if d is None else d

    def _resolve(self, ri):
        if self.recoveries[ri] > 0:
            self.outcomes[ri] = "recovered"
        elif (self.eos_id is not None and self.outputs[ri]
                and self.outputs[ri][-1] == self.eos_id):
            self.outcomes[ri] = "eos"
        else:
            self.outcomes[ri] = "ok"
        self.newly_done.append(ri)

    def _free_slot(self, slot):
        self.slot_req[slot] = -1
        if self.ctl is not None:
            self.ctl.free_slot(slot)

    # -- the scheduler iteration ----------------------------------------

    def step(self):
        """One scheduler iteration: deadline sweep, admission (recoveries
        first), one decode window with quarantine / checksum / chaos
        bookkeeping.  Exactly the loop body :meth:`ServeEngine.serve`
        always ran — extracted so a fleet can interleave replicas."""
        self._sweep_deadlines()
        self._admit()
        self._decode_window()

    def _sweep_deadlines(self):
        if not self.any_deadline:
            return
        now_ms = (self._clock() - self.t_origin) * 1e3
        killed = False
        for slot in np.nonzero(self.active_np)[0]:
            ri = self.slot_req[slot]
            dl = self._req_deadline(ri)
            if dl is not None and now_ms > dl:
                self.outcomes[ri] = "deadline"
                self.stats["deadline_hits"] += 1
                self.newly_done.append(ri)
                self.active_np[slot] = False
                self._free_slot(slot)
                killed = True
        if killed:
            self.active = jnp.asarray(self.active_np)
        for q in (self.recover_q, self.pending):
            for _ in range(len(q)):
                ri = q.popleft()
                dl = self._req_deadline(ri)
                if dl is not None and now_ms > dl:
                    self.outcomes[ri] = "deadline"
                    self.stats["deadline_hits"] += 1
                    self.newly_done.append(ri)
                else:
                    q.append(ri)

    def _admit(self):
        eng, b = self.eng, self.b
        ctl = self.ctl
        free = [i for i in range(b) if not self.active_np[i]]
        take: list[int] = []
        slot_alloc: dict[int, tuple] = {}
        group_pid: int | None = None
        while len(take) < len(free) and (self.recover_q or self.pending):
            q = self.recover_q if self.recover_q else self.pending
            ri = q[0]
            if ctl is not None:
                pid = self.pid_of[ri]
                if pid is not None:
                    if group_pid is None:
                        group_pid = pid
                    elif pid != group_pid:
                        # One prefix entry per admission dispatch: a
                        # second prefix waits for the next round.
                        break
                alloc = ctl.try_admit(
                    free[len(take)],
                    self.p_lens[ri] + int(self.reqs[ri].max_new_tokens),
                    pid, self.start_of[ri])
                if alloc is None:
                    # Pool pressure: the head-of-line request waits for
                    # pages freed by completions — it is never skipped
                    # (no starvation reorder).
                    self.stats["page_waits"] += 1
                    break
                slot_alloc[free[len(take)]] = alloc
            q.popleft()
            take.append(ri)
        if not take:
            return
        # A recovery's "prompt" is the original prompt plus its accepted
        # tokens; fresh requests have none.
        used = free[: len(take)]
        admit_np = np.zeros(b, bool)
        plen_np = np.zeros(b, np.int32)
        tokidx_np = np.zeros(b, np.int32)
        bud_np = np.array(self.budgets)
        rid_np = np.array(self.req_ids)
        full = {
            ri: np.concatenate([
                self.prompts_np[ri],
                np.asarray(self.outputs[ri], np.int32),
            ])
            for ri in take
        }
        if ctl is None:
            p_b = _bucket32(max(full[ri].size for ri in take))
            tok_np = np.zeros((b, p_b), np.int32)
            for slot, ri in zip(used, take):
                t_arr = full[ri]
                tok_np[slot, : t_arr.size] = t_arr
                admit_np[slot] = True
                plen_np[slot] = t_arr.size
                tokidx_np[slot] = len(self.outputs[ri])
                bud_np[slot] = int(self.reqs[ri].max_new_tokens)
                rid_np[slot] = ri
                self.slot_req[slot] = ri
            self.budgets = jnp.asarray(bud_np)
            self.req_ids = jnp.asarray(rid_np)
            fn = eng._admit_step(
                p_b, self.temperature, self.top_k, self.eos_id)
            args = (eng.params, self.state, jnp.asarray(tok_np),
                    jnp.asarray(admit_np), jnp.asarray(plen_np),
                    jnp.asarray(tokidx_np), self.lengths, self.counts,
                    self.budgets, self.req_ids, self.active, self.cur,
                    self.base_key)
        else:
            # Paged: only the suffix past each request's shared-prefix
            # start is prefilled; the prefix rides in as copied state /
            # shared pages.
            p_b = _bucket32(max(
                full[ri].size - self.start_of[ri] for ri in take))
            tok_np = np.zeros((b, p_b), np.int32)
            start_np = np.zeros(b, np.int32)
            prefix_np = np.zeros(b, bool)
            for slot, ri in zip(used, take):
                t_arr = full[ri][self.start_of[ri]:]
                tok_np[slot, : t_arr.size] = t_arr
                admit_np[slot] = True
                plen_np[slot] = t_arr.size
                start_np[slot] = self.start_of[ri]
                prefix_np[slot] = self.start_of[ri] > 0
                tokidx_np[slot] = len(self.outputs[ri])
                bud_np[slot] = int(self.reqs[ri].max_new_tokens)
                rid_np[slot] = ri
                self.slot_req[slot] = ri
                if self.start_of[ri] > 0:
                    self.stats["prefix_admissions"] += 1
            self.budgets = jnp.asarray(bud_np)
            self.req_ids = jnp.asarray(rid_np)
            tables, scrubs = [], []
            for i_node, g in enumerate(ctl.geoms):
                t_rows = np.full((b, g.nl), -1, np.int32)
                s_rows = np.full((b, g.nl), -1, np.int32)
                for slot in used:
                    t_rows[slot] = slot_alloc[slot][0][i_node]
                    s_rows[slot] = slot_alloc[slot][1][i_node]
                tables.append(jnp.asarray(t_rows))
                scrubs.append(jnp.asarray(s_rows))
            if group_pid is not None:
                _, rec, kv = eng._prefix_entry(
                    group_pid, self.insert_window)
            else:
                rec, kv = eng._null_entry(self.insert_window)
            ring = [kv[i] for i, role in enumerate(ctl.roles)
                    if role == "copy"]
            fn = eng._admit_step_paged(
                p_b, self.temperature, self.top_k, self.eos_id, ctl.roles)
            args = (eng.params, self.state, jnp.asarray(tok_np),
                    jnp.asarray(admit_np), jnp.asarray(plen_np),
                    jnp.asarray(start_np), jnp.asarray(prefix_np),
                    tables, scrubs, rec, ring, jnp.asarray(tokidx_np),
                    self.lengths, self.counts, self.budgets, self.req_ids,
                    self.active, self.cur, self.base_key)
        (self.state, self.lengths, self.counts, self.active, self.cur,
         tok0, entry_csum, exit_csum) = eng._dispatch(
            "admit", fn, args,
            chaos=self.chaos, watchdog=self.watchdog,
            straggler=self.straggler, stats=self.stats,
            max_retries=self.max_retries, backoff_s=self.backoff_s,
            index=self.stats["decode_dispatches"],
        )
        tok0_np = np.asarray(tok0)
        self.active_np = np.array(self.active)
        if self.checksum_every > 0:
            # Chain check for the rows this admission did NOT touch: their
            # state is frozen through the jit, so a mismatch means the
            # bits changed between dispatches.
            self._chain_check(np.asarray(entry_csum), skip=admit_np,
                              emits_np=None)
            self._csum_base = np.asarray(exit_csum).copy()
            self._csum_have = True
        for slot, ri in zip(used, take):
            self.outputs[ri].append(int(tok0_np[slot]))
            if not self.active_np[slot]:
                # Done at admission (budget 1 / instant EOS).
                self._resolve(ri)
                self._free_slot(slot)
        self.stats["admissions"] += 1

    def _decode_window(self):
        if not self.active_np.any():
            return
        eng = self.eng
        if self.chaos is not None:
            self.state, _ = self.chaos.maybe_poison(
                self.state, self.active_np, self.stats["decode_dispatches"],
                self.slot_req)
            self.state, _ = self.chaos.maybe_bitflip(
                self.state, self.active_np, self.stats["decode_dispatches"],
                self.slot_req)
        fn = eng._serve_window(self.k_w, self.temperature, self.top_k,
                               self.eos_id)
        (self.state, self.cur, self.lengths, self.counts, self.active,
         quar, toks, emits, entry_csum, exit_csum) = eng._dispatch(
            "window", fn,
            (eng.params, self.state, self.cur, self.lengths, self.counts,
             self.budgets, self.active, self.req_ids, self.base_key),
            chaos=self.chaos, watchdog=self.watchdog,
            straggler=self.straggler, stats=self.stats,
            max_retries=self.max_retries, backoff_s=self.backoff_s,
            index=self.stats["decode_dispatches"],
        )
        toks_np = np.asarray(toks)
        emits_np = np.asarray(emits)
        for step_i in range(self.k_w):
            for slot in np.nonzero(emits_np[step_i])[0]:
                self.outputs[self.slot_req[slot]].append(
                    int(toks_np[step_i, slot]))
        prev_active = self.active_np
        self.active_np = np.array(self.active)
        quar_np = np.asarray(quar)
        self.stats["decode_dispatches"] += 1
        self.stats["slot_steps"] += self.k_w * self.b
        corrupt_np = np.zeros(self.b, bool)
        if self.checksum_every > 0:
            # Checksum chain: this window's entry checksum must equal the
            # last dispatch's exit checksum.  In-jit quarantined slots are
            # skipped here — the NaN path already recovers them.
            corrupt_np = self._chain_check(
                np.asarray(entry_csum), skip=quar_np, emits_np=emits_np)
            self._csum_base = np.asarray(exit_csum).copy()
            self._csum_have = True
        # Quarantined slots: queue the victim for re-prefill recovery
        # from its accepted prefix.
        for slot in np.nonzero(quar_np)[0]:
            ri = self.slot_req[slot]
            self.stats["quarantines"] += 1
            self.stats["recoveries"] += 1
            self.recoveries[ri] += 1
            self.recover_q.append(ri)
            self._free_slot(slot)
        # Completions: active before, inactive after, not quarantined and
        # not checksum-corrupt (a corrupt slot's "completion" was computed
        # from bad bits — it re-queues instead).
        for slot in np.nonzero(
                prev_active & ~self.active_np & ~quar_np & ~corrupt_np)[0]:
            ri = self.slot_req[slot]
            if ri >= 0:
                self._resolve(ri)
                self._free_slot(slot)
        if self.chaos is not None:
            slot = self.chaos.maybe_drop_request(
                self.active_np, self.stats["decode_dispatches"],
                self.slot_req)
            if slot is not None:
                ri = self.slot_req[slot]
                self.outcomes[ri] = "dropped"
                self.stats["req_drops"] += 1
                self.newly_done.append(ri)
                self.active_np[slot] = False
                self._free_slot(slot)
                self.active = jnp.asarray(self.active_np)
        if self.checksum_every > 0:
            self._since_spot += 1
            if self._since_spot >= self.checksum_every:
                self._spot_check()
        if (self.snapshot_every > 0 and self.snapshot_dir is not None
                and self.stats["decode_dispatches"]
                % self.snapshot_every == 0):
            self.snapshot_now()
        if self.chaos is not None:
            self.chaos.check_preempt(self.stats["decode_dispatches"])
            self.chaos.check_replica_kill(self.stats["decode_dispatches"])

    # -- silent-corruption detection -------------------------------------

    def _chain_check(self, entry_np, *, skip, emits_np):
        """Compare a dispatch's entry checksums against the previous
        dispatch's exit checksums.  Slots in ``skip`` (admitted rows,
        in-jit quarantined rows) are excluded.  For window dispatches,
        ``emits_np`` lets the detector roll back the tokens the corrupted
        window emitted — they were computed from bad bits, and the
        re-admission regenerates them from the last verified prefix.
        Returns the (B,) bool mask of corrupt slots."""
        corrupt = np.zeros(self.b, bool)
        if not self._csum_have:
            return corrupt
        for slot in range(self.b):
            if skip[slot] or self.slot_req[slot] < 0:
                continue
            if entry_np[slot] == self._csum_base[slot]:
                continue
            corrupt[slot] = True
            rollback = (int(emits_np[:, slot].sum())
                        if emits_np is not None else 0)
            self._corrupted(slot, rollback)
        if corrupt.any():
            self.active = jnp.asarray(self.active_np)
        return corrupt

    def _corrupted(self, slot: int, rollback: int):
        ri = self.slot_req[slot]
        self.stats["corruptions"] += 1
        self.stats["quarantines"] += 1
        self.corruptions[ri] += 1
        if rollback:
            del self.outputs[ri][len(self.outputs[ri]) - rollback:]
        self.active_np[slot] = False
        self._free_slot(slot)
        if self.corruptions[ri] > MAX_CORRUPTION_RECOVERIES:
            # Persistent corruption is a hardware problem, not a retry
            # problem: terminal typed outcome, last verified prefix kept.
            self.outcomes[ri] = "corrupt"
            self.newly_done.append(ri)
        else:
            self.stats["recoveries"] += 1
            self.recoveries[ri] += 1
            self.recover_q.append(ri)

    def _spot_check(self):
        """Shadow recompute: re-checksum the live state out-of-band and
        compare against the last emitted exit checksums.  The chain
        catches anything that flips bits *between* dispatches; this
        catches corruption after the most recent emission (and would
        catch an emission path that lies)."""
        self._since_spot = 0
        if not self._csum_have:
            return
        self.stats["checksum_spot_checks"] += 1
        shadow = np.asarray(self.eng._shadow_csum(self.state))
        bad = False
        for slot in range(self.b):
            if self.slot_req[slot] < 0:
                continue
            if shadow[slot] != self._csum_base[slot]:
                self._corrupted(slot, rollback=0)
                bad = True
        if bad:
            self.active = jnp.asarray(self.active_np)
        self._csum_base = shadow.copy()

    # -- snapshot / teardown ---------------------------------------------

    def snapshot_now(self):
        self.eng._snapshot_serve(
            self.snapshot_dir, self.stats, self.state, self.cur,
            self.lengths, self.counts, self.budgets, self.req_ids,
            self.active, self.slot_req, self.pending, self.recover_q,
            self.outputs, self.outcomes, self.recoveries,
            self.b, self.k_w, self.insert_window, self.n, self.seed,
            self.ctl, corruptions=self.corruptions, saver=self.saver)
        self.stats["snapshots"] += 1

    def close(self):
        """Finalize stats and run the paged audit.  Idempotent; runs in
        ``finally`` position so preemption/kill exceptions still leave
        ``last_serve_stats`` and the audit behind."""
        if self.closed:
            return
        self.closed = True
        if self.saver is not None:
            self.saver.wait()
        self.eng.last_serve_stats = self.stats
        if self.ctl is not None:
            self.ctl.audit(self.state, self.active_np, self.slot_req)
            self.eng.last_paged_stats = {
                "page_size": int(self.eng.page_size),
                "shared_pages": self.ctl.shared_total,
                "pool_bytes": self.ctl.pool_bytes(),
                "dense_bytes": self.ctl.dense_bytes(),
                "peak_mapped_bytes": self.ctl.peak_mapped_bytes,
                "page_table_violations": len(self.ctl.violations),
            }

    def results(self) -> list[RequestResult]:
        out = []
        for i in range(self.n):
            if self.outcomes[i] is None:   # defensive: loop exit ⇒ terminal
                self._resolve(i)
            out.append(RequestResult(
                tokens=np.asarray(self.outputs[i], np.int32),
                outcome=self.outcomes[i], recoveries=self.recoveries[i],
            ))
        return out
