"""Replica health: escalate per-dispatch fault signals into a fleet
verdict.

The serve engine already *measures* everything a fleet needs — the
:class:`~repro.ft.watchdog.StepWatchdog` heartbeats around every
dispatch, the :class:`~repro.ft.watchdog.StragglerDetector` EWMA-flags
slow windows, and ``last_serve_stats`` counts quarantines, corruptions
and retries per replica.  What is missing is the *policy*: when do those
per-dispatch signals mean "stop routing new work here" (``degraded``)
and when do they mean "this replica is gone, hand its work off"
(``dead``)?

:class:`ReplicaMonitor` is that policy, deliberately boring and
deterministic (every transition is unit-testable without a clock):

* ``healthy``  — route freely.
* ``degraded`` — no **new** admissions; in-flight work may finish.
    Entered when the recent-window quarantine+corruption rate crosses
    ``quarantine_rate_limit``, when ``straggler_limit`` consecutive
    dispatches are EWMA-flagged stragglers, or when the watchdog has
    timed out at least once.  A clean observation window heals back to
    ``healthy`` — degradation is a brown-out, not a verdict.
* ``dead``     — terminal.  Entered when the engine raises a
    non-recoverable fault (:class:`~repro.serve.chaos.ReplicaKilled`,
    a dispatch-retry exhaustion, a device error), or when degradation
    persists for ``dead_after_degraded`` consecutive observations.
    A dead replica's state is *discarded*; the router recovers its
    requests from the replica's last atomic snapshot.

States only ever move ``healthy <-> degraded -> dead``; ``dead`` never
heals (a process that lost its device state cannot un-lose it — the
snapshot handoff is the recovery path, not resurrection).
"""

from __future__ import annotations

import dataclasses

from repro.ft.watchdog import make_lock

HEALTHY = "healthy"
DEGRADED = "degraded"
DEAD = "dead"


@dataclasses.dataclass
class ReplicaMonitor:
    """Sliding-window escalation of one replica's fault signals.

    Call :meth:`observe` once per scheduler iteration with that
    iteration's *deltas* (faults since the previous observation) and
    flags; read :attr:`state`.  ``window`` is the number of recent
    observations the fault rate is computed over.
    """

    window: int = 20
    #: (quarantines + corruptions) / observations over the recent window
    #: at/above which the replica browns out.
    quarantine_rate_limit: float = 0.5
    #: Consecutive straggler-flagged dispatches that brown out.
    straggler_limit: int = 3
    #: Consecutive degraded observations after which the replica is
    #: declared dead (wedged, not merely slow).
    dead_after_degraded: int = 10

    def __post_init__(self):
        if self.window < 1:
            raise ValueError("window must be >= 1")
        # Observations may come from a health-probe thread while the
        # router loop reads the verdict: every mutation happens under
        # this lock, and :meth:`status` reads (state, reason) under it —
        # a reader never sees a new state with a stale reason (or a
        # ``dead`` that heals).
        self._lock = make_lock()
        self.state = HEALTHY
        self.reason = ""
        self._faults: list[int] = []     # recent per-observation fault counts
        self._timeouts: list[bool] = []  # recent watchdog-timeout flags
        self._straggler_run = 0
        self._degraded_run = 0
        #: (state, reason) history of every transition, oldest first.
        self.transitions: list[tuple[str, str]] = []

    def _goto(self, state: str, reason: str):
        if state != self.state:
            self.state = state
            self.reason = reason
            self.transitions.append((state, reason))

    def observe(self, *, faults: int = 0, straggler: bool = False,
                watchdog_timeout: bool = False) -> str:
        """Fold one scheduler iteration's signals in; returns the state.

        ``faults`` is the iteration's quarantine + corruption delta —
        both are one-slot blast-radius events individually, but a
        replica producing them at a sustained rate has a sick device,
        and routing fresh requests onto it just grows the handoff.
        """
        with self._lock:
            return self._observe_locked(
                faults=faults, straggler=straggler,
                watchdog_timeout=watchdog_timeout)

    def _observe_locked(self, *, faults: int, straggler: bool,
                        watchdog_timeout: bool) -> str:
        if self.state == DEAD:
            return self.state
        self._faults.append(int(faults))
        if len(self._faults) > self.window:
            self._faults.pop(0)
        self._timeouts.append(bool(watchdog_timeout))
        if len(self._timeouts) > self.window:
            self._timeouts.pop(0)
        self._straggler_run = self._straggler_run + 1 if straggler else 0

        rate = sum(1 for f in self._faults if f) / len(self._faults)
        # A watchdog timeout degrades until a full clean window has
        # passed since — it ages out of the sliding window the same way
        # the fault rate does, so one timeout is a brown-out, not a
        # death sentence.
        sick = (rate >= self.quarantine_rate_limit
                or self._straggler_run >= self.straggler_limit
                or any(self._timeouts))
        if sick:
            if self.state == HEALTHY:
                why = (f"fault rate {rate:.2f}" if rate
                       >= self.quarantine_rate_limit
                       else f"{self._straggler_run} consecutive stragglers"
                       if self._straggler_run >= self.straggler_limit
                       else "watchdog timeout")
                self._goto(DEGRADED, why)
            self._degraded_run += 1
            if self._degraded_run >= self.dead_after_degraded:
                self._goto(DEAD, f"degraded for {self._degraded_run} "
                                 "consecutive observations")
        else:
            self._degraded_run = 0
            if self.state == DEGRADED:
                self._goto(HEALTHY, "clean observation window")
        return self.state

    def mark_dead(self, reason: str):
        """Terminal, externally observed death (ReplicaKilled, dispatch
        retries exhausted, device error).  Idempotent."""
        with self._lock:
            self._goto(DEAD, reason)

    def status(self) -> tuple[str, str]:
        """Atomic (state, reason) pair — the torn-read-free way for a
        router loop to report a verdict an observer thread may be
        changing concurrently."""
        with self._lock:
            return self.state, self.reason

    @property
    def routable(self) -> bool:
        """True iff the router may place NEW requests here."""
        with self._lock:
            return self.state == HEALTHY
