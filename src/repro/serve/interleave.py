"""Deterministic forced-preemption drill: the runtime witness for the
host-tier static audit.

``repro.analysis.hostsafety`` *claims*, statically, that the serving
stack's cross-thread state is safe: every shared write holds its lock,
abandoned watchdog/saver threads are fenced off by generations, loop
guards sample consistent epochs.  This module is the dynamic complement:
a seeded scheduler that forces an OS-level preemption window at exactly
the boundaries the audit reasons about — lock acquire/release and jit
dispatch pre/post — while a replica fleet serves a chaos workload
(pinned NaN + dispatch drop, watchdogged, snapshotting every window).
If any interleaving the static passes missed can corrupt a stream, a
forced schedule is how it shows up; the drill asserts every request's
tokens stay **bit-identical to a fault-free single-engine run** across
every schedule.

Determinism: each preemption decision is keyed by ``(seed, tag,
per-tag-index)``, not by global arrival order — so the decision sequence
at each boundary class is reproducible per seed even though threads
reach the boundaries in racy order.

Instrumentation hooks (both production no-ops):

* :func:`repro.ft.watchdog.set_lock_factory` — every lock in the
  watchdog / checkpoint-saver / health-monitor stack comes from
  ``make_lock()``; the drill swaps in :class:`InstrumentedLock`.
* ``repro.serve.engine.dispatch_hook`` — called around every
  fault-plumbed jit dispatch, inside the watchdog worker thread.

CLI (tier-1 lane 3f)::

    python -m repro.serve.interleave --arch rwkv6-1.6b --seeds 8
"""

from __future__ import annotations

import argparse
import collections
import contextlib
import random
import shutil
import sys
import tempfile
import threading
import time

from repro.ft import watchdog as W


class ForcedSchedule:
    """Seeded preemption forcing at instrumented boundaries.

    :meth:`point` is called at every boundary with a tag; the decision
    (preempt or not, and for how long) is a pure function of
    ``(seed, tag, index-of-this-tag)``.  A "preemption" is a short
    ``time.sleep`` — it releases the GIL, so any thread waiting at a
    racy boundary actually gets scheduled into the window.
    """

    def __init__(self, seed: int, p_preempt: float = 0.5,
                 max_sleep_s: float = 0.002):
        self.seed = int(seed)
        self.p_preempt = float(p_preempt)
        self.max_sleep_s = float(max_sleep_s)
        self.active = True
        self._state_lock = threading.Lock()   # raw: guards counters only
        self.counts: collections.Counter = collections.Counter()
        self.preemptions = 0

    def point(self, tag: str):
        """One boundary crossing; deterministically maybe-preempt."""
        if not self.active:
            return
        with self._state_lock:
            idx = self.counts[tag]
            self.counts[tag] += 1
        rng = random.Random(f"{self.seed}:{tag}:{idx}")
        if rng.random() < self.p_preempt:
            with self._state_lock:
                self.preemptions += 1
            time.sleep(rng.random() * self.max_sleep_s)
        else:
            time.sleep(0)   # still a switch point, just a zero-width one

    def decisions(self, tag: str, n: int) -> list[bool]:
        """The first ``n`` preempt/no-preempt decisions for ``tag`` —
        pure, for determinism tests; does not advance counters."""
        return [random.Random(f"{self.seed}:{tag}:{i}").random()
                < self.p_preempt for i in range(n)]


class InstrumentedLock:
    """A ``threading.Lock`` that routes acquire/release through a
    :class:`ForcedSchedule` — forcing contention windows right before a
    lock is taken, while it is held, and right before it is dropped."""

    def __init__(self, sched: ForcedSchedule):
        self._sched = sched
        # hostsafety: ok(lock wrapper internals; discipline is checked at
        # the call sites that use this object *as* the lock)
        self._real = threading.Lock()

    def acquire(self, *args, **kwargs):
        self._sched.point("lock.acquire")
        # hostsafety: ok(lock wrapper: this IS the with-block plumbing)
        got = self._real.acquire(*args, **kwargs)
        if got:
            self._sched.point("lock.held")
        return got

    def release(self):
        self._sched.point("lock.release")
        # hostsafety: ok(lock wrapper: this IS the with-block plumbing)
        self._real.release()

    def locked(self) -> bool:
        return self._real.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


@contextlib.contextmanager
def installed(sched: ForcedSchedule):
    """Install ``sched`` at every instrumented boundary; restore the
    production hooks (and deactivate the schedule) on exit."""
    from repro.serve import engine as E

    prev_factory = W.set_lock_factory(lambda: InstrumentedLock(sched))
    prev_hook = E.dispatch_hook
    E.dispatch_hook = lambda phase, kind: sched.point(
        f"dispatch.{phase}.{kind}")
    try:
        yield sched
    finally:
        W.set_lock_factory(prev_factory)
        E.dispatch_hook = prev_hook
        # Locks created under the drill outlive it; mute them so late
        # teardown (saver drains, session close) runs at full speed.
        sched.active = False


# -- the drill -------------------------------------------------------------

#: (prompt_len, max_new_tokens) per request — ragged on purpose, so slot
#: recycling and admission interleave with decode under forced schedules.
REQUEST_SPEC = ((5, 7), (11, 5), (7, 9), (3, 6), (9, 8))


def _build(arch: str, replicas: int):
    import jax
    import numpy as np

    from repro.configs.registry import get_config
    from repro.model import model as M
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab_size, (pl,))
                    .astype(np.int32), max_new_tokens=nn)
            for pl, nn in REQUEST_SPEC]
    engines = [ServeEngine(cfg, params, max_len=96, decode_window=4)
               for _ in range(replicas)]
    return engines, reqs


def run_drill(arch: str = "rwkv6-1.6b", *, seeds=range(8),
              replicas: int = 2, p_preempt: float = 0.5,
              max_sleep_ms: float = 2.0,
              log=lambda msg: None) -> dict:
    """Serve the chaos workload under every forced schedule; assert
    stream bit-identity against the fault-free single-engine baseline.

    Raises ``RuntimeError`` on any divergence (or on a schedule that
    never actually preempted — a drill that forces nothing witnesses
    nothing).  Returns summary stats.
    """
    import numpy as np

    from repro.serve.chaos import ChaosInjector
    from repro.serve.fleet import FleetRouter

    engines, reqs = _build(arch, replicas)
    # recoverable=True: the fleet sessions size their rings for recovery,
    # and bit-identity only holds against a baseline sized the same way.
    base = engines[0].serve(reqs, slots=2, seed=0, recoverable=True)
    base_tokens = [np.asarray(r.tokens) for r in base]
    log(f"baseline: {sum(t.size for t in base_tokens)} tokens over "
        f"{len(reqs)} requests")

    stats = {"schedules": 0, "preemptions": 0, "points": 0}
    for seed in seeds:
        sched = ForcedSchedule(seed, p_preempt=p_preempt,
                               max_sleep_s=max_sleep_ms / 1e3)
        root = tempfile.mkdtemp(prefix=f"interleave_s{seed}_")
        try:
            with installed(sched):
                chaos = [ChaosInjector(seed=7, nan_at=(1,), drop_at=(3,)),
                         None][:replicas]
                fl = FleetRouter(
                    engines, reqs, slots=2, seed=0,
                    watchdog_timeout_s=30.0, snapshot_every=1,
                    snapshot_root=root, checksum_every=2, chaos=chaos)
                outs = fl.run()
        finally:
            shutil.rmtree(root, ignore_errors=True)
        bad = [o.outcome for o in outs
               if o.outcome not in ("ok", "eos", "recovered")]
        if bad:
            raise RuntimeError(
                f"schedule {seed}: unexpected outcomes {bad}")
        for ri, (b, o) in enumerate(zip(base_tokens, outs)):
            got = np.asarray(o.tokens)
            if not np.array_equal(b, got):
                raise RuntimeError(
                    f"schedule {seed}: request {ri} diverged from the "
                    f"fault-free baseline under forced preemption "
                    f"(want {b.tolist()}, got {got.tolist()})")
        n_pts = sum(sched.counts.values())
        if sched.preemptions == 0 or sched.counts["lock.acquire"] == 0:
            raise RuntimeError(
                f"schedule {seed} forced no preemptions "
                f"({dict(sched.counts)}) — the drill witnessed nothing")
        stats["schedules"] += 1
        stats["preemptions"] += sched.preemptions
        stats["points"] += n_pts
        log(f"schedule {seed}: bit-identical "
            f"({sched.preemptions}/{n_pts} boundaries preempted, "
            f"faults quarantined: "
            f"{sum(1 for o in outs if o.outcome == 'recovered')} "
            f"recovered)")
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve.interleave")
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--seeds", type=int, default=8,
                    help="number of forced schedules (seeds 0..N-1)")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--p-preempt", type=float, default=0.5)
    ap.add_argument("--max-sleep-ms", type=float, default=2.0)
    args = ap.parse_args(argv)
    try:
        stats = run_drill(
            args.arch, seeds=range(args.seeds), replicas=args.replicas,
            p_preempt=args.p_preempt, max_sleep_ms=args.max_sleep_ms,
            log=lambda msg: print(f"[interleave] {msg}"))
    except RuntimeError as e:
        print(f"[interleave] FAIL: {e}", file=sys.stderr)
        return 1
    print(f"[interleave] OK: {stats['schedules']} schedules bit-identical "
          f"({stats['preemptions']} forced preemptions over "
          f"{stats['points']} boundaries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
