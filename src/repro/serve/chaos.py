"""Chaos injection for the serve engine: seed-deterministic fault drills.

Fault isolation is only as real as its drills.  This module injects the
four failure modes the engine's recovery paths handle, all driven by one
seeded RNG so every drill is reproducible bit-for-bit:

  * **NaN-in-state** — poison one active slot's device state between
    decode windows (a RecState ``h`` row, or a KV cache row for
    attention-only archs).  Exercises in-window quarantine + host-side
    re-prefill recovery.
  * **dispatch exception** — raise :class:`DispatchDropped` *before* the
    jitted call consumes its (donated) arguments.  Exercises
    retry-with-backoff; pre-consumption is what makes the retry safe.
  * **hang** — spin inside the dispatch until the engine's
    :class:`~repro.ft.watchdog.StepWatchdog` fences the step off
    (``cancelled()`` flips), then abort *without* invoking the jit — the
    cooperative-cancel contract that keeps donated buffers valid for the
    retry.  Exercises watchdog timeout + retry.
  * **request drop** — an in-flight request vanishes (client gone).
    Exercises slot freeing with a typed ``dropped`` outcome and
    neighbor isolation.

``preempt_after`` additionally kills the whole engine loop
(:class:`EnginePreempted`) after N decode dispatches — the host-
preemption drill for snapshot/restore.

Injection sites take the *decode-dispatch index* so drills can pin
faults to exact points (``nan_at=(2,)``) instead of relying on rates;
rates (``nan_rate`` etc.) drive the bench / smoke lanes.  Every
injection is appended to :attr:`ChaosInjector.events` as
``(kind, dispatch_index, detail)`` and tallied in ``counters``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.model.attention import KVCache, PagedKVCache
from repro.model.recurrent import RecState


class DispatchDropped(RuntimeError):
    """Injected dispatch failure (raised before the jit consumed args)."""


class EnginePreempted(RuntimeError):
    """Injected host preemption: the serve loop dies mid-run."""


class ReplicaKilled(RuntimeError):
    """Injected replica death: the whole engine replica is lost mid-serve.

    Unlike :class:`EnginePreempted` (a preemption the *same* engine later
    resumes from via ``restore_from``), a killed replica never comes
    back — a fleet router must hand its in-flight requests to survivors
    from the victim's last snapshot."""


def poison_slot_state(state, slot: int):
    """Return ``state`` with ``slot``'s row made non-finite.

    Prefers recurrent leaves (the WKV (Dh, Dh) S / RG-LRU h — the
    paper-side loop-carried values); attention-only states get a NaN KV
    row at position 0 instead, which every later query of that slot
    attends to (global attention) or which the positional masks zero out
    only with exact-0 weights that still propagate NaN.  Neighbors'
    rows are untouched — the blast radius the engine must then prove is
    one slot.
    """
    has_rec = any(
        isinstance(n, RecState)
        for n in _nodes(state)
    )

    def fix(node):
        if isinstance(node, RecState):
            stacked = node.conv.ndim - 3
            idx = (slice(None),) * stacked + (slot,)
            return RecState(h=node.h.at[idx].set(jnp.nan), conv=node.conv)
        if isinstance(node, KVCache) and not has_rec:
            stacked = node.k.ndim - 4
            idx = (slice(None),) * stacked + (slot, slice(None), 0)
            return KVCache(k=node.k.at[idx].set(jnp.nan), v=node.v,
                           length=node.length)
        if isinstance(node, PagedKVCache) and not has_rec:
            # Poison the slot's most recently written position (always a
            # page the slot itself owns — shared prefix pages are below
            # its start length, so the blast radius stays one slot).
            stacked = node.k.ndim - 4      # pool is (P, ps, Hkv, Dh) (+L)
            tbl = np.asarray(node.page_table)
            ln = np.asarray(node.length)
            while tbl.ndim > 2:
                tbl, ln = tbl[0], ln[0]
            pos = max(int(ln[slot]) - 1, 0) % node.s_view
            page = int(tbl[slot, pos // node.page_size])
            if page < 0:
                return node
            idx = (slice(None),) * stacked + (
                page, pos % node.page_size, slice(None), 0)
            return PagedKVCache(
                k=node.k.at[idx].set(jnp.nan), v=node.v,
                page_table=node.page_table, length=node.length,
                s_view=node.s_view, page_size=node.page_size,
            )
        return node

    import jax

    return jax.tree.map(
        fix, state,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache, RecState)),
    )


def bitflip_slot_state(state, slot: int):
    """Return ``state`` with one bit of ``slot``'s row flipped.

    The silent-corruption injector: flipping the lowest mantissa bit of a
    finite float leaves it finite-but-wrong, so the ``isfinite``
    quarantine of PR 6 never fires — only the state checksum
    (:func:`repro.model.model.decode_state_checksum`) can catch it.  Same
    site preference as :func:`poison_slot_state`: a recurrent ``h``
    element when the arch has recurrent state, else a KV element of the
    slot's own row/page.  Neighbors' rows are untouched.
    """
    import jax
    import jax.lax as lax

    has_rec = any(isinstance(n, RecState) for n in _nodes(state))

    def flip_elt(arr, idx):
        elt = arr[idx]
        nbytes = jnp.dtype(elt.dtype).itemsize
        uint = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32,
                8: jnp.uint64}[nbytes]
        bits = lax.bitcast_convert_type(elt, uint)
        flipped = lax.bitcast_convert_type(bits ^ uint(1), elt.dtype)
        return arr.at[idx].set(flipped)

    done = False

    def fix(node):
        nonlocal done
        if done:
            return node
        if isinstance(node, RecState):
            stacked = node.conv.ndim - 3
            idx = (0,) * stacked + (slot,) + (0,) * (
                node.h.ndim - stacked - 1)
            done = True
            return RecState(h=flip_elt(node.h, idx), conv=node.conv)
        if isinstance(node, KVCache) and not has_rec:
            stacked = node.k.ndim - 4
            idx = (0,) * stacked + (slot, 0, 0, 0)
            done = True
            return KVCache(k=flip_elt(node.k, idx), v=node.v,
                           length=node.length)
        if isinstance(node, PagedKVCache) and not has_rec:
            stacked = node.k.ndim - 4
            tbl = np.asarray(node.page_table)
            ln = np.asarray(node.length)
            while tbl.ndim > 2:
                tbl, ln = tbl[0], ln[0]
            pos = max(int(ln[slot]) - 1, 0) % node.s_view
            page = int(tbl[slot, pos // node.page_size])
            if page < 0:
                return node
            idx = (0,) * stacked + (page, pos % node.page_size, 0, 0)
            done = True
            return PagedKVCache(
                k=flip_elt(node.k, idx), v=node.v,
                page_table=node.page_table, length=node.length,
                s_view=node.s_view, page_size=node.page_size,
            )
        return node

    return jax.tree.map(
        fix, state,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache, RecState)),
    )


def _nodes(state):
    import jax

    return jax.tree.leaves(
        state,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache, RecState)),
    )


@dataclasses.dataclass
class ChaosInjector:
    """Pluggable fault source for :meth:`ServeEngine.serve`.

    Rates are per-opportunity probabilities (one draw per decode window
    for ``nan_rate`` / ``req_drop_rate``, one per dispatch attempt for
    ``drop_rate`` / ``hang_rate``); ``*_at`` pin injections to exact
    decode-dispatch indices for deterministic drills.  All draws come
    from one ``numpy`` RNG seeded with ``seed`` — a fixed seed replays
    the identical fault schedule.
    """

    seed: int = 0
    nan_rate: float = 0.0
    drop_rate: float = 0.0
    hang_rate: float = 0.0
    req_drop_rate: float = 0.0
    bitflip_rate: float = 0.0
    nan_at: tuple = ()
    drop_at: tuple = ()
    hang_at: tuple = ()
    req_drop_at: tuple = ()
    #: Silent corruption: flip one state bit of an active slot (finite-
    #: but-wrong — only the checksum path can detect it).
    bitflip_at: tuple = ()
    #: Replica death: raise :class:`ReplicaKilled` once the replica's
    #: decode-dispatch count reaches the pinned index (fleet drills).
    replica_kill_at: tuple = ()
    preempt_after: int | None = None
    hang_poll_s: float = 0.005
    # Safety valve: an un-watched hang (no watchdog) ends here and turns
    # into a retried DispatchDropped instead of wedging the host loop.
    max_hang_s: float = 2.0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.events: list[tuple[str, int, Any]] = []
        self.counters = {
            "nan": 0, "drop": 0, "hang": 0, "req_drop": 0, "preempt": 0,
            "bitflip": 0, "replica_kill": 0,
        }
        self._fired: set[tuple[str, int]] = set()

    def _hit(self, name: str, index: int, rate: float) -> bool:
        """One draw per opportunity; pinned ``*_at`` indices fire exactly
        once (a retried dispatch keeps its index — without the once-only
        guard a pinned hang would re-trigger on every retry, forever)."""
        pinned = getattr(self, name + "_at")
        if index in pinned and (name, index) not in self._fired:
            self._fired.add((name, index))
            return True
        return self._rng.random() < rate

    # -- dispatch-path faults (run inside the watchdog thread) ----------

    def before_dispatch(self, kind: str, index: int,
                        cancelled: Callable[[], bool] | None = None):
        """Called inside the dispatch wrapper, before the jit is invoked.

        May raise :class:`DispatchDropped` (drop) or hang until the
        watchdog ``cancelled`` fence flips (hang).  Either way the jitted
        function — and with it the donated state — is never touched, so
        the engine's retry re-runs from valid buffers.
        """
        if kind != "window":
            return
        if self._hit("drop", index, self.drop_rate):
            self.counters["drop"] += 1
            self.events.append(("drop", index, None))
            raise DispatchDropped(f"injected dispatch drop at {index}")
        if self._hit("hang", index, self.hang_rate):
            self.counters["hang"] += 1
            self.events.append(("hang", index, None))
            t0 = time.monotonic()
            while time.monotonic() - t0 < self.max_hang_s:
                if cancelled is not None and cancelled():
                    # Watchdog fenced us off: abort without touching the
                    # donated state; our raise is discarded by the fence.
                    raise DispatchDropped(
                        f"injected hang at {index} (watchdog cancelled)"
                    )
                time.sleep(self.hang_poll_s)
            raise DispatchDropped(f"injected hang at {index} (unwatched)")

    # -- state / request faults (host side, between windows) ------------

    def maybe_poison(self, state, active: np.ndarray, index: int,
                     slot_req: list[int]):
        """Possibly NaN-poison one active slot.  Returns (state, slot|None)."""
        if not active.any():
            return state, None
        if self._hit("nan", index, self.nan_rate):
            slot = int(self._rng.choice(np.nonzero(active)[0]))
            self.counters["nan"] += 1
            self.events.append(("nan", index, slot_req[slot]))
            return poison_slot_state(state, slot), slot
        return state, None

    def maybe_bitflip(self, state, active: np.ndarray, index: int,
                      slot_req: list[int]):
        """Possibly flip one state bit of an active slot (silent
        corruption).  Returns (state, slot|None).  Same pinned
        ``bitflip_at`` fire-exactly-once contract as every other
        injector: a retried dispatch keeps its index, so the flip lands
        once and the retry converges."""
        if not active.any():
            return state, None
        if self._hit("bitflip", index, self.bitflip_rate):
            slot = int(self._rng.choice(np.nonzero(active)[0]))
            self.counters["bitflip"] += 1
            self.events.append(("bitflip", index, slot_req[slot]))
            return bitflip_slot_state(state, slot), slot
        return state, None

    def maybe_drop_request(self, active: np.ndarray, index: int,
                           slot_req: list[int]):
        """Possibly drop one in-flight request.  Returns slot|None."""
        if not active.any():
            return None
        if self._hit("req_drop", index, self.req_drop_rate):
            slot = int(self._rng.choice(np.nonzero(active)[0]))
            self.counters["req_drop"] += 1
            self.events.append(("req_drop", index, slot_req[slot]))
            return slot
        return None

    def check_preempt(self, decode_dispatches: int):
        if (self.preempt_after is not None
                and decode_dispatches >= self.preempt_after):
            self.counters["preempt"] += 1
            self.events.append(("preempt", decode_dispatches, None))
            raise EnginePreempted(
                f"injected preemption after {decode_dispatches} dispatches"
            )

    def check_replica_kill(self, decode_dispatches: int):
        """Raise :class:`ReplicaKilled` at a pinned decode-dispatch count.

        Pinned ``replica_kill_at`` indices fire exactly once (via the
        shared ``_fired`` guard): a fleet that retries or hands off work
        never re-kills the same point, so drills converge."""
        if (decode_dispatches in self.replica_kill_at
                and ("replica_kill", decode_dispatches) not in self._fired):
            self._fired.add(("replica_kill", decode_dispatches))
            self.counters["replica_kill"] += 1
            self.events.append(("replica_kill", decode_dispatches, None))
            raise ReplicaKilled(
                f"injected replica kill at dispatch {decode_dispatches}"
            )
