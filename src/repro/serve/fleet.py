"""Replica fleet: a health-checked pool of serve engines with snapshot
handoff.

One process, N :class:`~repro.serve.engine.ServeEngine` replicas — same
config and (shared) params, *distinct* device-state trees — fed from one
shared FIFO/deadline queue.  The fleet is the cluster-scale rendering of
the paper's point-to-point argument that the single engine already makes
per slot: a fault's blast radius is one replica's hand-off, never a
fleet-global barrier.

* **Routing** is recovery-aware least-loaded: a request goes to the
  healthy replica with the smallest modeled backlog, where backlog
  counts queued + in-flight work *plus* the
  :func:`~repro.core.cost_model.serve_recovery_steps` cost of the
  re-prefills sitting in the replica's recovery queue (a replica
  digesting handoffs is behind even when its queue looks short —
  :func:`~repro.core.cost_model.serve_fleet_drain` models the win).
* **Health** escalates the engine's own per-dispatch signals — watchdog
  heartbeats, straggler EWMA flags, quarantine and corruption counts —
  through a per-replica :class:`~repro.serve.health.ReplicaMonitor`:
  ``healthy -> degraded`` (no new admissions, in-flight work finishes)
  ``-> dead`` (state discarded).
* **Snapshot handoff**: replicas checkpoint atomically every
  ``snapshot_every`` dispatches through a background
  :class:`~repro.checkpoint.checkpoint.AsyncSaver`.  When a replica
  dies (:class:`~repro.serve.chaos.ReplicaKilled`, dispatch-retry
  exhaustion, or monitor escalation), its live memory is *discarded* —
  exactly what a real process loss means — and its undelivered requests
  resume on survivors from the accepted prefix recorded in its last
  on-disk snapshot.  The per-(request, token-index) sampling keys make
  every resumed stream bit-identical to the one the dead replica was
  producing, so a client cannot tell a handoff happened except by
  latency.

Requests whose snapshot shows no accepted token (never admitted on the
victim) re-enter the shared queue as fresh work — no recovery is
charged, and their outcome stays ``ok``/``eos``.
"""

from __future__ import annotations

import collections
import time
from pathlib import Path
from typing import Any

import numpy as np

from repro.core import cost_model
from repro.ft.watchdog import StepTimeout
from repro.serve import health as H
from repro.serve.chaos import EnginePreempted, ReplicaKilled
from repro.serve.engine import (OUTCOMES, RequestResult, ServeSession)

#: Snapshot ``meta`` vector length (see ``ServeEngine._serve_meta``) —
#: a handoff validates shape and ranges before trusting the payload.
META_LEN = 9

#: Fleet-level counters (per-replica serve stats stay on each engine's
#: ``last_serve_stats``).
FLEET_STAT_KEYS = (
    "rounds", "assignments", "handoffs", "replica_deaths",
    "handoff_requeued_fresh", "shared_deadline_hits", "shared_shed",
)


def read_snapshot_host(snapshot_dir, n: int):
    """Read the host half of a replica's latest serve snapshot and
    validate it as a handoff source.

    Returns ``None`` when no snapshot landed (the victim dies before its
    first ``snapshot_every`` boundary — survivors then re-run its
    requests from scratch).  Otherwise returns
    ``{"outputs": list[list[int]], "outcomes": list[str|None], "meta"}``.

    A corrupt or mismatched snapshot raises: handing off from a snapshot
    whose meta says a different request count / geometry would silently
    resume the wrong streams, which is worse than failing loudly.
    """
    from repro.checkpoint import checkpoint as C

    step = C.latest_step(snapshot_dir)
    if step is None:
        return None
    with np.load(Path(snapshot_dir) / f"step_{step}" / "arrays.npz") as data:
        if "meta" not in data.files:
            raise ValueError(f"snapshot {snapshot_dir} has no meta vector")
        meta = data["meta"]
        host = {k.split("/", 1)[1]: data[k] for k in data.files
                if k.startswith("host/")}
    if meta.shape != (META_LEN,):
        raise ValueError(
            f"snapshot meta has shape {meta.shape}, want ({META_LEN},) — "
            "not a serve snapshot this fleet can hand off from")
    if int(meta[3]) != n:
        raise ValueError(
            f"snapshot meta says {int(meta[3])} requests, fleet has {n} — "
            "refusing to hand off from a different serve")
    if min(int(meta[0]), int(meta[1]), int(meta[2])) < 1:
        raise ValueError(
            f"snapshot meta geometry {meta.tolist()} is malformed")
    off = host["out_off"]
    flat = host["out_flat"]
    if off.shape != (n + 1,) or int(off[-1]) != flat.size:
        raise ValueError("snapshot output offsets are inconsistent")
    outputs = [[int(t) for t in flat[off[i]: off[i + 1]]] for i in range(n)]
    outcomes = [None if c < 0 else OUTCOMES[int(c)]
                for c in host["outcome_codes"]]
    return {"outputs": outputs, "outcomes": outcomes, "meta": meta}


class FleetRouter:
    """Drive ``requests`` to completion across a pool of engine replicas.

    ``engines`` must be configured identically (same cfg, max_len,
    decode_window, paging) — the sessions they host derive identical jit
    shapes and snapshot meta from the shared request list, which is what
    makes a request's stream independent of which replica runs it.
    ``chaos`` is an optional per-replica list of
    :class:`~repro.serve.chaos.ChaosInjector` (``None`` entries = no
    chaos on that replica).
    """

    def __init__(self, engines, requests, *, slots: int = 4,
                 temperature: float = 0.0, top_k: int = 0,
                 eos_id: int | None = None, seed: int = 0,
                 deadline_ms: float | None = None,
                 max_queue: int | None = None,
                 watchdog_timeout_s: float | None = None,
                 max_dispatch_retries: int = 3,
                 retry_backoff_s: float = 0.02,
                 snapshot_every: int = 0,
                 snapshot_root: str | None = None,
                 checksum_every: int = 0,
                 chaos: list | None = None,
                 monitor_kw: dict | None = None,
                 clock=time.monotonic):
        if not engines:
            raise ValueError("need at least one engine replica")
        if snapshot_every > 0 and snapshot_root is None:
            raise ValueError("snapshot_every > 0 needs snapshot_root")
        if chaos is not None and len(chaos) != len(engines):
            raise ValueError("chaos must have one entry per engine")
        from repro.checkpoint import checkpoint as C

        self.engines = list(engines)
        self.reqs = list(requests)
        self.n = len(self.reqs)
        self.k_w = max(1, int(self.engines[0].decode_window))
        self.deadline_ms = deadline_ms
        self._clock = clock
        self.t_origin = clock()
        self.stats = {k: 0 for k in FLEET_STAT_KEYS}
        self.record: list[RequestResult | None] = [None] * self.n
        #: request -> replica currently responsible (-1 = shared queue).
        self.assigned = [-1] * self.n
        self.sessions: list[ServeSession] = []
        self.savers: list[Any] = []
        self.snapshot_dirs: list[str | None] = []
        for i, eng in enumerate(self.engines):
            sdir = (str(Path(snapshot_root) / f"replica{i}")
                    if snapshot_root is not None else None)
            saver = C.AsyncSaver() if snapshot_every > 0 else None
            self.sessions.append(ServeSession(
                eng, self.reqs, slots=slots, temperature=temperature,
                top_k=top_k, eos_id=eos_id, seed=seed,
                deadline_ms=deadline_ms,
                watchdog_timeout_s=watchdog_timeout_s,
                max_dispatch_retries=max_dispatch_retries,
                retry_backoff_s=retry_backoff_s,
                snapshot_every=snapshot_every, snapshot_dir=sdir,
                chaos=None if chaos is None else chaos[i],
                recoverable=True, checksum_every=checksum_every,
                clock=clock, clock_origin=self.t_origin, external=True,
                saver=saver))
            self.savers.append(saver)
            self.snapshot_dirs.append(sdir)
        self.monitors = [H.ReplicaMonitor(**(monitor_kw or {}))
                         for _ in self.engines]
        self.death_reasons: list[str | None] = [None] * len(self.engines)
        # Validation/capacity sheds happen identically in every session
        # (same engines, same request list): the first drain records them.
        for i in range(len(self.sessions)):
            self._drain(i)
        self.shared: collections.deque[int] = collections.deque(
            ri for ri in range(self.n) if self.record[ri] is None)
        #: handoff accepted-prefix staging: request -> tokens to resume
        #: from when it is next assigned.
        self._handoff_prefix: dict[int, list[int]] = {}
        if max_queue is not None:
            # Fleet-wide admission bound: every live replica's slots
            # admit immediately; at most max_queue requests may wait in
            # the shared queue beyond that.
            cap = slots * len(self.engines) + max(0, int(max_queue))
            while len(self.shared) > cap:
                ri = self.shared.pop()
                self.record[ri] = RequestResult(
                    tokens=np.zeros(0, np.int32), outcome="shed")
                self.stats["shared_shed"] += 1
        # Per-replica signal baselines for monitor deltas.
        self._sig = [dict(faults=0, stragglers=0, timeouts=0)
                     for _ in self.engines]

    # -- routing ---------------------------------------------------------

    def _live(self):
        return [i for i in range(len(self.sessions))
                if self.monitors[i].state != H.DEAD]

    def _routable(self):
        """Replicas new work may be placed on: healthy ones — or, when
        the whole fleet is browned out, the degraded survivors (serving
        slowly beats deadlocking the queue)."""
        ok = [i for i in self._live() if self.monitors[i].routable]
        return ok or self._live()

    def _load(self, i: int) -> int:
        """Modeled backlog in slot-steps: queued + in-flight dispatch
        work plus the recovery debt of pending handoff re-prefills."""
        s = self.sessions[i]
        return (s.queue_depth() * self.k_w
                + s.recovery_debt_steps(window=self.k_w))

    def _assign(self):
        cand = self._routable()
        if not cand:
            return
        while self.shared:
            # Least-loaded among routable replicas, bounded local queue:
            # a replica holds at most 2x its slot count so late-healing
            # replicas still find work in the shared queue.
            tgt = min(cand, key=self._load)
            sess = self.sessions[tgt]
            if sess.queue_depth() >= 2 * sess.b:
                break
            ri = self.shared.popleft()
            if self.record[ri] is not None:
                continue
            acc = self._handoff_prefix.pop(ri, None)
            if acc:
                sess.enqueue_handoff(ri, acc)
            else:
                sess.enqueue(ri)
            self.assigned[ri] = tgt
            self.stats["assignments"] += 1

    def _sweep_shared_deadlines(self):
        if self.deadline_ms is None and not any(
                getattr(r, "deadline_ms", None) is not None
                for r in self.reqs):
            return
        now_ms = (self._clock() - self.t_origin) * 1e3
        for _ in range(len(self.shared)):
            ri = self.shared.popleft()
            d = getattr(self.reqs[ri], "deadline_ms", None)
            dl = self.deadline_ms if d is None else d
            if dl is not None and now_ms > dl:
                # Shared-queue wait counts against the deadline: the
                # request dies here with whatever handoff prefix it had.
                acc = self._handoff_prefix.pop(ri, [])
                self.record[ri] = RequestResult(
                    tokens=np.asarray(acc, np.int32), outcome="deadline")
                self.stats["shared_deadline_hits"] += 1
            else:
                self.shared.append(ri)

    # -- results + health plumbing --------------------------------------

    def _drain(self, i: int):
        sess = self.sessions[i]
        for ri in sess.drain_done():
            if self.record[ri] is None:
                self.record[ri] = RequestResult(
                    tokens=np.asarray(sess.outputs[ri], np.int32),
                    outcome=sess.outcomes[ri],
                    recoveries=sess.recoveries[ri])

    def _observe(self, i: int):
        sess, sig = self.sessions[i], self._sig[i]
        faults = sess.stats["quarantines"]
        stragglers = sess.stats["stragglers"]
        timeouts = sess.stats["watchdog_timeouts"]
        state = self.monitors[i].observe(
            faults=faults - sig["faults"],
            straggler=stragglers > sig["stragglers"],
            watchdog_timeout=timeouts > sig["timeouts"])
        sig.update(faults=faults, stragglers=stragglers, timeouts=timeouts)
        return state

    def _handoff(self, victim: int, reason: str):
        """Discard a dead replica's live memory; resume its undelivered
        requests on survivors from its last atomic snapshot."""
        self.monitors[victim].mark_dead(reason)
        self.death_reasons[victim] = reason
        self.stats["replica_deaths"] += 1
        sess = self.sessions[victim]
        # Results already completed host-side were delivered to clients
        # before the failure — keep them.
        self._drain(victim)
        # The dead process's memory is gone; never run its close-time
        # device audit.  Its saver may still be mid-write: join it so the
        # snapshot we read is the newest one that LANDED (a failed write
        # surfaces as AsyncSaverError and falls back to the prior LATEST,
        # which is still atomic).
        sess.closed = True
        sess.eng.last_serve_stats = sess.stats
        if self.savers[victim] is not None:
            try:
                self.savers[victim].wait()
            except Exception:  # noqa: BLE001 — victim is dead either way
                pass
        snap = None
        if self.snapshot_dirs[victim] is not None:
            snap = read_snapshot_host(self.snapshot_dirs[victim], self.n)
        orphans = [ri for ri in range(self.n)
                   if self.assigned[ri] == victim
                   and self.record[ri] is None]
        for ri in orphans:
            acc = snap["outputs"][ri] if snap is not None else []
            self.assigned[ri] = -1
            if acc:
                self._handoff_prefix[ri] = acc
                self.stats["handoffs"] += 1
            else:
                # Never admitted on the victim (or no snapshot landed):
                # plain re-run, no recovery charged.
                self.stats["handoff_requeued_fresh"] += 1
            self.shared.append(ri)
        if not self._live() and (self.shared or self._handoff_prefix):
            outstanding = sum(1 for r in self.record if r is None)
            raise RuntimeError(
                f"all {len(self.sessions)} replicas dead with "
                f"{outstanding} requests outstanding (last death: {reason})")

    # -- the drive loop --------------------------------------------------

    @property
    def done(self) -> bool:
        return all(r is not None for r in self.record)

    def step_round(self):
        """One fleet scheduler round: shared-queue deadline sweep,
        recovery-aware assignment, then one session step per live busy
        replica (with health observation and death handling)."""
        self.stats["rounds"] += 1
        self._sweep_shared_deadlines()
        self._assign()
        for i in self._live():
            sess = self.sessions[i]
            if not sess.busy:
                continue
            try:
                sess.step()
                self._drain(i)
                if self._observe(i) == H.DEAD:
                    self._handoff(i, self.monitors[i].reason)
            except (ReplicaKilled, EnginePreempted, StepTimeout,
                    RuntimeError) as e:
                self._handoff(i, repr(e))

    def run(self) -> list[RequestResult]:
        try:
            while not self.done:
                before = sum(1 for r in self.record if r is not None)
                self.step_round()
                after = sum(1 for r in self.record if r is not None)
                # Post-round state: a round that completed nothing is
                # still progress if work remains in flight (busy
                # session) or schedulable (shared queue) — only the
                # all-idle, all-drained case is a wedge.
                busy = any(self.sessions[i].busy for i in self._live())
                if after == before and not busy and not self.shared:
                    raise RuntimeError(
                        "fleet made no progress with requests outstanding")
        finally:
            self.close()
        return self.results()

    def close(self):
        for i in self._live():
            self.sessions[i].close()

    def results(self) -> list[RequestResult]:
        missing = [ri for ri, r in enumerate(self.record) if r is None]
        if missing:
            raise RuntimeError(f"requests {missing} never completed")
        return list(self.record)

    def stats_by_replica(self) -> list[dict]:
        """Per-replica serve stats (engine ``last_serve_stats`` after the
        session closed — for a dead replica, its stats at death)."""
        return [dict(s.stats) for s in self.sessions]
