"""Sharded, atomic, elastic checkpointing (no external deps).

Layout:  <dir>/step_<N>/
            manifest.json        {step, keys, shapes, dtypes, mesh_shape}
            arrays.npz           flattened param/opt tree ('/'-joined paths)
         <dir>/LATEST            atomically-renamed pointer file

Properties needed at thousand-node scale (and implemented here at
container scale, same logic):
  * **atomicity** — writes go to ``step_<N>.tmp`` and are renamed only after
    fsync; a crash mid-save never corrupts the restore point.
  * **elasticity** — arrays are stored *unsharded by logical shape*; restore
    re-places them under whatever mesh/sharding the new job uses (the mesh
    shape in the manifest is advisory, not binding).
  * **async** — ``save_async`` snapshots to host memory synchronously (one
    device->host copy) and writes in a background thread, overlapping I/O
    with the next training steps.
  * **resumable data** — the step index in the manifest re-keys the
    stateless data pipeline exactly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.watchdog import make_lock


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str | os.PathLike, step: int, tree: Any, *, mesh_shape=None):
    """Synchronous atomic save."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest_tmp = directory / "LATEST.tmp"
    latest_tmp.write_text(str(step))
    os.replace(latest_tmp, directory / "LATEST")
    return final


class AsyncSaverError(RuntimeError):
    """A background checkpoint write failed (surfaced on the next
    :meth:`AsyncSaver.save_async` / :meth:`AsyncSaver.wait`)."""


class AsyncSaver:
    """Background-thread checkpoint writer (one in flight at a time).

    A failed background write is *not* silently dropped: the exception is
    captured and re-raised (wrapped in :class:`AsyncSaverError`) from the
    next ``save_async`` or ``wait`` call.  A consumer that restores from
    "the last snapshot" must find out that the last snapshot never landed
    — a recovery source that failed silently is worse than none.

    A *stalled* write is not allowed to hang the caller either:
    :meth:`wait` joins the writer with a timeout (``timeout_s``, per-call
    overridable) and raises :class:`AsyncSaverError` if the thread is
    still alive when it expires.  The abandoned writer is fenced off by a
    generation counter — like the step watchdog's, because a Python
    thread cannot be killed: if it eventually finishes, its late error
    (or success) is discarded (``stale_discarded`` counts them) instead
    of being misattributed to a later write.
    """

    def __init__(self, timeout_s: float = 600.0):
        self.timeout_s = timeout_s
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self._gen = 0
        self._lock = make_lock()
        self.stale_discarded = 0
        self.stalls = 0

    def _write(self, directory, step, host_tree, mesh_shape, gen):
        try:
            save(directory, step, host_tree, mesh_shape=mesh_shape)
            err = None
        except BaseException as e:  # noqa: BLE001 — re-raised at next drain
            err = e
        with self._lock:
            if gen != self._gen:        # fenced: a timed-out wait() moved on
                self.stale_discarded += 1
                return
            if err is not None:
                self._error = err

    def save_async(self, directory, step, tree, *, mesh_shape=None):
        self.wait()
        # Snapshot to host synchronously (cheap vs. step time), write async.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        with self._lock:
            self._gen += 1
            gen = self._gen
        self._thread = threading.Thread(
            target=self._write,
            args=(directory, step, host_tree, mesh_shape, gen),
            daemon=True,
        )
        self._thread.start()

    def wait(self, timeout_s: float | None = None):
        t = self._thread
        if t is not None:
            limit = self.timeout_s if timeout_s is None else timeout_s
            t.join(limit)
            if t.is_alive():
                with self._lock:
                    # Fence the stalled writer off before abandoning it:
                    # its eventual result belongs to no one now.
                    self._gen += 1
                    self.stalls += 1
                self._thread = None
                raise AsyncSaverError(
                    f"background checkpoint write still running after "
                    f"{limit}s — stalled writer abandoned (its late "
                    "result will be discarded)")
            self._thread = None
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise AsyncSaverError("background checkpoint save failed") from err


def latest_step(directory) -> int | None:
    latest = Path(directory) / "LATEST"
    if not latest.exists():
        return None
    return int(latest.read_text().strip())


def restore(directory, template: Any, *, step: int | None = None,
            shardings: Any = None):
    """Restore into the structure of ``template`` (values ignored).

    ``shardings``: optional matching tree of NamedSharding for elastic
    re-placement on a (possibly different) mesh — each array is placed
    directly into its new layout via ``jax.device_put``.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = directory / f"step_{step}"
    with np.load(path / "arrays.npz") as data:
        flat = {k: data[k] for k in data.files}

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(paths)
    )
    leaves = []
    for (path_elems, leaf), shard in zip(paths, shard_leaves):
        key = "/".join(_path_str(p) for p in path_elems)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jnp.asarray(arr))
    return treedef.unflatten(leaves), step
