"""Mixture-of-Experts with sort-based capacity dispatch and expert parallelism.

The dispatch is *point-to-point token forwarding*: tokens are sorted by
destination expert and gathered into per-expert buffers (on the production
mesh the expert axis is sharded over "model", so the gather lowers to an
all-to-all-class exchange) — the dMT-CGRA pattern of sending a value
directly to its consumer rather than staging it in a shared buffer behind a
barrier.  Dropped-on-overflow capacity semantics (standard Switch/DBRX
style); the residual path carries dropped tokens unchanged.

Router math in float32.  DBRX: 16 experts top-4; Qwen3-MoE: 128 experts
top-8 with normalized top-k probabilities (both fine-grained, no shared
expert).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.model.sharding import constrain, gather_for_use


def init_moe(mk, cfg, name: str):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    # Experts take the "model" axis (EP); within an expert the FFN dims stay
    # local (no TP inside an expert — fine-grained experts are small), and
    # the d_model axis carries FSDP over "data".
    p = {
        "router": mk(f"{name}.router", (d, e), ("embed", "experts")),
        "w_gate": mk(f"{name}.w_gate", (e, d, f), ("experts", "embed", None)),
        "w_up": mk(f"{name}.w_up", (e, d, f), ("experts", "embed", None)),
        "w_down": mk(f"{name}.w_down", (e, f, d), ("experts", None, "embed")),
    }
    return p


def _topk_routing(logits: jax.Array, k: int):
    """Returns (weights (T,k) float32, experts (T,k) int32), renormalized."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, experts


def apply_moe(params, x: jax.Array, cfg, *, capacity_factor: float | None = None):
    """x: (B, T, D) -> (B, T, D).  Capacity-dropped top-k MoE."""
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    n = b * t
    cap = max(1, int(n * k * cf / e))
    # Hardware-align the per-expert buffer (lane width).
    cap = -(-cap // 8) * 8

    xf = x.reshape(n, d)
    router_logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    weights, experts = _topk_routing(router_logits, k)   # (n, k)

    # ---- build dispatch indices by stable-sorting assignments by expert ----
    flat_expert = experts.reshape(-1)                     # (n*k,)
    flat_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_weight = weights.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_weight = flat_weight[order]

    # Position of each assignment within its expert's run.
    counts = jnp.bincount(flat_expert, length=e)          # (e,)
    starts = jnp.cumsum(counts) - counts                  # run start offsets
    pos_in_expert = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_expert]
    keep = pos_in_expert < cap                            # capacity drop

    # Scatter token ids into the (e, cap) dispatch grid.
    slot = sorted_expert * cap + pos_in_expert            # (n*k,)
    slot = jnp.where(keep, slot, e * cap)                 # overflow -> spill row
    dispatch_tok = jnp.zeros(e * cap + 1, jnp.int32).at[slot].set(sorted_token + 1)
    dispatch_w = jnp.zeros(e * cap + 1, jnp.float32).at[slot].set(sorted_weight)
    dispatch_tok = dispatch_tok[: e * cap].reshape(e, cap)   # 0 = empty slot
    dispatch_w = dispatch_w[: e * cap].reshape(e, cap)

    # ---- gather -> expert FFN -> weighted scatter-add back ------------------
    valid = dispatch_tok > 0
    tok_idx = jnp.maximum(dispatch_tok - 1, 0)            # (e, cap)
    xe = jnp.take(xf, tok_idx.reshape(-1), axis=0).reshape(e, cap, d)
    xe = jnp.where(valid[..., None], xe, 0.0)
    xe = constrain(xe, "experts", "expert_cap", "act_embed")

    if cfg.mlp_type == "geglu":
        act = lambda g: jax.nn.gelu(g, approximate=True)
    else:
        act = jax.nn.silu
    g = cfg.fsdp_gather_weights
    w_gate = gather_for_use(params["w_gate"], ("experts", "embed", None), g)
    w_up = gather_for_use(params["w_up"], ("experts", "embed", None), g)
    w_down = gather_for_use(params["w_down"], ("experts", None, "embed"), g)
    h = act(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xe, w_up
    )
    h = constrain(h, "experts", "expert_cap", None)  # EP owns the model axis
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)
    ye = ye * dispatch_w[..., None]
    ye = jnp.where(valid[..., None], ye, 0.0)

    out = jnp.zeros((n + 1, d), ye.dtype).at[dispatch_tok.reshape(-1)].add(
        ye.reshape(-1, d)
    )[1:]
    out = out.reshape(b, t, d).astype(x.dtype)
    return constrain(out, "batch", "seq", "act_embed")


def load_balance_loss(router_logits: jax.Array, experts: jax.Array, e: int):
    """Switch-style auxiliary loss: E * sum(frac_tokens * frac_probs)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    frac_probs = probs.mean(axis=0)
    onehot = jax.nn.one_hot(experts[:, 0], e)
    frac_tokens = onehot.mean(axis=0)
    return e * jnp.sum(frac_tokens * frac_probs)
