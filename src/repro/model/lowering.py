"""Back-compat shim: lowering flags moved to :mod:`repro.core.lowering`
so the kernels layer can use them without importing the model package.
"""

from repro.core.lowering import scan_unroll, unrolled_cost_mode

__all__ = ["scan_unroll", "unrolled_cost_mode"]
