"""Model building blocks: norms, MLPs, embeddings, rotary embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.model.sharding import constrain, gather_for_use


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(mk, d: int, name: str):
    return {"scale": mk(f"{name}.scale", (d,), ("act_embed",), "ones")}


def rms_norm(params, x: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(mk, cfg, name: str):
    d, f = cfg.d_model, cfg.d_ff
    p = {}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = mk(f"{name}.w_gate", (d, f), ("embed", "ff"))
        p["w_up"] = mk(f"{name}.w_up", (d, f), ("embed", "ff"))
    else:  # relu2 (nemotron): no gating
        p["w_up"] = mk(f"{name}.w_up", (d, f), ("embed", "ff"))
    p["w_down"] = mk(f"{name}.w_down", (f, d), ("ff", "embed"))
    return p


def apply_mlp(params, x: jax.Array, cfg) -> jax.Array:
    g = cfg.fsdp_gather_weights
    w_up = gather_for_use(params["w_up"], ("embed", "ff"), g)
    w_down = gather_for_use(params["w_down"], ("ff", "embed"), g)
    if cfg.mlp_type == "swiglu":
        w_gate = gather_for_use(params["w_gate"], ("embed", "ff"), g)
        h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    elif cfg.mlp_type == "geglu":
        w_gate = gather_for_use(params["w_gate"], ("embed", "ff"), g)
        h = jax.nn.gelu(x @ w_gate, approximate=True) * (x @ w_up)
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(x @ w_up))
    else:
        raise ValueError(cfg.mlp_type)
    h = constrain(h, "batch", "seq", "act_ff")
    return h @ w_down


# --------------------------------------------------------------------------
# Embeddings / logits
# --------------------------------------------------------------------------

def init_embeddings(mk, cfg, name: str = "tok"):
    v = cfg.padded_vocab
    p = {"embedding": mk(f"{name}.embedding", (v, cfg.d_model),
                         ("vocab", "embed"), "normal", 0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = mk(f"{name}.unembed", (cfg.d_model, v),
                          ("embed", "vocab"))
    return p


def embed_tokens(params, tokens: jax.Array, cfg) -> jax.Array:
    x = jnp.take(params["embedding"], tokens, axis=0)
    return constrain(x.astype(cfg.dtype), "batch", "seq", "act_embed")


def logits_projection(params, x: jax.Array, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        w = gather_for_use(
            params["embedding"], ("vocab", "embed"), cfg.fsdp_gather_weights
        ).T
    else:
        w = gather_for_use(
            params["unembed"], ("embed", "vocab"), cfg.fsdp_gather_weights
        )
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        # Mask pad rows so they can never win the softmax/argmax.
        vid = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(vid >= cfg.vocab_size, -1e30, logits)
    return constrain(logits, "batch", "seq", "vocab")


# --------------------------------------------------------------------------
# Rotary embeddings (RoPE + qwen2-vl M-RoPE)
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: tuple[int, ...] | None = None) -> jax.Array:
    """x: (B, H, T, D).  positions: (B, T) or (3, B, T) for M-RoPE.

    M-RoPE (qwen2-vl): the half-dim frequency bands are partitioned into
    (temporal, height, width) sections; each section rotates by its own
    positional stream.  Text tokens carry identical t/h/w positions, making
    M-RoPE degenerate to 1D RoPE for them.
    """
    b, h, t, d = x.shape
    half = d // 2
    freqs = rope_frequencies(d, theta)  # (half,)

    if mrope_sections is not None:
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        assert sum(mrope_sections) == half, (mrope_sections, half)
        section_id = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.asarray(mrope_sections),
            total_repeat_length=half,
        )  # (half,) which positional stream each band uses
        pos = positions.astype(jnp.float32)  # (3, B, T)
        # angle[b, t, i] = pos[section_id[i], b, t] * freqs[i]
        angle = jnp.take(pos, section_id, axis=0)            # (half, B, T)
        angle = jnp.moveaxis(angle, 0, -1) * freqs           # (B, T, half)
    else:
        pos = positions.astype(jnp.float32)                  # (B, T)
        angle = pos[:, :, None] * freqs                      # (B, T, half)

    cos = jnp.cos(angle)[:, None, :, :]  # (B, 1, T, half)
    sin = jnp.sin(angle)[:, None, :, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
