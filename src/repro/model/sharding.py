"""Logical-axis sharding: one naming scheme, per-mode mesh rules.

Every parameter/activation dimension carries a *logical* name; a rules table
maps logical names to mesh axes per execution mode (train / prefill /
decode).  Model code annotates with :func:`constrain`; the launcher installs
the (mesh, rules) context.  Outside a context everything is a no-op, so the
same model code runs on 1 CPU device and on the 512-chip production mesh.

Parameter construction uses the ``mk`` protocol: every ``init_*`` function
receives a constructor ``mk(name, shape, axes, init)`` and is interpreted
three ways — real arrays (init), ShapeDtypeStructs (abstract, for the
dry-run), or PartitionSpecs (sharding) — from a single code path, so specs
can never drift from shapes.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --------------------------------------------------------------------------
# Rules: logical axis -> mesh axis (or tuple, or None)
# --------------------------------------------------------------------------

def make_rules(mesh: Mesh, mode: str) -> dict:
    """Sharding rules for a mesh with ("pod",)? + ("data", "model") axes."""
    axes = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    data = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    model = "model" if "model" in axes else None

    rules = {
        # parameters
        "vocab": model,
        "embed": data,        # FSDP/ZeRO-3: weights' d_model axis over data
        "heads_out": model,   # flattened n_heads*head_dim projection dim
        "kv_out": model,
        "ff": model,
        "experts": model,     # EP
        "rnn": model,
        "layers": None,
        "taps": None,
        "stats": None,
        # activations
        "batch": data,
        "seq": None,
        "act_embed": None,
        "act_ff": model,
        "act_heads": model,
        "kv_seq": None,
        "expert_cap": None,
    }
    if mode == "decode":
        # Batched decode: batch over data, KV sequence over model — the
        # cache dominates memory and attention reads it once per step, so
        # seq-sharding it turns decode attention into per-shard partials +
        # an LSE psum (flash-decoding) instead of a KV all-gather.
        rules["kv_seq"] = model
    elif mode == "decode_long":
        # batch=1: KV sequence sharded over *all* axes; batch unshardable.
        both = tuple(a for a in (data if isinstance(data, tuple) else (data,))
                     if a) + ((model,) if model else ())
        rules["batch"] = None
        rules["kv_seq"] = both if len(both) > 1 else (both[0] if both else None)
        rules["seq"] = None
    elif mode == "prefill":
        rules["seq"] = None
    elif mode == "prefill_seq":
        # Long-context prefill: the *sequence* goes over the model axis.
        # Recurrent blocks detect this rule (see seq_shard_info) and take
        # the sequence-parallel WKV path — only the O(Dh²) (decay, state)
        # segment summary crosses the seq axis (kernels/wkv/seqpar), never
        # the token activations the default GSPMD lowering would gather.
        # With the model axis spent on the sequence, the per-token feature
        # activations lose their model mapping (one spec cannot map an
        # axis twice); parameters keep theirs and GSPMD re-gathers them at
        # use — at long-context prompt lengths the activations dominate.
        rules["seq"] = model
        rules["act_ff"] = None
        rules["act_heads"] = None
    return rules


# --------------------------------------------------------------------------
# Context
# --------------------------------------------------------------------------

class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: dict | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: dict):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def axes_size(mesh: Mesh, axes) -> int:
    """Total mesh extent of a rules entry (axis name, tuple of names, or
    None/empty → 1)."""
    if not axes:
        return 1
    axes = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def seq_shard_info():
    """(mesh, seq_axes, batch_axes) when the active rules map ``seq`` to a
    mesh axis (sequence-parallel mode, e.g. ``prefill_seq``); None
    otherwise.  Recurrent blocks consult this to dispatch the
    segment-summary sequence-parallel path."""
    if _CTX.mesh is None or _CTX.rules is None:
        return None
    seq = _CTX.rules.get("seq")
    if not seq:
        return None
    return _CTX.mesh, seq, _CTX.rules.get("batch")


def to_pspec(axes: tuple, rules: dict) -> P:
    parts = []
    used: set = set()
    for ax in axes:
        r = rules.get(ax) if ax is not None else None
        # A spec may map each mesh axis to at most one dimension.  When a
        # rules mode aliases two logical axes onto the same mesh axis
        # (e.g. prefill_seq maps ``seq`` to the model axis, which ``vocab``
        # also names), the earlier dimension keeps the mapping and later
        # ones replicate — for activation specs the sequence/batch dims
        # come first, which is exactly the priority sequence-parallel
        # modes want.
        vals = r if isinstance(r, tuple) else (r,)
        if any(v in used for v in vals if v is not None):
            r = None
        else:
            used.update(v for v in vals if v is not None)
        parts.append(r)
    # Trim trailing Nones for tidiness.
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def gather_for_use(w: jax.Array, axes: tuple, enabled: bool) -> jax.Array:
    """ZeRO-3 weight gathering: re-constrain a parameter with its data-mesh
    (FSDP) axes stripped, forcing an all-gather of the weight shard before
    use.  Without this GSPMD may instead contract against the sharded dim
    and all-reduce the (much larger) activations.  Model-axis (TP/EP)
    sharding is preserved."""
    if not enabled or _CTX.mesh is None or _CTX.rules is None:
        return w
    data_axes = {a for a in ("pod", "data") if a in _CTX.mesh.axis_names}

    def keep(ax):
        r = _CTX.rules.get(ax) if ax is not None else None
        vals = r if isinstance(r, tuple) else (r,)
        if any(v in data_axes for v in vals if v is not None):
            return None  # strip the FSDP mapping -> gathered at use
        return ax

    axes = tuple(keep(a) for a in axes[-w.ndim:])
    if len(axes) < w.ndim:
        axes = (None,) * (w.ndim - len(axes)) + axes  # leading stack dims
    return constrain(w, *axes)


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """Attach a sharding constraint using the active context (no-op outside)."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = to_pspec(axes, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


# --------------------------------------------------------------------------
# The mk protocol
# --------------------------------------------------------------------------

def init_mk(key: jax.Array, dtype) -> Callable:
    """Real-array constructor; splits the key per call (order-deterministic)."""
    counter = [0]

    def mk(name, shape, axes, init="normal", scale=None):
        counter[0] += 1
        sub = jax.random.fold_in(key, counter[0])
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            s = scale if scale is not None else (shape[0] ** -0.5 if len(shape) > 1 else 0.02)
            return (jax.random.normal(sub, shape, jnp.float32) * s).astype(dtype)
        raise ValueError(init)

    return mk


def abstract_mk(dtype) -> Callable:
    """ShapeDtypeStruct constructor (dry-run: no allocation)."""

    def mk(name, shape, axes, init="normal", scale=None):
        return jax.ShapeDtypeStruct(shape, dtype)

    return mk


def spec_mk(rules: dict) -> Callable:
    """PartitionSpec constructor (same code path as init => always in sync)."""

    def mk(name, shape, axes, init="normal", scale=None):
        return to_pspec(axes, rules)

    return mk
