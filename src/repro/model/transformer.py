"""Transformer assembly: pattern-aware blocks, scan-over-periods, enc-dec.

Layer patterns (gemma3 5×local:1×global, recurrentgemma rec:rec:attn) are
handled by scanning over *periods*: one period = one instance of the pattern
with heterogeneous sublayers; params are stacked over periods so the HLO
contains each layer body once (compile time & HLO size stay O(pattern), not
O(num_layers)).  Remainder layers (when the pattern doesn't divide
num_layers) are unrolled individually.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from repro.core.lowering import scan_unroll

from repro.model import attention as attn_mod
from repro.model import moe as moe_mod
from repro.model import recurrent as rec_mod
from repro.model.attention import KVCache
from repro.model.layers import apply_mlp, init_mlp, init_rmsnorm, rms_norm
from repro.model.recurrent import RecState
from repro.model.sharding import constrain

ATTN_KINDS = ("attn", "local", "global")


# --------------------------------------------------------------------------
# Block init / apply
# --------------------------------------------------------------------------

def init_block(mk, cfg, kind: str, name: str, *, cross: bool = False):
    p: dict[str, Any] = {"ln1": init_rmsnorm(mk, cfg.d_model, f"{name}.ln1"),
                         "ln2": init_rmsnorm(mk, cfg.d_model, f"{name}.ln2")}
    if kind in ATTN_KINDS:
        p["attn"] = attn_mod.init_attention(mk, cfg, f"{name}.attn")
    elif kind == "rec":
        p["rec"] = rec_mod.init_rglru_block(mk, cfg, f"{name}.rec")
    elif kind == "rwkv":
        p["rwkv"] = rec_mod.init_rwkv_block(mk, cfg, f"{name}.rwkv")
    else:
        raise ValueError(kind)
    if cross:
        p["ln_cross"] = init_rmsnorm(mk, cfg.d_model, f"{name}.ln_cross")
        p["cross"] = attn_mod.init_attention(mk, cfg, f"{name}.cross", cross=True)
    if cfg.num_experts:
        p["ffn"] = moe_mod.init_moe(mk, cfg, f"{name}.moe")
    else:
        p["ffn"] = init_mlp(mk, cfg, f"{name}.mlp")
    return p


def apply_block(
    params, x, cfg, kind: str, *, positions=None, causal=True,
    state=None, enc_out=None, token_mask=None,
):
    """Pre-norm block. Returns (x, new_state_or_None).

    ``token_mask`` (B, t) bool (decode only): masked tokens leave every
    state leaf untouched — KV slots unwritten, recurrent carries frozen.
    """
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    new_state = None
    if kind in ATTN_KINDS:
        out, new_state = attn_mod.apply_attention(
            params["attn"], h, cfg, kind=kind, positions=positions,
            causal=causal, kv_cache=state, token_mask=token_mask,
        )
    elif kind == "rec":
        out, new_state = rec_mod.apply_rglru_block(
            params["rec"], h, cfg, state=state, token_mask=token_mask)
    elif kind == "rwkv":
        out, new_state = rec_mod.apply_rwkv_block(
            params["rwkv"], h, cfg, state=state, token_mask=token_mask)
    else:
        raise ValueError(kind)
    x = x + out

    if enc_out is not None and "cross" in params:
        h = rms_norm(params["ln_cross"], x, cfg.norm_eps)
        out, _ = attn_mod.apply_attention(
            params["cross"], h, cfg, x_kv=enc_out, causal=False,
        )
        x = x + out

    h = rms_norm(params["ln2"], x, cfg.norm_eps)
    if cfg.num_experts:
        if cfg.moe_impl == "a2a":
            from repro.model.moe_a2a import apply_moe_sharded

            out = apply_moe_sharded(params["ffn"], h, cfg)
        else:
            out = moe_mod.apply_moe(params["ffn"], h, cfg)
    else:
        out = apply_mlp(params["ffn"], h, cfg)
    x = x + out
    return constrain(x, "batch", "seq", "act_embed"), new_state


# --------------------------------------------------------------------------
# Layer-group planning
# --------------------------------------------------------------------------

def plan_groups(cfg, num_layers: int | None = None):
    """(pattern, n_periods, remainder_kinds) for scan-over-periods."""
    pattern = cfg.pattern
    n = num_layers if num_layers is not None else cfg.num_layers
    p = len(pattern)
    n_periods = n // p
    remainder = tuple(pattern[i % p] for i in range(n_periods * p, n))
    return pattern, n_periods, remainder


def init_stack(mk_factory, cfg, *, num_layers=None, cross=False, name="dec"):
    """Init scanned period params (stacked over periods) + remainder list.

    ``mk_factory(i)`` returns an mk for period/remainder instance i — for
    real init each instance gets fresh keys; for abstract/spec modes the
    same constructor is reused and leaves are stacked.
    """
    pattern, n_periods, remainder = plan_groups(cfg, num_layers)

    def init_period(mk, tag):
        return [
            init_block(mk, cfg, kind, f"{name}.{tag}.l{j}", cross=cross)
            for j, kind in enumerate(pattern)
        ]

    if n_periods > 0:
        periods = [init_period(mk_factory(i), f"p{i}") for i in range(n_periods)]
        scanned = jax.tree.map(lambda *xs: _stack_leaves(xs), *periods)
    else:
        scanned = None
    rem = [
        init_block(mk_factory(n_periods + i), cfg, kind, f"{name}.r{i}", cross=cross)
        for i, kind in enumerate(remainder)
    ]
    return {"scanned": scanned, "remainder": rem}


def _stack_leaves(leaves):
    first = leaves[0]
    if isinstance(first, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct((len(leaves),) + first.shape, first.dtype)
    if _is_pspec(first):
        # PartitionSpec: prepend the (unsharded) layer axis.
        from jax.sharding import PartitionSpec as P
        return P(None, *first)
    return jnp.stack(leaves)


def _is_pspec(x):
    from jax.sharding import PartitionSpec
    return isinstance(x, PartitionSpec)


def apply_stack(
    stack_params, x, cfg, *, positions=None, causal=True,
    states=None, enc_out=None, num_layers=None, token_mask=None,
):
    """Apply scanned periods + remainder.  Returns (x, new_states_or_None).

    ``states``: {"scanned": stacked-state pytree or None, "remainder": list}.
    ``token_mask``: see :func:`apply_block` (decode-state freezing).
    """
    pattern, n_periods, remainder = plan_groups(cfg, num_layers)
    remat_policy = _remat_policy(cfg)

    def period_fn(x, period_params, period_states):
        new_states = []
        for sub_params, kind, sub_state in zip(
            period_params, pattern,
            period_states if period_states is not None else [None] * len(pattern),
        ):
            x, ns = apply_block(
                sub_params, x, cfg, kind, positions=positions, causal=causal,
                state=sub_state, enc_out=enc_out, token_mask=token_mask,
            )
            new_states.append(ns)
        return x, new_states

    if remat_policy is not None:
        period_fn = jax.checkpoint(period_fn, policy=remat_policy)

    new_scan_states = None
    if n_periods > 0:
        if states is None or states.get("scanned") is None:
            def scan_body(carry, period_params):
                y, _ = period_fn(carry, period_params, None)
                return y, None
            x, _ = jax.lax.scan(
                scan_body, x, stack_params["scanned"], unroll=scan_unroll()
            )
        else:
            def scan_body(carry, inputs):
                period_params, period_states = inputs
                y, ns = period_fn(carry, period_params, period_states)
                return y, ns
            x, new_scan_states = jax.lax.scan(
                scan_body, x, (stack_params["scanned"], states["scanned"]),
                unroll=scan_unroll(),
            )

    new_rem_states = []
    for i, (sub_params, kind) in enumerate(zip(stack_params["remainder"], remainder)):
        st = states["remainder"][i] if states is not None else None
        x, ns = apply_block(
            sub_params, x, cfg, kind, positions=positions, causal=causal,
            state=st, enc_out=enc_out, token_mask=token_mask,
        )
        new_rem_states.append(ns)

    if states is None:
        return x, None
    return x, {"scanned": new_scan_states, "remainder": new_rem_states}


def _remat_policy(cfg):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    raise ValueError(cfg.remat)
