"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and RWKV6 (Finch).

Both are direct consumers of the paper's technique: the hidden-state
hand-off h[t-1] -> h[t] is literally ``fromThreadOrConst<h, Δ=1, C=h0>``
(the paper's prefix-sum dataflow, Fig. 6), and the token-shift mixing of
RWKV is ``fromThreadOrConst<x, Δ=1, C=0>``.  Sequence-chunked execution
keeps the carries in VMEM (elevator token buffers) via the
``elevator_scan`` / ``token_shift`` / ``wkv`` Pallas kernels — the last
carrying the matrix-valued WKV state (Dh × Dh per head) across chunks.

Decode is O(1) per token: the recurrent state *is* the entire context —
which is why these archs run the long_500k shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.elevator_scan.ops import elevator_scan
from repro.kernels.token_shift.ops import token_shift
from repro.kernels.wkv.ops import wkv_fused
from repro.kernels.wkv.ref import wkv_chunked_ref, wkv_sequential_ref
from repro.kernels.wkv.seqpar import wkv_seqshard
from repro.model.layers import init_rmsnorm, rms_norm
from repro.model.sharding import (
    axes_size,
    constrain,
    gather_for_use,
    seq_shard_info,
)

_RGLRU_C = 8.0  # Griffin's fixed recurrence-sharpness constant


class RecState(NamedTuple):
    """Decode-time state for one recurrent layer."""

    h: jax.Array           # RG-LRU hidden (B, d_rnn) | RWKV S (B, H, dk, dv)
    conv: jax.Array        # conv tail (B, width-1, d_rnn) | x_prev (B, 1, D)


# ==========================================================================
# RG-LRU (RecurrentGemma)
# ==========================================================================

def init_rglru_block(mk, cfg, name: str):
    d, dr, w = cfg.d_model, cfg.d_rnn, cfg.conv_width
    return {
        "w_y": mk(f"{name}.w_y", (d, dr), ("embed", "rnn")),
        "w_x": mk(f"{name}.w_x", (d, dr), ("embed", "rnn")),
        "conv_w": mk(f"{name}.conv_w", (w, dr), ("taps", "rnn"), "normal", 0.1),
        "gate_a": mk(f"{name}.gate_a", (dr, dr), ("embed", "rnn")),
        "gate_x": mk(f"{name}.gate_x", (dr, dr), ("embed", "rnn")),
        "log_lambda": mk(f"{name}.log_lambda", (dr,), ("rnn",), "normal", 0.5),
        "w_out": mk(f"{name}.w_out", (dr, d), ("rnn", "embed")),
    }


def _rglru_gates(params, xb):
    r = jax.nn.sigmoid(xb @ params["gate_a"])
    i = jax.nn.sigmoid(xb @ params["gate_x"])  # gates gathered by caller
    log_a = -_RGLRU_C * jax.nn.softplus(params["log_lambda"]) * r
    a = jnp.exp(log_a)
    gated_x = i * xb
    # sqrt(1 - a^2) normalizer keeps the state variance bounded.
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6)) * gated_x
    return a, b


def apply_rglru_block(params, x: jax.Array, cfg, *, state: RecState | None = None,
                      token_mask: jax.Array | None = None):
    """x: (B, T, D) -> ((B, T, D), new_state_or_None).

    ``token_mask`` (B, T) bool (stateful calls): masked tokens are state
    no-ops — the recurrence sees (a=1, b=0) there, so ``h`` carries
    through unchanged, and the conv tail is gathered at each request's
    last *valid* tokens.  Must be a prefix mask per row.
    """
    b_, t, _ = x.shape
    g_ = cfg.fsdp_gather_weights
    w_y = gather_for_use(params["w_y"], ("embed", "rnn"), g_)
    w_x = gather_for_use(params["w_x"], ("embed", "rnn"), g_)
    y = jax.nn.gelu(x @ w_y, approximate=True)                  # gate branch
    xb = x @ w_x                                                # recurrent branch
    xb = constrain(xb, "batch", "seq", "rnn")

    # Temporal conv (width 4): the token-shift elevator chain.
    if state is not None:
        ext = jnp.concatenate([state.conv.astype(xb.dtype), xb], axis=1)
        xb_conv = token_shift(ext, params["conv_w"])[:, state.conv.shape[1]:]
        conv_tail = ext[:, ext.shape[1] - (cfg.conv_width - 1):]
    else:
        xb_conv = token_shift(xb, params["conv_w"])
        conv_tail = xb[:, t - (cfg.conv_width - 1):] if t >= cfg.conv_width - 1 else None

    gate_params = {
        "gate_a": gather_for_use(params["gate_a"], ("embed", "rnn"), g_),
        "gate_x": gather_for_use(params["gate_x"], ("embed", "rnn"), g_),
        "log_lambda": params["log_lambda"],
    }
    a, bb = _rglru_gates(gate_params, xb_conv)
    a32, b32 = a.astype(jnp.float32), bb.astype(jnp.float32)
    if token_mask is not None and state is not None:
        # Masked tokens are identity steps: h passes through, so h[:, -1]
        # is each request's state at its last valid token.
        m = token_mask[:, :, None]
        a32 = jnp.where(m, a32, 1.0)
        b32 = jnp.where(m, b32, 0.0)
    h0 = state.h.astype(jnp.float32) if state is not None else None
    # Stateful (serving) calls dispatch the persistent-state decode path
    # (kernels/elevator_scan/decode): h rides a VMEM carry across the
    # window's tokens instead of round-tripping HBM per token.
    h32 = elevator_scan(a32, b32, h0, decode=state is not None)
    h = h32.astype(x.dtype)

    new_state = None
    if state is not None:
        if token_mask is not None:
            # Conv tail at each request's last valid tokens: rows
            # counts..counts+width-2 of [old tail | window] — all-False
            # rows keep the old tail verbatim.
            counts = jnp.sum(token_mask, axis=1, dtype=jnp.int32)
            idx = counts[:, None] + jnp.arange(cfg.conv_width - 1,
                                               dtype=jnp.int32)[None]
            conv_tail = jnp.take_along_axis(ext, idx[:, :, None], axis=1)
        # State read off the f32 scan output (not the model-dtype cast):
        # a frozen slot must round-trip bit-identically even under bf16.
        new_state = RecState(h=h32[:, -1], conv=conv_tail)
    out = (h * y) @ gather_for_use(params["w_out"], ("rnn", "embed"), g_)
    return constrain(out, "batch", "seq", "act_embed"), new_state


# ==========================================================================
# RWKV6 (Finch)
# ==========================================================================

RWKV_HEAD_DIM = 64


def init_rwkv_block(mk, cfg, name: str):
    d = cfg.d_model
    return {
        "mu": mk(f"{name}.mu", (5, d), ("taps", "embed"), "normal", 0.2),
        "w_r": mk(f"{name}.w_r", (d, d), ("embed", "heads_out")),
        "w_k": mk(f"{name}.w_k", (d, d), ("embed", "heads_out")),
        "w_v": mk(f"{name}.w_v", (d, d), ("embed", "heads_out")),
        "w_g": mk(f"{name}.w_g", (d, d), ("embed", "heads_out")),
        # Data-dependent decay (the Finch signature): base + low-rank delta.
        "w_decay_base": mk(f"{name}.w_decay_base", (d,), ("heads_out",), "normal", 0.5),
        "w_decay_lora_a": mk(f"{name}.w_decay_a", (d, 64), ("embed", None)),
        "w_decay_lora_b": mk(f"{name}.w_decay_b", (64, d), (None, "heads_out")),
        "u_bonus": mk(f"{name}.u_bonus", (d,), ("heads_out",), "normal", 0.3),
        "w_o": mk(f"{name}.w_o", (d, d), ("heads_out", "embed")),
        "out_norm": init_rmsnorm(mk, d, f"{name}.out_norm"),
    }


def _rwkv_mix(x, x_prev, mu_row):
    """Token-shift lerp: x + (shift(x) - x) * mu  (Δ=1 elevator edge)."""
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    return x + (shifted - x) * mu_row


# Back-compat aliases: the WKV math now lives with its Pallas kernel in
# repro.kernels.wkv.ref (wkv_sequential_ref is re-exported above).
_wkv_chunked = wkv_chunked_ref


def apply_rwkv_block(params, x: jax.Array, cfg, *, state: RecState | None = None,
                     chunk: int = 16, use_kernel: bool | None = None,
                     token_mask: jax.Array | None = None):
    """x: (B, T, D) -> ((B, T, D), new_state_or_None).

    ``token_mask`` (B, T) bool (stateful calls): masked tokens are state
    no-ops — the WKV recurrence sees (w=1, k=0) there, so S carries
    through unchanged on every backend (chunked, decode, seq-parallel)
    without touching the kernels, and the token-shift state is gathered
    at each request's last *valid* token.  Must be a prefix mask per row.
    """
    b, t, d = x.shape
    h = d // RWKV_HEAD_DIM
    dh = RWKV_HEAD_DIM

    x_prev = (
        state.conv.astype(x.dtype)
        if state is not None
        else jnp.zeros((b, 1, d), x.dtype)
    )
    mu = params["mu"]
    xr = _rwkv_mix(x, x_prev, mu[0])
    xk = _rwkv_mix(x, x_prev, mu[1])
    xv = _rwkv_mix(x, x_prev, mu[2])
    xg = _rwkv_mix(x, x_prev, mu[3])
    xw = _rwkv_mix(x, x_prev, mu[4])

    gg = cfg.fsdp_gather_weights
    r = xr @ gather_for_use(params["w_r"], ("embed", "heads_out"), gg)
    k = xk @ gather_for_use(params["w_k"], ("embed", "heads_out"), gg)
    v = xv @ gather_for_use(params["w_v"], ("embed", "heads_out"), gg)
    g = jax.nn.silu(xg @ gather_for_use(params["w_g"], ("embed", "heads_out"), gg))
    # Data-dependent decay in (0, 1): exp(-exp(...)) (Finch).  The logit is
    # clamped so |log w| <= 4: the decay-ratio trick (kernels/wkv) holds
    # per-chunk decay products in fp32, which stays finite iff
    # chunk * |log w| < ~80 (chunk=16 below -> max exponent 64).
    decay_logit = params["w_decay_base"] + (
        jax.nn.tanh(xw @ params["w_decay_lora_a"]) @ params["w_decay_lora_b"]
    )
    decay_logit = jnp.clip(decay_logit.astype(jnp.float32), -6.0, 1.386)
    w = jnp.exp(-jnp.exp(decay_logit))

    def heads(z):
        return z.reshape(b, t, h, dh).swapaxes(1, 2)  # (B,H,T,Dh)

    r_, k_, v_, w_ = heads(r), heads(k), heads(v), heads(w.astype(x.dtype))
    if token_mask is not None and state is not None:
        # Masked tokens are identity steps for S: decay 1, zero k^T v.
        m = token_mask[:, None, :, None]                # (B, 1, T, 1)
        w_ = jnp.where(m, w_, jnp.ones((), w_.dtype))
        k_ = jnp.where(m, k_, jnp.zeros((), k_.dtype))
    u = params["u_bonus"].reshape(h, dh)

    h0 = (
        state.h.astype(jnp.float32)
        if state is not None
        else jnp.zeros((b, h, dh, dh), jnp.float32)
    )
    # Fused WKV elevator kernel: the (Dh, Dh) state rides a VMEM carry.
    # use_kernel=None is auto mode (the elevator_scan convention): the
    # kernel on TPU — for training too, since the custom VJP pairs it with
    # the reverse VMEM-adjoint sweep (kernels/wkv/bwd.py) — and the jnp
    # chunked path elsewhere.  Stateful (serving) calls set decode=True:
    # windows up to DECODE_WINDOW_MAX tokens take the persistent-state
    # decode kernels (kernels/wkv/decode — one HBM round-trip of S per
    # window, no chunk-divisibility constraint), longer cache-fill sweeps
    # fall through to the chunked kernel.  r/k/v/w go in the model dtype
    # (bf16 allowed): every backend accumulates in f32 internally and
    # returns out in the input dtype, so there is no caller-side upcast
    # doubling the kernel's HBM I/O.
    #
    # Under sequence-parallel rules (seq mapped to a mesh axis, e.g. the
    # prefill_seq mode) the WKV dispatches through the shard_map-ed
    # segment-summary path: each device runs the fused kernel on its
    # sequence shard and only the O(Dh²) (decay, state) summary crosses
    # the seq axis — device-space elevator edges instead of a state
    # all-gather (kernels/wkv/seqpar).
    seq_info = seq_shard_info()
    seq_plan = None
    if seq_info is not None and t > 1:
        mesh, seq_ax, batch_ax = seq_info
        n_seq = axes_size(mesh, seq_ax)
        n_b = axes_size(mesh, batch_ax)
        if (isinstance(seq_ax, str) and n_seq > 1 and t % n_seq == 0
                and b % n_b == 0):
            seq_plan = (mesh, seq_ax, batch_ax)
    if seq_plan is not None:
        mesh, seq_ax, batch_ax = seq_plan
        out, S = wkv_seqshard(
            r_, k_, v_, w_, u, h0,
            mesh=mesh, seq_axis=seq_ax, batch_axis=batch_ax,
            chunk=chunk, use_kernel=use_kernel,
        )
    else:
        out, S = wkv_fused(
            r_, k_, v_, w_, u, h0,
            chunk=chunk,
            use_kernel=use_kernel,
            decode=state is not None,
            # Per-config warn dedup: two configs sharing an awkward
            # (T, chunk) each get their own chunk-adjustment warning.
            warn_scope=getattr(cfg, "name", None),
        )

    out = out.swapaxes(1, 2).reshape(b, t, d).astype(x.dtype)
    out = rms_norm(params["out_norm"], out, cfg.norm_eps) * g
    out = out @ gather_for_use(params["w_o"], ("heads_out", "embed"), gg)

    new_state = None
    if state is not None:
        if token_mask is None:
            conv = x[:, -1:]
        else:
            # Token-shift state = each request's last valid token (row
            # counts of [x_prev | x]); an all-False row keeps x_prev.
            counts = jnp.sum(token_mask, axis=1, dtype=jnp.int32)
            ext = jnp.concatenate([x_prev, x], axis=1)
            conv = jnp.take_along_axis(ext, counts[:, None, None], axis=1)
        new_state = RecState(h=S, conv=conv)
    return constrain(out, "batch", "seq", "act_embed"), new_state
