"""Attention: GQA with RoPE/M-RoPE, full/local windows, KV-cache decode.

Train/prefill use the flash-attention Pallas kernel (jnp oracle on CPU);
decode uses a jnp path whose KV-sequence axis may be sharded — softmax over
the sharded axis lowers to the flash-decoding log-sum-exp combine under
GSPMD (partial max/sum per shard + small cross-shard reductions), i.e. the
point-to-point pattern rather than a KV all-gather.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.local_attention.ops import flash_attention
from repro.model.layers import apply_rope, init_rmsnorm, rms_norm
from repro.model.sharding import constrain, gather_for_use


class KVCache(NamedTuple):
    k: jax.Array          # (B, Hkv, S, Dh)
    v: jax.Array          # (B, Hkv, S, Dh)
    length: jax.Array     # () int32 — tokens filled


def init_attention(mk, cfg, name: str, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads + cfg.head_pad, cfg.num_kv_heads
    p = {
        "wq": mk(f"{name}.wq", (d, nq * hd), ("embed", "heads_out")),
        "wk": mk(f"{name}.wk", (d, nkv * hd), ("embed", "kv_out")),
        "wv": mk(f"{name}.wv", (d, nkv * hd), ("embed", "kv_out")),
        "wo": mk(f"{name}.wo", (nq * hd, d), ("heads_out", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = mk(f"{name}.bq", (nq * hd,), ("heads_out",), "zeros")
        p["bk"] = mk(f"{name}.bk", (nkv * hd,), ("kv_out",), "zeros")
        p["bv"] = mk(f"{name}.bv", (nkv * hd,), ("kv_out",), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(mk, hd, f"{name}.q_norm")
        p["k_norm"] = init_rmsnorm(mk, hd, f"{name}.k_norm")
    return p


def _project_qkv(params, x, x_kv, cfg):
    b, t, _ = x.shape
    s = x_kv.shape[1]
    nq, nkv, hd = cfg.num_heads + cfg.head_pad, cfg.num_kv_heads, cfg.head_dim
    g = cfg.fsdp_gather_weights
    q = x @ gather_for_use(params["wq"], ("embed", "heads_out"), g)
    k = x_kv @ gather_for_use(params["wk"], ("embed", "kv_out"), g)
    v = x_kv @ gather_for_use(params["wv"], ("embed", "kv_out"), g)
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = constrain(q, "batch", "seq", "act_heads")
    k = constrain(k, "batch", "seq", "act_heads")
    v = constrain(v, "batch", "seq", "act_heads")
    q = q.reshape(b, t, nq, hd).swapaxes(1, 2)     # (B, Hq, T, Dh)
    k = k.reshape(b, s, nkv, hd).swapaxes(1, 2)
    v = v.reshape(b, s, nkv, hd).swapaxes(1, 2)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm(params["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _softcap(logits, cap):
    return cap * jnp.tanh(logits / cap) if cap else logits


def apply_attention(
    params,
    x: jax.Array,
    cfg,
    *,
    kind: str = "attn",                    # attn | local | global
    positions: jax.Array | None = None,
    causal: bool = True,
    x_kv: jax.Array | None = None,         # cross-attention memory
    kv_cache: KVCache | None = None,       # decode
):
    """Returns (out, new_kv_cache_or_None)."""
    b, t, _ = x.shape
    cross = x_kv is not None
    src = x_kv if cross else x
    q, k, v = _project_qkv(params, x, src, cfg)

    window = cfg.attn_window if kind == "local" else None
    if positions is None:
        base = jnp.arange(t, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(base, (b, t))

    if not cross:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    new_cache = None
    if kv_cache is not None and not cross:
        # Decode: append this step's K/V (a window of t >= 1 tokens) and
        # attend to the cache.  Local layers use a ring buffer (slot =
        # pos mod S); the mod-arithmetic in _masked_insert is universal
        # because for a full-length cache length + t <= S.
        k_cache = _masked_insert(kv_cache.k, k, kv_cache.length)
        v_cache = _masked_insert(kv_cache.v, v, kv_cache.length)
        new_cache = KVCache(k_cache, v_cache, kv_cache.length + t)
        out = _decode_attention(
            q, k_cache, v_cache, kv_cache.length, cfg, window=window
        )
    else:
        out = flash_attention(
            q, k, v,
            causal=causal and not cross,
            window=window,
            use_kernel=None,
        )

    out = out.swapaxes(1, 2).reshape(
        b, t, (cfg.num_heads + cfg.head_pad) * cfg.head_dim
    )
    out = constrain(out, "batch", "seq", "act_heads")
    wo = gather_for_use(params["wo"], ("heads_out", "embed"), cfg.fsdp_gather_weights)
    return out @ wo, new_cache


def _masked_insert(cache: jax.Array, new: jax.Array, length: jax.Array):
    """Insert `new` (B,H,t,D) at absolute positions length..length+t-1
    along axis 2, ring-buffer aware (slot = pos mod S).

    Uses a positional where-mask instead of dynamic_update_slice so the
    cache's sequence sharding is preserved (no gather/dynamic-slice
    resharding under GSPMD) — each shard updates only the slots it owns:
    the eLDST write-once discipline.
    """
    s = cache.shape[2]
    t = new.shape[2]
    if t > s:
        # A window wider than the whole ring can never be represented —
        # static shapes, so reject at trace time.  Windows that *fit* but
        # exceed the state's insert_window contract
        # (model.init_decode_state) cannot be detected here: whether the
        # ring wraps depends on the traced ``length`` and on the max_len
        # cap the builder applied, so honoring insert_window >= K is the
        # caller's contract (ServeEngine always satisfies it) — violating
        # it on a local-attention layer silently truncates the context
        # the earlier in-window queries see.
        raise ValueError(
            f"decode window of {t} tokens exceeds cache size {s}; build the "
            f"state with init_decode_state(insert_window >= {t})"
        )
    idx = jnp.arange(s, dtype=jnp.int32)
    # The window token landing on each slot (ring: slot = pos mod S);
    # t <= S guarantees at most one writer per slot.
    off = jnp.mod(idx - length, s)
    if t == 1:
        sel = (off == 0)[None, None, :, None]
        return jnp.where(sel, new.astype(cache.dtype), cache)
    sel = off < t
    gathered = jnp.take(new.astype(cache.dtype), jnp.clip(off, 0, t - 1),
                        axis=2)
    return jnp.where(sel[None, None, :, None], gathered, cache)


def _decode_attention(q, k_cache, v_cache, cur_pos, cfg, *, window=None):
    """Windowed decode attention against a (possibly seq-sharded) KV cache.

    q: (B, Hq, t, Dh) with t >= 1 new tokens at absolute positions
    cur_pos..cur_pos+t-1 (``cur_pos`` == pre-insert cache length; the
    cache already contains the window's K/V).  Softmax over the cache axis
    is written max/exp/sum-explicitly; if `kv_seq` is sharded, GSPMD
    lowers it to per-shard partials + a tiny psum (flash-decoding
    combine).  Ring-buffer caches are handled positionally: post-insert,
    slot i holds absolute position last - ((last - i) mod S) with
    last = cur_pos + t - 1.  Queries mask causally *within* the window:
    query j attends only to slots whose absolute position is <= cur_pos+j.
    """
    b, hq, t, hd = q.shape
    nkv = k_cache.shape[1]
    group = hq // nkv
    s = k_cache.shape[2]
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(b, nkv, group, t, hd)
    logits = jnp.einsum(
        "bhgtd,bhsd->bhgts", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    logits = _softcap(logits, cfg.attn_logit_softcap)

    slot = jnp.arange(s, dtype=jnp.int32)
    last = cur_pos + t - 1
    abs_pos = last - jnp.mod(last - slot, s)         # newest pos <= last in slot
    qpos = cur_pos + jnp.arange(t, dtype=jnp.int32)  # (t,)
    valid = (abs_pos[None, :] >= 0) & (abs_pos[None, :] <= qpos[:, None])
    if window is not None:
        valid &= abs_pos[None, :] > (qpos[:, None] - window)
    valid = valid[None, None, None]                  # (1, 1, 1, t, s)
    logits = jnp.where(valid, logits, -1e30)

    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(valid, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgts,bhsd->bhgtd", p, v_cache.astype(jnp.float32))
    out = out / jnp.maximum(denom, 1e-30)
    return out.reshape(b, hq, t, hd).astype(q.dtype)
