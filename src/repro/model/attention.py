"""Attention: GQA with RoPE/M-RoPE, full/local windows, KV-cache decode.

Train/prefill use the flash-attention Pallas kernel (jnp oracle on CPU);
decode uses a jnp path whose KV-sequence axis may be sharded — softmax over
the sharded axis lowers to the flash-decoding log-sum-exp combine under
GSPMD (partial max/sum per shard + small cross-shard reductions), i.e. the
point-to-point pattern rather than a KV all-gather.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.local_attention.ops import flash_attention
from repro.model.layers import apply_rope, init_rmsnorm, rms_norm
from repro.model.sharding import constrain, gather_for_use


class KVCache(NamedTuple):
    k: jax.Array          # (B, Hkv, S, Dh)
    v: jax.Array          # (B, Hkv, S, Dh)
    length: jax.Array     # (B,) int32 — tokens filled per request (a scalar
    #                       broadcasts: every request at the same position,
    #                       the lockstep special case)


#: Physical page 0 of every page pool is the *null page*: never allocated,
#: never written (unmapped logical pages scatter with index -1 / mode
#: "drop", and gathers clip unmapped entries here), so it stays exactly
#: zero for the life of the pool — a masked read of an unmapped slot sees
#: the same zeros a dense cache's never-written slot holds.
NULL_PAGE = 0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """A KV cache stored as pooled fixed-size pages + a per-slot page table.

    The dense cache's ``(B, Hkv, S, Dh)`` sequence axis becomes
    indirection: logical ring slot ``i`` (= position mod ``s_view``) of
    request ``b`` lives at offset ``i % page_size`` of physical page
    ``page_table[b, i // page_size]``.  ``s_view`` is *exactly* the
    sequence extent the dense cache would have had (``max_len``, or the
    local ring ``min(max_len, window + insert_window - 1)``) — the last
    logical page may be partial — so the gathered view has the dense
    cache's shape and valid content, the positional masks in
    :func:`_decode_attention` apply unchanged, and token streams are
    bit-identical to the dense engine.  Freed/unmapped pages are
    unreachable by construction: an unmapped table entry is ``-1``, whose
    gather clips to the all-zero :data:`NULL_PAGE`, and every slot a
    stale page could alias maps to an absolute position the masks
    already reject.

    ``s_view`` and ``page_size`` are pytree aux data (static at trace
    time); the arrays are the children, so the cache rides ``lax.scan``
    stacking, donation, and checkpointing like any NamedTuple state node.
    """

    k: jax.Array           # (P, page_size, Hkv, Dh) pooled pages
    v: jax.Array           # (P, page_size, Hkv, Dh)
    page_table: jax.Array  # (B, NL) int32 physical page ids; -1 = unmapped
    length: jax.Array      # (B,) int32 — tokens filled per request
    s_view: int            # static: dense-equivalent sequence extent
    page_size: int         # static: tokens per page

    def tree_flatten(self):
        return ((self.k, self.v, self.page_table, self.length),
                (self.s_view, self.page_size))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def init_attention(mk, cfg, name: str, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads + cfg.head_pad, cfg.num_kv_heads
    p = {
        "wq": mk(f"{name}.wq", (d, nq * hd), ("embed", "heads_out")),
        "wk": mk(f"{name}.wk", (d, nkv * hd), ("embed", "kv_out")),
        "wv": mk(f"{name}.wv", (d, nkv * hd), ("embed", "kv_out")),
        "wo": mk(f"{name}.wo", (nq * hd, d), ("heads_out", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = mk(f"{name}.bq", (nq * hd,), ("heads_out",), "zeros")
        p["bk"] = mk(f"{name}.bk", (nkv * hd,), ("kv_out",), "zeros")
        p["bv"] = mk(f"{name}.bv", (nkv * hd,), ("kv_out",), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(mk, hd, f"{name}.q_norm")
        p["k_norm"] = init_rmsnorm(mk, hd, f"{name}.k_norm")
    return p


def _project_qkv(params, x, x_kv, cfg):
    b, t, _ = x.shape
    s = x_kv.shape[1]
    nq, nkv, hd = cfg.num_heads + cfg.head_pad, cfg.num_kv_heads, cfg.head_dim
    g = cfg.fsdp_gather_weights
    q = x @ gather_for_use(params["wq"], ("embed", "heads_out"), g)
    k = x_kv @ gather_for_use(params["wk"], ("embed", "kv_out"), g)
    v = x_kv @ gather_for_use(params["wv"], ("embed", "kv_out"), g)
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = constrain(q, "batch", "seq", "act_heads")
    k = constrain(k, "batch", "seq", "act_heads")
    v = constrain(v, "batch", "seq", "act_heads")
    q = q.reshape(b, t, nq, hd).swapaxes(1, 2)     # (B, Hq, T, Dh)
    k = k.reshape(b, s, nkv, hd).swapaxes(1, 2)
    v = v.reshape(b, s, nkv, hd).swapaxes(1, 2)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm(params["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _softcap(logits, cap):
    return cap * jnp.tanh(logits / cap) if cap else logits


def apply_attention(
    params,
    x: jax.Array,
    cfg,
    *,
    kind: str = "attn",                    # attn | local | global
    positions: jax.Array | None = None,
    causal: bool = True,
    x_kv: jax.Array | None = None,         # cross-attention memory
    kv_cache: KVCache | None = None,       # decode
    token_mask: jax.Array | None = None,   # (B, t) bool — decode validity
):
    """Returns (out, new_kv_cache_or_None).

    ``token_mask`` (decode only) marks which window tokens are real: masked
    tokens are not inserted into the cache and do not advance the
    per-request length, so a finished / empty slot's cache is untouched and
    pad tokens of a ragged prompt never become attendable.
    """
    b, t, _ = x.shape
    cross = x_kv is not None
    src = x_kv if cross else x
    q, k, v = _project_qkv(params, x, src, cfg)

    window = cfg.attn_window if kind == "local" else None
    if positions is None:
        base = jnp.arange(t, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(base, (b, t))

    if not cross:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    new_cache = None
    if kv_cache is not None and not cross:
        # Decode: append this step's K/V (a window of t >= 1 tokens) and
        # attend to the cache.  Local layers use a ring buffer (slot =
        # pos mod S); the mod-arithmetic in _masked_insert is universal
        # because for a full-length cache length + t <= S.  Lengths are
        # per-request: each slot inserts at — and attends from — its own
        # position.
        advance = (
            jnp.int32(t) if token_mask is None
            else jnp.sum(token_mask, axis=1, dtype=jnp.int32)
        )
        if isinstance(kv_cache, PagedKVCache):
            # Page-table indirection: scatter the window into the slots'
            # mapped pages, gather the dense-shaped view back, and run
            # the *same* positional-mask attention — values at every
            # valid slot equal the dense cache's, so the outputs are
            # bit-identical (masked slots contribute exactly-0 weights
            # either way).
            pool_k, pool_v = _paged_insert(kv_cache, k, v, token_mask)
            new_cache = PagedKVCache(
                pool_k, pool_v, kv_cache.page_table,
                kv_cache.length + advance,
                kv_cache.s_view, kv_cache.page_size,
            )
            k_cache = _paged_gather(new_cache, pool_k)
            v_cache = _paged_gather(new_cache, pool_v)
        else:
            k_cache = _masked_insert(kv_cache.k, k, kv_cache.length, token_mask)
            v_cache = _masked_insert(kv_cache.v, v, kv_cache.length, token_mask)
            new_cache = KVCache(k_cache, v_cache, kv_cache.length + advance)
        out = _decode_attention(
            q, k_cache, v_cache, kv_cache.length, cfg, window=window
        )
    else:
        out = flash_attention(
            q, k, v,
            causal=causal and not cross,
            window=window,
            use_kernel=None,
        )

    out = out.swapaxes(1, 2).reshape(
        b, t, (cfg.num_heads + cfg.head_pad) * cfg.head_dim
    )
    out = constrain(out, "batch", "seq", "act_heads")
    wo = gather_for_use(params["wo"], ("heads_out", "embed"), cfg.fsdp_gather_weights)
    return out @ wo, new_cache


def _lengths_2d(length: jax.Array, b: int) -> jax.Array:
    """Per-request lengths as (B, 1) int32; a scalar broadcasts (lockstep)."""
    return jnp.broadcast_to(jnp.reshape(length, (-1, 1)), (b, 1))


def _masked_insert(cache: jax.Array, new: jax.Array, length: jax.Array,
                   token_mask: jax.Array | None = None):
    """Insert `new` (B,H,t,D) at absolute positions length..length+t-1
    along axis 2 — per request: ``length`` is (B,) (or a scalar, which
    broadcasts), ring-buffer aware (slot = pos mod S per request).

    ``token_mask`` (B, t) drops individual window tokens from the insert:
    a masked token writes nothing, so a finished slot's cache — or the pad
    tail of a ragged prompt — stays bit-identical.

    Uses a positional where-mask instead of dynamic_update_slice so the
    cache's sequence sharding is preserved (no gather/dynamic-slice
    resharding under GSPMD) — each shard updates only the slots it owns:
    the eLDST write-once discipline.
    """
    b = cache.shape[0]
    s = cache.shape[2]
    t = new.shape[2]
    if t > s:
        # A window wider than the whole ring can never be represented —
        # static shapes, so reject at trace time.  (Windows that *fit* the
        # ring but exceed the state's insert_window contract are rejected
        # by model.decode_step, which knows the layer kinds and max_len.)
        raise ValueError(
            f"decode window of {t} tokens exceeds cache size {s}; build the "
            f"state with init_decode_state(insert_window >= {t})"
        )
    idx = jnp.arange(s, dtype=jnp.int32)
    # The window token landing on each slot (ring: slot = pos mod S);
    # t <= S guarantees at most one writer per slot.
    off = jnp.mod(idx[None, :] - _lengths_2d(length, b), s)   # (B, S)
    sel = off < t
    if token_mask is not None:
        # Only real tokens write: look up each slot's candidate window
        # token in the mask.
        sel &= jnp.take_along_axis(
            token_mask, jnp.clip(off, 0, t - 1), axis=1
        )
    if t == 1:
        sel &= off == 0
        return jnp.where(sel[:, None, :, None], new.astype(cache.dtype), cache)
    gathered = jnp.take_along_axis(
        new.astype(cache.dtype), jnp.clip(off, 0, t - 1)[:, None, :, None],
        axis=2,
    )
    return jnp.where(sel[:, None, :, None], gathered, cache)


def _paged_gather(cache: PagedKVCache, pool: jax.Array) -> jax.Array:
    """Gather a pooled cache into the dense view ``(B, Hkv, s_view, Dh)``.

    Logical ring slot ``i`` reads offset ``i % page_size`` of physical
    page ``page_table[b, i // page_size]``.  Unmapped entries (-1) clip
    to the all-zero :data:`NULL_PAGE`; every such slot is already
    rejected by the positional masks (it would alias a position beyond
    the slot's fill), so the zeros only guarantee finiteness, exactly
    like a dense cache's never-written slots.
    """
    s, ps = cache.s_view, cache.page_size
    b = cache.page_table.shape[0]
    p, _, hkv, dh = pool.shape
    i = jnp.arange(s, dtype=jnp.int32)
    pages = jnp.take(cache.page_table, i // ps, axis=1)        # (B, S)
    flat = jnp.clip(pages, 0) * ps + (i % ps)[None, :]
    out = jnp.take(pool.reshape(p * ps, hkv, dh), flat.reshape(-1), axis=0)
    return out.reshape(b, s, hkv, dh).swapaxes(1, 2)


def _paged_insert(cache: PagedKVCache, k_new: jax.Array, v_new: jax.Array,
                  token_mask: jax.Array | None = None):
    """Paged dual of :func:`_masked_insert`: scatter ``k_new``/``v_new``
    (B, Hkv, t, Dh) at absolute positions ``length..length+t-1`` into the
    slots' mapped pages (ring slot = pos mod ``s_view``, then page-table
    indirection).  Masked or unmapped targets scatter with index -1 /
    ``mode="drop"`` — nothing is written, so a finished slot's pages, a
    shared read-only prefix page (positions below the slot's start
    length are never insert targets), and the null page all stay
    bit-identical.  Returns (new_k_pool, new_v_pool).
    """
    b, hkv, t, dh = k_new.shape
    s, ps = cache.s_view, cache.page_size
    if t > s:
        raise ValueError(
            f"decode window of {t} tokens exceeds paged view size {s}; "
            f"build the state with init_decode_state(insert_window >= {t})"
        )
    pos = _lengths_2d(cache.length, b) + jnp.arange(t, dtype=jnp.int32)[None]
    slot = jnp.mod(pos, s)                                     # (B, t)
    pages = jnp.take_along_axis(cache.page_table, slot // ps, axis=1)
    ok = pages >= 0
    if token_mask is not None:
        ok &= token_mask
    flat = jnp.where(ok, pages * ps + slot % ps, -1).reshape(-1)

    def put(pool, new):
        pf = pool.reshape(-1, hkv, dh)
        src = new.swapaxes(1, 2).reshape(b * t, hkv, dh).astype(pool.dtype)
        pf = pf.at[flat].set(src, mode="drop")
        return pf.reshape(pool.shape)

    return put(cache.k, k_new), put(cache.v, v_new)


def _decode_attention(q, k_cache, v_cache, cur_pos, cfg, *, window=None):
    """Windowed decode attention against a (possibly seq-sharded) KV cache.

    q: (B, Hq, t, Dh) with t >= 1 new tokens; request b's tokens sit at
    absolute positions cur_pos[b]..cur_pos[b]+t-1 (``cur_pos`` (B,) or
    scalar == pre-insert cache length per request; the cache already
    contains the window's K/V).  Softmax over the cache axis is written
    max/exp/sum-explicitly; if `kv_seq` is sharded, GSPMD lowers it to
    per-shard partials + a tiny psum (flash-decoding combine).
    Ring-buffer caches are handled positionally: post-insert, slot i of
    request b holds absolute position last_b - ((last_b - i) mod S) with
    last_b = cur_pos[b] + t - 1.  Queries mask causally *within* the
    window: query j attends only to slots whose absolute position is
    <= cur_pos[b]+j.
    """
    b, hq, t, hd = q.shape
    nkv = k_cache.shape[1]
    group = hq // nkv
    s = k_cache.shape[2]
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(b, nkv, group, t, hd)
    logits = jnp.einsum(
        "bhgtd,bhsd->bhgts", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    logits = _softcap(logits, cfg.attn_logit_softcap)

    slot = jnp.arange(s, dtype=jnp.int32)
    cur2 = _lengths_2d(cur_pos, b)                       # (B, 1)
    last = cur2 + t - 1                                  # (B, 1)
    abs_pos = last - jnp.mod(last - slot[None, :], s)    # (B, S): newest pos
    qpos = cur2 + jnp.arange(t, dtype=jnp.int32)[None]   # (B, t)
    valid = (abs_pos[:, None, :] >= 0) & (abs_pos[:, None, :] <= qpos[:, :, None])
    if window is not None:
        valid &= abs_pos[:, None, :] > (qpos[:, :, None] - window)
    valid = valid[:, None, None]                         # (B, 1, 1, t, s)
    logits = jnp.where(valid, logits, -1e30)

    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(valid, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgts,bhsd->bhgtd", p, v_cache.astype(jnp.float32))
    out = out / jnp.maximum(denom, 1e-30)
    return out.reshape(b, hq, t, hd).astype(q.dtype)
