"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The beyond-baseline §Perf variant (``moe_impl="a2a"``).  The baseline
gather-MoE routes globally: GSPMD must all-gather the token activations
across the mesh before the expert gather, and all-reduce the scatter-add —
O(tokens·d) all-gather bytes per layer.  Here, routing is *local* per data
shard and tokens travel to their experts by ONE all-to-all over the model
axis (and back) — point-to-point producer→consumer delivery, the paper's
elevator/eLDST discipline at ICI level (DeepSpeed-MoE style):

  per shard:  tokens (n_loc, d) --route--> (E, C_loc, d)
  all_to_all: (E, C_loc, d) -> (E_loc, tp·C_loc, d)     [model axis]
  expert FFN on local experts; reverse all_to_all; local weighted combine.

Collective bytes per layer per device drop from O(n_loc·d·tp) (gather) to
2·k·n_loc·cf·d (two a2a passes of the dispatched tokens).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.model.moe import _topk_routing


def apply_moe_a2a(
    params, x: jax.Array, cfg, *, axis_name: str = "model",
    capacity_factor: float | None = None,
):
    """Inside shard_map: x (b_loc, t, d) local tokens; experts sharded on
    ``axis_name``.  Router/expert weights arrive as their local shards."""
    tp = jax.lax.psum(1, axis_name)
    b, t, d = x.shape
    e = cfg.num_experts
    k = cfg.num_experts_per_tok
    e_loc = e // tp
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    n = b * t
    cap = max(8, int(n * k * cf / e))
    cap = -(-cap // 8) * 8

    xf = x.reshape(n, d)
    # Router weights are replicated across the model axis inside shard_map.
    logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    weights, experts = _topk_routing(logits, k)

    flat_expert = experts.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_weight = weights.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_weight = flat_weight[order]
    counts = jnp.bincount(flat_expert, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_expert]
    keep = pos < cap
    slot = jnp.where(keep, sorted_expert * cap + pos, e * cap)

    disp_tok = jnp.zeros(e * cap + 1, jnp.int32).at[slot].set(sorted_token + 1)
    disp_w = jnp.zeros(e * cap + 1, jnp.float32).at[slot].set(sorted_weight)
    disp_tok = disp_tok[: e * cap].reshape(e, cap)
    disp_w = disp_w[: e * cap].reshape(e, cap)

    valid = disp_tok > 0
    xe = jnp.take(xf, jnp.maximum(disp_tok - 1, 0).reshape(-1), axis=0)
    xe = xe.reshape(e, cap, d)
    xe = jnp.where(valid[..., None], xe, 0.0)

    # ---- point-to-point dispatch: tokens travel to their expert's shard ----
    # local (E, C, d): expert-major rows; tiled a2a sends the rows of expert
    # group j to device j and concatenates received sender blocks along the
    # capacity axis -> (e_loc, tp*C, d), slot = sender*C + c.
    xe = jax.lax.all_to_all(
        xe, axis_name, split_axis=0, concat_axis=1, tiled=True
    )

    if cfg.mlp_type == "geglu":
        act = lambda g: jax.nn.gelu(g, approximate=True)
    else:
        act = jax.nn.silu
    # Local expert weights: (e_loc, d, f) shards of the stacked tensors.
    h = act(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, params["w_up"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    # ---- return trip: inverse tiled exchange, back to expert-major layout --
    ye = jax.lax.all_to_all(ye, axis_name, split_axis=1, concat_axis=0,
                            tiled=True)  # (e, cap, d)

    ye = ye * disp_w[..., None]
    ye = jnp.where(valid[..., None], ye, 0.0)
    out = jnp.zeros((n + 1, d), ye.dtype).at[disp_tok.reshape(-1)].add(
        ye.reshape(-1, d)
    )[1:]
    return out.reshape(b, t, d).astype(x.dtype)


def apply_moe_sharded(params, x: jax.Array, cfg):
    """pjit-callable wrapper: runs :func:`apply_moe_a2a` under shard_map
    using the active sharding context.  Falls back to the gather path when
    no mesh/model axis is active (CPU tests) or batch doesn't divide."""
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro.model import moe as moe_mod
    from repro.model.sharding import _CTX

    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return moe_mod.apply_moe(params, x, cfg)
    data = rules.get("batch")
    data_size = 1
    if data:
        axes = data if isinstance(data, tuple) else (data,)
        for a in axes:
            data_size *= mesh.shape[a]
    if data_size == 0 or x.shape[0] % max(data_size, 1):
        return moe_mod.apply_moe(params, x, cfg)
    tp = mesh.shape["model"]
    if cfg.num_experts % tp or x.shape[1] % tp:
        return moe_mod.apply_moe(params, x, cfg)

    # Tokens sequence-sharded over the model axis (SP): every device routes
    # a distinct 1/tp of the tokens — no replicated routing work.
    x_spec = P(data, "model", None)
    param_specs = {
        "router": P(None, None),             # replicated (tiny)
        "w_gate": P("model", None, None),    # local experts
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    # Replication checking off (the a2a writes are deliberately uneven);
    # shard_map_norep owns the check_rep/check_vma jax-version spelling.
    from repro.kernels.common import shard_map_norep

    f = shard_map_norep(
        partial(apply_moe_a2a, cfg=cfg, axis_name="model"),
        mesh=mesh,
        in_specs=(param_specs, x_spec),
        out_specs=x_spec,
    )
    return f(params, x)
