"""Top-level model: init / abstract / spec params, forward, prefill, decode.

One code path (the ``mk`` protocol) produces real params, ShapeDtypeStructs
(dry-run) and PartitionSpecs (sharding), so they can never drift.

Input conventions per family:
  * text archs: ``tokens (B, S) int32``.
  * vlm (qwen2-vl): ``tokens (B, S)`` + ``frontend_embeds (B, S_f, D)``
    (precomputed patch embeddings, stub frontend) occupying the first S_f
    positions, + M-RoPE ``positions (3, B, S)``.
  * audio enc-dec (seamless): encoder consumes ``frontend_embeds (B, S, D)``
    (precomputed frame embeddings); decoder consumes ``tokens (B, S_dec)``.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.model import transformer as tf
from repro.model.attention import KVCache, PagedKVCache
from repro.model.layers import (
    embed_tokens,
    init_embeddings,
    init_rmsnorm,
    logits_projection,
    rms_norm,
)
from repro.model.recurrent import RWKV_HEAD_DIM, RecState
from repro.model.sharding import abstract_mk, constrain, init_mk, spec_mk, to_pspec


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# Parameter construction (three interpretations of one code path)
# --------------------------------------------------------------------------

def _build_params(cfg, mk_factory):
    p: dict[str, Any] = {
        "tok": init_embeddings(mk_factory(-1), cfg),
        "final_norm": init_rmsnorm(mk_factory(-2), cfg.d_model, "final_norm"),
        "decoder": tf.init_stack(
            mk_factory, cfg, cross=cfg.is_enc_dec, name="dec"
        ),
    }
    if cfg.is_enc_dec:
        import dataclasses

        enc_cfg = dataclasses.replace(cfg, pattern=("attn",), num_experts=0)
        enc_factory = lambda i: mk_factory(10_000 + i)
        p["encoder"] = tf.init_stack(
            enc_factory, enc_cfg, num_layers=cfg.encoder_layers, name="enc"
        )
        p["enc_final_norm"] = init_rmsnorm(
            mk_factory(-3), cfg.d_model, "enc_final_norm"
        )
    return p


def init_params(cfg, key: jax.Array):
    """Real parameters (smoke tests, examples, small-scale training)."""
    def factory(i):
        return init_mk(jax.random.fold_in(key, i % (2**30)), _dtype(cfg))
    return _build_params(cfg, factory)


def abstract_params(cfg):
    """ShapeDtypeStruct tree — dry-run lowering, no allocation."""
    mk = abstract_mk(_dtype(cfg))
    return _build_params(cfg, lambda i: mk)


def param_pspecs(cfg, rules: dict):
    """PartitionSpec tree aligned with the param tree."""
    mk = spec_mk(rules)
    return _build_params(cfg, lambda i: mk)


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------

def forward(
    params,
    cfg,
    tokens: jax.Array | None = None,
    *,
    positions: jax.Array | None = None,
    frontend_embeds: jax.Array | None = None,
    enc_tokens_embeds: jax.Array | None = None,
) -> jax.Array:
    """Returns logits (B, S, V) (decoder logits for enc-dec)."""
    enc_out = None
    if cfg.is_enc_dec:
        assert enc_tokens_embeds is not None, "enc-dec needs encoder inputs"
        import dataclasses

        enc_cfg = dataclasses.replace(cfg, pattern=("attn",), num_experts=0)
        ex = enc_tokens_embeds.astype(_dtype(cfg))
        ex, _ = tf.apply_stack(
            params["encoder"], ex, enc_cfg, causal=False,
            num_layers=cfg.encoder_layers,
        )
        enc_out = rms_norm(params["enc_final_norm"], ex, cfg.norm_eps)

    x = embed_tokens(params["tok"], tokens, cfg)
    if frontend_embeds is not None:
        s_f = frontend_embeds.shape[1]
        x = jnp.concatenate(
            [frontend_embeds.astype(x.dtype), x[:, s_f:]], axis=1
        )
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    x, _ = tf.apply_stack(
        params["decoder"], x, cfg, positions=positions, causal=True,
        enc_out=enc_out,
    )
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return logits_projection(params["tok"], x, cfg)


# --------------------------------------------------------------------------
# Decode state (KV caches / recurrent states), concrete + abstract
# --------------------------------------------------------------------------

class PageSpec(NamedTuple):
    """Geometry of a paged decode state (see
    :class:`repro.model.attention.PagedKVCache`).

    ``page_size``: tokens per physical page — a multiple of the 32-token
    admit bucket, so page boundaries and admission buckets line up.
    ``private_pages``: allocatable (non-shared) physical pages per KV
    node pool; ``None`` = dense-equivalent capacity (``batch`` × logical
    pages per slot), which can never starve.  Each node's pool is capped
    at that dense-equivalent count regardless — a local ring can't use
    more.  ``shared_pages``: extra read-only pages reserved (per
    full-view node) for prefilled shared prefixes.
    """

    page_size: int = 32
    private_pages: int | None = None
    shared_pages: int = 0


def _layer_state_shape(cfg, kind: str, batch: int, max_len: int,
                       insert_window: int = 1, paged: PageSpec | None = None):
    dt = _dtype(cfg)
    if kind in tf.ATTN_KINDS:
        window = cfg.attn_window if kind == "local" else None
        # Local layers only retain a window-sized cache (ring-buffer slots).
        # Multi-token decode windows need insert_window - 1 slack slots so
        # a window inserted at once never overwrites positions its earlier
        # queries still attend to; capped at max_len the ring can't wrap at
        # all, so either way windowed decode stays exact.
        s = min(max_len, window + insert_window - 1) if window else max_len
        if paged is not None:
            ps = int(paged.page_size)
            nl = -(-s // ps)                       # logical pages per slot
            cap = batch * nl                       # dense-equivalent pool
            private = cap if paged.private_pages is None else min(
                int(paged.private_pages), cap)
            # Shared prefix pages only exist where they are immutable:
            # a view spanning every position (s == max_len) never wraps,
            # so pages below a slot's start length are never written.
            shared = int(paged.shared_pages) if s == max_len else 0
            pool = (1 + shared + private, ps, cfg.num_kv_heads, cfg.head_dim)
            return PagedKVCache(
                k=jax.ShapeDtypeStruct(pool, dt),
                v=jax.ShapeDtypeStruct(pool, dt),
                page_table=jax.ShapeDtypeStruct((batch, nl), jnp.int32),
                length=jax.ShapeDtypeStruct((batch,), jnp.int32),
                s_view=s, page_size=ps,
            )
        kv_shape = (batch, cfg.num_kv_heads, s, cfg.head_dim)
        return KVCache(
            k=jax.ShapeDtypeStruct(kv_shape, dt),
            v=jax.ShapeDtypeStruct(kv_shape, dt),
            # Per-request fill counts: continuous batching advances each
            # slot at its own pace (lockstep is the all-equal special case).
            length=jax.ShapeDtypeStruct((batch,), jnp.int32),
        )
    if kind == "rec":
        return RecState(
            h=jax.ShapeDtypeStruct((batch, cfg.d_rnn), jnp.float32),
            conv=jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, cfg.d_rnn), dt),
        )
    if kind == "rwkv":
        h = cfg.d_model // RWKV_HEAD_DIM
        return RecState(
            h=jax.ShapeDtypeStruct((batch, h, RWKV_HEAD_DIM, RWKV_HEAD_DIM), jnp.float32),
            conv=jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dt),
        )
    raise ValueError(kind)


def abstract_decode_state(cfg, batch: int, max_len: int,
                          insert_window: int = 1,
                          paged: PageSpec | None = None):
    pattern, n_periods, remainder = tf.plan_groups(cfg)

    def stack(sds_tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_periods,) + s.shape, s.dtype),
            sds_tree,
        )

    scanned = (
        [stack(_layer_state_shape(cfg, k, batch, max_len, insert_window,
                                  paged))
         for k in pattern]
        if n_periods > 0
        else None
    )
    rem = [_layer_state_shape(cfg, k, batch, max_len, insert_window, paged)
           for k in remainder]
    return {"scanned": scanned, "remainder": rem}


def init_decode_state(cfg, batch: int, max_len: int, insert_window: int = 1,
                      paged: PageSpec | None = None):
    """Zeroed decode state.  ``insert_window`` is the widest token window
    any single ``decode_step`` call will insert (1 = classic per-token
    decode) — it sizes the local-attention ring slack; recurrent states
    are O(1) in it.  The WKV state stays (B, H, Dh, Dh) float32 end to
    end: serve loops carry it without per-step reshapes or casts.

    ``paged`` swaps every KV node for a :class:`PagedKVCache` pool of
    that geometry; page tables initialize to -1 (nothing mapped)."""
    state = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        abstract_decode_state(cfg, batch, max_len, insert_window, paged),
    )
    if paged is None:
        return state

    def unmap(node):
        if isinstance(node, PagedKVCache):
            return PagedKVCache(
                node.k, node.v, jnp.full_like(node.page_table, -1),
                node.length, node.s_view, node.page_size,
            )
        return node

    return jax.tree.map(
        unmap, state,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache, RecState)),
    )


def decode_state_pspecs(cfg, batch: int, max_len: int, rules: dict,
                        insert_window: int = 1,
                        paged: PageSpec | None = None):
    """PartitionSpecs for the decode state.

    KV caches shard (batch, ·, kv_seq, ·); recurrent states shard
    (batch, rnn-ish) — built by walking the typed abstract tree, so stacked
    (leading ``layers``) axes are detected from rank deltas.  Paged pools
    stay replicated (any slot's table may reference any page); their
    tables/lengths shard along batch.
    """
    abstract = abstract_decode_state(cfg, batch, max_len, insert_window,
                                     paged)

    def node_spec(node):
        if isinstance(node, KVCache):
            extra = len(node.k.shape) - 4  # 0 = unstacked, 1 = (L, B, H, S, D)
            prefix = ("layers",) * extra
            kv = to_pspec(prefix + ("batch", None, "kv_seq", None), rules)
            ln = to_pspec(prefix + ("batch",), rules)
            return KVCache(k=kv, v=kv, length=ln)
        if isinstance(node, PagedKVCache):
            extra = len(node.k.shape) - 4
            prefix = ("layers",) * extra
            pool = to_pspec(prefix + (None, None, None, None), rules)
            tbl = to_pspec(prefix + ("batch", None), rules)
            ln = to_pspec(prefix + ("batch",), rules)
            return PagedKVCache(k=pool, v=pool, page_table=tbl, length=ln,
                                s_view=node.s_view, page_size=node.page_size)
        if isinstance(node, RecState):
            extra = len(node.conv.shape) - 3
            prefix = ("layers",) * extra
            h_axes = prefix + ("batch",) + (None,) * (len(node.h.shape) - extra - 1)
            c_axes = prefix + ("batch", None, "rnn")
            return RecState(h=to_pspec(h_axes, rules), conv=to_pspec(c_axes, rules))
        raise TypeError(type(node))

    return jax.tree.map(
        node_spec, abstract,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache, RecState)),
    )


def decode_state_finite(state) -> jax.Array:
    """(B,) bool — per-slot finiteness of the recurrent decode state.

    Reduces ``isfinite`` over every :class:`RecState` leaf (WKV S, RG-LRU
    h, conv tails) per batch row — the fault-detection flag a serving
    window folds into its jitted scan: a slot whose recurrent state went
    non-finite is quarantined *inside* the jit (no extra dispatch, no
    host sync per token).  KV caches are deliberately not scanned — a
    NaN KV row poisons that slot's logits the same step it is attended,
    so the caller's logits-finiteness check covers attention state at
    O(V) instead of O(max_len·H·Dh) per step.

    Attention-only states (no recurrent layers) return all-True: slot
    health is then carried entirely by the logits check.
    """
    flags = []
    batch = None

    def visit(node):
        nonlocal batch
        if isinstance(node, (KVCache, PagedKVCache)):
            if batch is None:
                batch = node.length.shape[-1]
            return
        if not isinstance(node, RecState):
            raise TypeError(type(node))
        # Leaves are (B, ...) or stacked (L, B, ...): the conv tail's rank
        # relative to its unstacked 3 gives the stacked prefix length,
        # hence the batch axis, for both leaves.
        stacked = node.conv.ndim - 3
        if batch is None:
            batch = node.conv.shape[stacked]
        for leaf in (node.h, node.conv):
            axes = tuple(a for a in range(leaf.ndim) if a != stacked)
            flags.append(jnp.all(jnp.isfinite(leaf), axis=axes))

    jax.tree.map(visit, state,
                 is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache,
                                                  RecState)))
    if not flags:
        return jnp.ones((batch,), bool)
    return functools.reduce(jnp.logical_and, flags)


def _checksum_words(leaf, batch_axis: int) -> jax.Array:
    """Per-slot uint32 wraparound sum of ``leaf``'s raw bit patterns.

    Bitcast (never value-convert) to unsigned words first: the sum is then
    an exact, order-independent function of the stored bits — modular
    integer addition is associative/commutative, so XLA may reduce in any
    order without changing the result, which a float-valued checksum could
    not guarantee.  A single flipped bit changes one word by a power of
    two, so the slot sum always moves.
    """
    nbytes = jnp.dtype(leaf.dtype).itemsize
    if nbytes >= 4:
        words = jax.lax.bitcast_convert_type(leaf, jnp.uint32)
    else:
        uint = jnp.uint8 if nbytes == 1 else jnp.uint16
        words = jax.lax.bitcast_convert_type(leaf, uint).astype(jnp.uint32)
    axes = tuple(a for a in range(words.ndim) if a != batch_axis)
    return jnp.sum(words, axis=axes, dtype=jnp.uint32)


def decode_state_checksum(state) -> jax.Array:
    """(B,) uint32 — per-slot wraparound checksum of the decode state.

    The silent-corruption complement to :func:`decode_state_finite`: a
    bit flip that leaves a value finite-but-wrong never trips the
    ``isfinite`` quarantine, but it always moves this sum.  Covers every
    per-slot leaf: recurrent states (WKV S / RG-LRU h, conv tails), dense
    KV caches (contents + lengths), and paged KV nodes (each slot's
    *mapped* pool pages gathered through its page table, plus the table
    and length words themselves — so a corrupted mapping is caught even
    when the pool bytes are intact).

    Cost: one O(state bytes) integer reduction per call — a serving
    window computes it twice per K-token dispatch (entry + exit), which
    is small against K forward passes.  Shared prefix pages are included
    in every sharing slot's sum; that keeps the sum a pure function of
    (state, slot) and stays deterministic.
    """
    sums = []
    batch = None

    def paged_sum(pool, tbl):
        # pool (P, ps, Hkv, Dh), tbl (B, nl) -> (B,) uint32 over mapped
        # pages only (unmapped entries are -1; their gather is masked out).
        pages = jnp.take(pool, jnp.clip(tbl, 0), axis=0)
        nbytes = jnp.dtype(pages.dtype).itemsize
        if nbytes >= 4:
            words = jax.lax.bitcast_convert_type(pages, jnp.uint32)
        else:
            uint = jnp.uint8 if nbytes == 1 else jnp.uint16
            words = jax.lax.bitcast_convert_type(pages, uint).astype(
                jnp.uint32)
        per_page = jnp.sum(
            words, axis=tuple(range(2, words.ndim)), dtype=jnp.uint32)
        return jnp.sum(jnp.where(tbl >= 0, per_page, 0), axis=1,
                       dtype=jnp.uint32)

    def visit(node):
        nonlocal batch
        if isinstance(node, KVCache):
            stacked = node.k.ndim - 4
            if batch is None:
                batch = node.length.shape[-1]
            for leaf in (node.k, node.v):
                sums.append(_checksum_words(leaf, stacked))
            sums.append(_checksum_words(node.length, node.length.ndim - 1))
            return
        if isinstance(node, PagedKVCache):
            stacked = node.k.ndim - 4
            if batch is None:
                batch = node.length.shape[-1]
            fn = paged_sum
            for _ in range(stacked):
                fn = jax.vmap(fn)
            for pool in (node.k, node.v):
                s = fn(pool, node.page_table)
                if stacked:
                    s = jnp.sum(s, axis=tuple(range(stacked)),
                                dtype=jnp.uint32)
                sums.append(s)
            sums.append(_checksum_words(node.page_table,
                                        node.page_table.ndim - 2))
            sums.append(_checksum_words(node.length, node.length.ndim - 1))
            return
        if not isinstance(node, RecState):
            raise TypeError(type(node))
        stacked = node.conv.ndim - 3
        if batch is None:
            batch = node.conv.shape[stacked]
        for leaf in (node.h, node.conv):
            sums.append(_checksum_words(leaf, stacked))

    jax.tree.map(visit, state,
                 is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache,
                                                  RecState)))
    if not sums:
        return jnp.zeros((batch,), jnp.uint32)
    return functools.reduce(jnp.add, sums)


# --------------------------------------------------------------------------
# Decode step
# --------------------------------------------------------------------------

def _check_ring_slack(cfg, state, t: int, max_len: int | None):
    """Trace-time guard for the local-attention ring contract.

    A window of ``t`` tokens inserted into a ring of ``S`` slots is exact
    iff ``S >= attn_window + t - 1`` (the slack ``init_decode_state``
    sizes via ``insert_window``) — or the ring can never wrap at all,
    which the builder guarantees by capping ``S`` at ``max_len``.  Before
    this check, violating the contract silently evicted slots the
    window's earlier queries still attend to (corrupt logits, no error).
    ``max_len=None`` (caller didn't vouch for the cap) treats any
    slack-deficient ring as an error.

    The rule itself lives in :mod:`repro.analysis.ringslack` (one source
    of truth for the trace-time guard and the static audit); this wrapper
    only turns violations into the trace-time ``ValueError``.
    """
    from repro.analysis.ringslack import ring_slack_violations

    msgs = ring_slack_violations(cfg, state, t, max_len)
    if msgs:
        raise ValueError(msgs[0])


def decode_step(params, cfg, state, tokens: jax.Array, lengths: jax.Array,
                *, enc_out: jax.Array | None = None,
                last_only: bool = False,
                token_mask: jax.Array | None = None,
                max_len: int | None = None):
    """One serve step over a window of tokens (B, K), K >= 1, given caches
    filled to ``lengths`` — scalar (lockstep: every request at the same
    position) or per-request ``(B,)``: request b's K tokens occupy
    positions ``lengths[b]..lengths[b]+K-1`` (causal within the window).
    K == 1 is classic per-token decode; K > 1 amortizes dispatch and, on
    the WKV path, the state's HBM round-trip (kernels/wkv/decode).

    ``token_mask`` (B, K) bool marks which window tokens are *real*.
    Masked tokens contribute nothing to any state — KV-cache slots are not
    written, per-request lengths don't advance, and recurrent states carry
    through unchanged (``jnp.where``-frozen) — so an all-False row leaves
    a finished/empty slot's state bit-identical, and a prefix mask
    (``arange(K) < prompt_len``) prefills a ragged prompt without pad
    pollution.  The mask must be a *prefix* per row (valid tokens, then
    padding): recurrent final states are read at the last valid position.

    The state must have been built with
    ``init_decode_state(insert_window >= K)``; a slack-deficient
    local-attention ring now fails at trace time (see
    :func:`_check_ring_slack`) instead of silently corrupting output —
    pass ``max_len`` (the position cap the state was built with) to allow
    rings legitimately capped at ``max_len``.

    ``last_only=True`` projects logits for the window's final *valid*
    position only (per request, when ``token_mask`` is given) — a greedy
    serve loop needs just that, and skipping the other K-1 (or P-1, at
    prefill) vocab projections keeps the logits buffer (B, 1, V) instead
    of (B, K, V).

    Returns (logits (B, K, V) — (B, 1, V) with ``last_only`` — new_state).
    """
    b, t = tokens.shape
    _check_ring_slack(cfg, state, t, max_len)
    lengths = jnp.reshape(jnp.asarray(lengths, jnp.int32), (-1, 1))
    positions = jnp.broadcast_to(
        lengths + jnp.arange(t, dtype=jnp.int32)[None, :], (b, t)
    ).astype(jnp.int32)
    x = embed_tokens(params["tok"], tokens, cfg)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x, new_state = tf.apply_stack(
        params["decoder"], x, cfg, positions=positions, causal=True,
        states=state, enc_out=enc_out, token_mask=token_mask,
    )
    if last_only:
        if token_mask is None:
            x = x[:, -1:]
        else:
            # Per-request last valid position (clamped: an all-False row
            # yields garbage logits the caller must ignore).
            idx = jnp.clip(
                jnp.sum(token_mask, axis=1, dtype=jnp.int32) - 1, 0, t - 1
            )
            x = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return logits_projection(params["tok"], x, cfg), new_state
