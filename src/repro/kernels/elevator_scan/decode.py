"""Pallas TPU decode micro-kernel: persistent RG-LRU state across tokens.

The RG-LRU analogue of :mod:`repro.kernels.wkv.decode` (ROADMAP item (d)).
Stateful decode used to force the unfused jnp path
(``elevator_scan(..., use_kernel=False if t == 1 else None)`` in
``model/recurrent.py``), so the (B, d_rnn) hidden state round-tripped HBM
on every generated token even on TPU.  Here the window of K decode steps
is swept in ONE kernel invocation on a ``(batch, d_blocks, K)`` grid with
``h`` held in a VMEM scratch — the same Δ=1 elevator carry the chunked
kernel uses over chunk space, now over *decode steps*: one HBM read of
``h0`` and one write of the exit state per K tokens instead of per token.
K is arbitrary (no chunk structure, no divisibility constraint); K == 1
is the classic single-token step.

Differentiable through :func:`elevator_decode_diff` (recompute-over-stage:
the backward is the closed-form adjoint of the linear recurrence — a
reverse linear scan — with the forward states recomputed, so the only
residuals are the primal inputs).  Dispatch:
``ops.elevator_scan(decode=True)`` sends windows up to
:data:`ELEVATOR_DECODE_WINDOW_MAX` tokens here; longer stateful sweeps
(cache prefill) fall through to the chunked paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pick_d_block, reset_carry
from repro.kernels.elevator_scan.ref import elevator_scan_ref_f32

# Stateful (decode) dispatches at or below this many tokens take the
# window kernel; above it the chunked elevator kernel wins (log-depth
# intra-chunk doubling amortizes).  Matches the WKV decode threshold.
ELEVATOR_DECODE_WINDOW_MAX = 64

__all__ = [
    "ELEVATOR_DECODE_WINDOW_MAX",
    "elevator_decode_window_pallas",
    "elevator_decode_diff",
]


def elevator_decode_window_kernel(a_ref, x_ref, h0_ref, out_ref, h_ref):
    """K-step window, grid (batch, d_blocks, K): h rides the VMEM scratch.

    Grid step ``i`` withdraws the state deposited by step ``i-1`` (step 0
    withdraws the boundary constant ``h0``) — the elevator hand-off of
    the chunked kernel with decode steps as the chunk axis.
    """
    reset_carry(h_ref, h0_ref[...], seq_axis=2)
    a = a_ref[0].astype(jnp.float32)                    # (1, d_block)
    x = x_ref[0].astype(jnp.float32)
    h = a * h_ref[...] + x
    out_ref[0] = h.astype(out_ref.dtype)
    h_ref[...] = h                                      # hand-off: TID -> TID+1


@functools.partial(jax.jit, static_argnames=("interpret",))
def elevator_decode_window_pallas(
    a: jax.Array,
    x: jax.Array,
    h0: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """K-token decode window of h[t] = a[t]*h[t-1] + x[t].

    a/x: (B, K, D), any K >= 1; h0: (B, D).  Returns h (B, K, D) in
    ``x.dtype`` — bit-identical to K single steps chained, with one HBM
    round-trip of the state instead of K.
    """
    b, t, d = x.shape
    if h0.shape != (b, d):
        raise ValueError(f"h0 shape {h0.shape} != {(b, d)}")
    d_block = pick_d_block(d)
    seq_spec = pl.BlockSpec((1, 1, d_block), lambda bi, di, ti: (bi, ti, di))
    return pl.pallas_call(
        elevator_decode_window_kernel,
        grid=(b, d // d_block, t),
        in_specs=[
            seq_spec, seq_spec,
            pl.BlockSpec((1, d_block), lambda bi, di, ti: (bi, di)),
        ],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, d_block), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)


# --------------------------------------------------------------------------
# Differentiable wrapper (ops.elevator_scan decode dispatch)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def elevator_decode_diff(interpret, use_pallas, a, x, h0):
    """Differentiable decode-window elevator scan.  Returns h (B, K, D)
    in ``x.dtype``.

    Forward: the window kernel (``use_pallas=True``) or the sequential
    jnp scan — for short decode windows the sequential form IS the
    cheapest jnp rendering.  Backward: the closed-form adjoint of the
    linear recurrence (g[t] = dh[t] + a[t+1]*g[t+1], swept in reverse),
    recompute-over-stage — only the primals are saved.
    """
    if use_pallas:
        return elevator_decode_window_pallas(a, x, h0, interpret=interpret)
    return elevator_scan_ref_f32(a, x, h0).astype(x.dtype)


def _elevator_decode_fwd(interpret, use_pallas, a, x, h0):
    return elevator_decode_diff(interpret, use_pallas, a, x, h0), (a, x, h0)


def _elevator_decode_bwd(interpret, use_pallas, res, dh):
    a, x, h0 = res
    a32 = a.astype(jnp.float32)
    dh32 = dh.astype(jnp.float32)
    h = elevator_scan_ref_f32(a, x, h0)                  # recompute
    h_prev = jnp.concatenate(
        [h0.astype(jnp.float32)[:, None], h[:, :-1]], axis=1
    )
    # g[t] = dh[t] + a[t+1] g[t+1]: the same recurrence run on reversed
    # time with the decay shifted one step left (identity at the end).
    a_next = jnp.concatenate([a32[:, 1:], jnp.ones_like(a32[:, :1])], axis=1)
    g = jnp.flip(
        elevator_scan_ref_f32(jnp.flip(a_next, 1), jnp.flip(dh32, 1),
                              jnp.zeros_like(h0, dtype=jnp.float32)), 1
    )
    da = g * h_prev
    dx = g
    dh0 = a32[:, 0] * g[:, 0]
    return da.astype(a.dtype), dx.astype(x.dtype), dh0.astype(h0.dtype)


elevator_decode_diff.defvjp(_elevator_decode_fwd, _elevator_decode_bwd)
