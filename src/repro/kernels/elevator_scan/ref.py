"""Pure-jnp oracle for the elevator scan kernel.

h[b, t, d] = a[b, t, d] * h[b, t-1, d] + x[b, t, d],   h[b, -1, d] = h0[b, d]

This is the paper's prefix-sum dataflow (Fig. 6) generalized with a
data-dependent decay ``a`` — the recurrence underlying RG-LRU and the
diagonal part of RWKV6.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def elevator_scan_ref_f32(
    a: jax.Array, x: jax.Array, h0: jax.Array | None = None
) -> jax.Array:
    """O(T) sequential scan, float32 in and out — the one copy of the
    recurrence the casting wrappers (and the decode backward's
    recompute) all share."""
    b, t, d = x.shape
    a32 = a.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    init = (
        jnp.zeros((b, d), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )

    def step(h, inputs):
        at, xt = inputs
        h = at * h + xt
        return h, h

    _, hs = jax.lax.scan(step, init, (a32.swapaxes(0, 1), x32.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)


def elevator_scan_ref(
    a: jax.Array, x: jax.Array, h0: jax.Array | None = None
) -> jax.Array:
    """O(T) sequential reference (float32 accumulation, input dtype out)."""
    return elevator_scan_ref_f32(a, x, h0).astype(x.dtype)
