"""Public op: decayed sequence scan with automatic backend dispatch.

On TPU this runs the Pallas kernel; on CPU (this container) the kernel runs
in interpret mode for validation, while the jitted associative-scan reference
is used for speed-sensitive callers (models) via ``use_kernel=False``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import halving_chunk, interpret_default, on_tpu
from repro.kernels.elevator_scan.kernel import elevator_scan_pallas
from repro.kernels.elevator_scan.ref import elevator_scan_ref


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def elevator_scan(
    a: jax.Array,
    x: jax.Array,
    h0: jax.Array | None = None,
    *,
    chunk: int = 256,
    use_kernel: bool | None = None,
) -> jax.Array:
    """h[b,t,d] = a[b,t,d] * h[b,t-1,d] + x[b,t,d].

    ``use_kernel=None`` auto-selects: Pallas on TPU, log-depth
    associative scan elsewhere (identical math, validated against each other
    in tests/test_kernel_elevator_scan.py).
    """
    kernel = on_tpu() if use_kernel is None else use_kernel
    if kernel:
        c = halving_chunk(x.shape[1], chunk)
        return elevator_scan_pallas(a, x, h0, chunk=c, interpret=interpret_default())

    # Log-depth path (jnp): chunk-free associative scan in float32.
    a32, x32 = a.astype(jnp.float32), x.astype(jnp.float32)
    if h0 is not None:
        x32 = x32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))

    def compose(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(compose, (a32, x32), axis=1)
    return h.astype(x.dtype)
