"""Public op: decayed sequence scan with automatic backend dispatch.

On TPU this runs the Pallas kernel; on CPU (this container) the kernel runs
in interpret mode for validation, while speed-sensitive callers (models)
get a jnp path via ``use_kernel=False`` / auto off-TPU.

The jnp path is itself dispatched per backend (BENCH_kernels.json,
``elevator_scan_jnp``): the log-depth ``associative_scan`` only wins where
gather-heavy tree steps are cheap (accelerators); on CPU it was measured
*slower* than the plain sequential reference (8.5ms vs 7.0ms at
B=4,T=2048,D=256), and the two-level ``chunked_linear_scan`` schedule is
slower still in XLA-CPU (9.3–12.8ms across chunk sizes and layouts — the
intra-chunk tree pays the same strided-gather tax).  What wins on CPU is
the *linear* scan in chunk-unrolled form — ``lax.scan`` with a small
unroll, so XLA composes consecutive steps into straight-line vector code
(4.6ms, 1.9x over log-depth).  That is the CPU dispatch here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    KernelResources,
    halving_chunk,
    interpret_default,
    on_tpu,
    pick_d_block,
    register_kernel_resources,
    validate_divisible,
)
from repro.kernels.elevator_scan.decode import (
    ELEVATOR_DECODE_WINDOW_MAX,
    elevator_decode_diff,
)
from repro.kernels.elevator_scan.kernel import elevator_scan_pallas
from repro.kernels.elevator_scan.ref import elevator_scan_ref

# lax.scan unroll for the CPU linear path: 2 composed steps per iteration
# was the measured sweet spot (4.6ms vs 5.2–5.3ms at unroll 4/8).
_CPU_SCAN_UNROLL = 2


def elevator_scan_logdepth(a: jax.Array, x: jax.Array, h0=None) -> jax.Array:
    """Log-depth associative-scan form of the recurrence (float32 math).

    Exposed for benchmarks and non-CPU jnp dispatch; models go through
    :func:`elevator_scan`.
    """
    a32, x32 = a.astype(jnp.float32), x.astype(jnp.float32)
    if h0 is not None:
        x32 = x32.at[:, 0].add(a32[:, 0] * h0.astype(jnp.float32))

    def compose(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(compose, (a32, x32), axis=1)
    return h.astype(x.dtype)


def elevator_scan_linear(a: jax.Array, x: jax.Array, h0=None) -> jax.Array:
    """Linear (sequential) scan, chunk-unrolled for XLA-CPU (float32 math)."""
    b, t, d = x.shape
    a32, x32 = a.astype(jnp.float32), x.astype(jnp.float32)
    init = (
        jnp.zeros((b, d), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )

    def step(h, inputs):
        at, xt = inputs
        h = at * h + xt
        return h, h

    _, hs = jax.lax.scan(
        step, init, (a32.swapaxes(0, 1), x32.swapaxes(0, 1)),
        unroll=_CPU_SCAN_UNROLL,
    )
    return hs.swapaxes(0, 1).astype(x.dtype)


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def elevator_scan(
    a: jax.Array,
    x: jax.Array,
    h0: jax.Array | None = None,
    *,
    chunk: int = 256,
    use_kernel: bool | None = None,
    decode: bool | None = None,
) -> jax.Array:
    """h[b,t,d] = a[b,t,d] * h[b,t-1,d] + x[b,t,d].

    ``use_kernel=None`` auto-selects: Pallas on TPU, jnp elsewhere — and
    the jnp form is itself backend-dispatched (linear scan on CPU,
    log-depth associative scan otherwise; identical math, validated
    against each other in tests/test_kernel_elevator_scan.py).

    ``decode=True`` marks a *stateful serving* call (threaded from
    ``apply_rglru_block``): windows up to
    :data:`~repro.kernels.elevator_scan.decode.ELEVATOR_DECODE_WINDOW_MAX`
    tokens take the persistent-state decode kernel
    (:mod:`repro.kernels.elevator_scan.decode`) — h is read from HBM once
    and written once per window, intermediate states ride a VMEM carry —
    fixing the old dispatch that forced the jnp path at ``t == 1`` and
    round-tripped h through HBM every generated token.  Longer stateful
    sweeps (cache prefill) fall through to the chunked paths.
    ``decode=None`` infers ``t == 1``.
    """
    kernel = on_tpu() if use_kernel is None else use_kernel
    t = x.shape[1]
    if decode is None:
        decode = t == 1
    if decode and t <= ELEVATOR_DECODE_WINDOW_MAX:
        if kernel:
            return elevator_decode_diff(interpret_default(), True, a, x,
                                        _h0_or_zeros(a, h0))
        # jnp fallback: the sequential scan is the cheapest form for a
        # short stateful window (no chunk structure to exploit).
        return elevator_scan_linear(a, x, h0)
    if kernel:
        c = halving_chunk(t, chunk)
        return elevator_scan_pallas(a, x, h0, chunk=c, interpret=interpret_default())
    if jax.default_backend() == "cpu":
        return elevator_scan_linear(a, x, h0)
    return elevator_scan_logdepth(a, x, h0)


def _h0_or_zeros(a: jax.Array, h0: jax.Array | None) -> jax.Array:
    if h0 is not None:
        return h0
    b, _, d = a.shape
    return jnp.zeros((b, d), jnp.float32)


# --------------------------------------------------------------------------
# Static resource declarations (repro.analysis.resources)
# --------------------------------------------------------------------------

def _elevator_geometry(cfg):
    d = cfg.d_rnn
    d_block = pick_d_block(d)
    isz = jnp.dtype(cfg.dtype).itemsize
    return d, d_block, isz


@register_kernel_resources("elevator_scan.fwd")
def _elevator_fwd_resources(cfg, *, t: int = 4096, chunk: int = 256):
    """Chunked decayed scan (the RG-LRU recurrence)."""
    if "rec" not in tuple(cfg.pattern):
        return None
    d, d_block, isz = _elevator_geometry(cfg)
    c = halving_chunk(t, chunk)
    validate_divisible("T", t, c)
    seq = (1, c, d_block)
    return KernelResources(
        kernel="elevator_scan.fwd",
        location="src/repro/kernels/elevator_scan/kernel.py:elevator_scan_pallas",
        grid=(1, d // d_block, t // c),
        blocks=(
            ("a", seq, isz), ("x", seq, isz),
            ("h0", (1, d_block), 4), ("out", seq, isz),
        ),
        scratch=(("h", (1, d_block), 4),),
    )


@register_kernel_resources("elevator_scan.decode_window")
def _elevator_decode_resources(cfg, *, window: int = ELEVATOR_DECODE_WINDOW_MAX):
    """Persistent-state decode window: h rides VMEM across the window."""
    if "rec" not in tuple(cfg.pattern):
        return None
    d, d_block, isz = _elevator_geometry(cfg)
    seq = (1, 1, d_block)
    return KernelResources(
        kernel="elevator_scan.decode_window",
        location=("src/repro/kernels/elevator_scan/decode.py:"
                  "elevator_decode_window_pallas"),
        grid=(1, d // d_block, window),
        blocks=(
            ("a", seq, isz), ("x", seq, isz),
            ("h0", (1, d_block), 4), ("out", seq, isz),
        ),
        scratch=(("h", (1, d_block), 4),),
    )
