"""Pallas TPU kernel: chunked decayed scan with a VMEM elevator carry.

TPU-native adaptation of the paper's elevator-node chain (§4.1/§4.3):

* the sequence is tiled into chunks; each chunk is one grid step;
* the inter-chunk state hand-off lives in a VMEM scratch register — the
  *token buffer* of a Δ=1 elevator node over chunk space (never HBM);
* within a chunk, the recurrence is solved with log2(chunk) Hillis–Steele
  doubling steps on the VPU (8×128 vector registers), i.e. the in-fabric
  forwarding network;
* the initial state ``h0`` plays the elevator constant C at the boundary.

Grid layout: ``(batch, d_blocks, seq_chunks)`` — the sequence axis iterates
fastest, so the carry scratch is private to a (batch, d_block) tile and is
reset when ``seq_chunk == 0``.

Tiling: chunk is a multiple of 8 (sublanes), d_block a multiple of 128
(lanes), so shifts along the chunk axis are sublane rotates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    pick_d_block,
    reset_carry,
    shift_rows,
    validate_divisible,
)


def _chunk_scan_doubling(a: jax.Array, x: jax.Array, chunk: int):
    """Inclusive scan of h=a*h_prev+x along axis 0 via log-depth doubling.

    Returns (a_cum, h_local): cumulative decay products and local scan
    results (carry-free).  Both are float32.
    """
    h = x
    acc = a
    shift = 1
    while shift < chunk:
        # Compose with the segment ending `shift` rows above (elevator shift
        # with identity constant: a=1, b=0 injected at the boundary).
        a_shift = shift_rows(acc, shift, fill=1.0)
        h_shift = shift_rows(h, shift, fill=0.0)
        h = acc * h_shift + h
        acc = acc * a_shift
        shift *= 2
    return acc, h


def elevator_scan_kernel(
    a_ref, x_ref, h0_ref, out_ref, carry_ref, *, chunk: int, n_chunks: int
):
    # Boundary: chunk 0 receives the elevator constant h0.
    reset_carry(carry_ref, h0_ref[...], seq_axis=2)

    a = a_ref[0].astype(jnp.float32)   # (chunk, d_block)
    x = x_ref[0].astype(jnp.float32)

    a_cum, h_local = _chunk_scan_doubling(a, x, chunk)

    carry_in = carry_ref[...]           # (1, d_block) token buffer
    h = h_local + a_cum * carry_in      # inject entering carry
    out_ref[0, :, :] = h.astype(out_ref.dtype)

    # Hand the token to the next chunk (retag TID -> TID + 1).
    carry_ref[...] = h[-1:, :]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def elevator_scan_pallas(
    a: jax.Array,
    x: jax.Array,
    h0: jax.Array | None = None,
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """h[t] = a[t]*h[t-1] + x[t] scanned along axis 1 of (B, T, D) arrays."""
    b, t, d = x.shape
    validate_divisible("T", t, chunk)
    if chunk & (chunk - 1):
        raise ValueError(f"chunk must be a power of two, got {chunk}")
    d_block = pick_d_block(d)
    n_chunks = t // chunk
    if h0 is None:
        h0 = jnp.zeros((b, d), x.dtype)

    grid = (b, d // d_block, n_chunks)
    kernel = functools.partial(
        elevator_scan_kernel, chunk=chunk, n_chunks=n_chunks
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, chunk, d_block), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, d_block), lambda bi, di, si: (bi, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((b, t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, d_block), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
