"""Pure-jnp oracle for (windowed/causal/full) attention with GQA."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.core.lowering import scan_unroll


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D) with Hkv | Hq.  float32 math.

    ``window``: token t attends to keys in (t-window, t] (sliding window).
    """
    b, hq, t, d = q.shape
    hkv = k.shape[1]
    s = k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)

    logits = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale

    q_pos = jnp.arange(t)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        # Align the causal diagonal to the *end* of the key sequence
        # (supports decode where t < s and query i sits at position s-t+i).
        offset = s - t
        mask &= k_pos <= (q_pos + offset)
        if window is not None:
            mask &= k_pos > (q_pos + offset - window)
    elif window is not None:
        mask &= jnp.abs(k_pos - q_pos) < window
    logits = jnp.where(mask, logits, -jnp.inf)

    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_blockwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block: int = 512,
) -> jax.Array:
    """Flash-structured attention in pure jnp: O(T·block) live memory.

    Same math as :func:`attention_ref` (tests assert allclose) but the
    score matrix is never materialized — a ``lax.scan`` over query blocks
    with an inner scan over key blocks carries online-softmax accumulators
    (m, l, acc), mirroring the Pallas kernel's VMEM schedule.  This is the
    lowering path used by the dry-run on CPU so compiled memory reflects
    the TPU kernel's profile, not an O(T²) reference.
    """
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    scale_ = scale if scale is not None else 1.0 / (d ** 0.5)
    offset = s - t

    bq = min(block, t)
    bk = min(block, s)
    tp = -(-t // bq) * bq
    sp = -(-s // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tp - t), (0, 0))).astype(jnp.float32)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sp - s), (0, 0))).astype(jnp.float32)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sp - s), (0, 0))).astype(jnp.float32)
    n_q, n_k = tp // bq, sp // bk

    kb = kp.reshape(b, hkv, n_k, bk, d)
    vb = vp.reshape(b, hkv, n_k, bk, d)
    qb = qp.reshape(b, hq, n_q, bq, d)

    # Windowed-causal: each q block only visits the last `n_steps` kv blocks
    # ending at its diagonal (the transmission window) — FLOPs scale with
    # the window, not with T, matching the Pallas kernel's restricted grid.
    banded = causal and window is not None
    n_steps = min(n_k, (window + bq) // bk + 2) if banded else n_k

    def q_step(_, qi):
        q_blk = qb[:, :, qi] * scale_                       # (B,Hq,bq,D)
        q_pos = qi * bq + jnp.arange(bq)
        top = (qi * bq + bq - 1 + offset) // bk if banded else 0

        def k_step(carry, j):
            m, l, acc = carry
            kj_raw = top - (n_steps - 1 - j) if banded else j
            kj = jnp.clip(kj_raw, 0, n_k - 1)
            k_blk = kb[:, :, kj]                            # (B,Hkv,bk,D)
            v_blk = vb[:, :, kj]
            k_pos = kj * bk + jnp.arange(bk)
            sc = _grouped_scores(q_blk, k_blk, group)
            mask = (k_pos[None, :] < s) & (q_pos[:, None] < t)
            if banded:
                mask &= (kj_raw >= 0) & (kj_raw == kj)
            if causal:
                mask &= k_pos[None, :] <= (q_pos[:, None] + offset)
                if window is not None:
                    mask &= k_pos[None, :] > (q_pos[:, None] + offset - window)
            elif window is not None:
                mask &= jnp.abs(k_pos[None, :] - q_pos[:, None]) < window
            sc = jnp.where(mask, sc, -1e30)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            p = jnp.where(mask, p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = _grouped_pv(p, v_blk, group)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hq, bq), -1e30),
            jnp.zeros((b, hq, bq)),
            jnp.zeros((b, hq, bq, d)),
        )
        (m, l, acc), _ = jax.lax.scan(
            k_step, init, jnp.arange(n_steps), unroll=scan_unroll()
        )
        out_blk = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out_blk

    _, out = jax.lax.scan(q_step, None, jnp.arange(n_q), unroll=scan_unroll())
    out = jnp.moveaxis(out, 0, 2).reshape(b, hq, tp, d)[:, :, :t]
    return out.astype(q.dtype)


def _grouped_scores(q_blk, k_blk, group):
    b, hq, bq, d = q_blk.shape
    hkv = hq // group
    qg = q_blk.reshape(b, hkv, group, bq, d)
    sc = jnp.einsum("bhgqd,bhsd->bhgqs", qg, k_blk)
    return sc.reshape(b, hq, bq, -1)


def _grouped_pv(p, v_blk, group):
    b, hq, bq, bk = p.shape
    hkv = hq // group
    pg = p.reshape(b, hkv, group, bq, bk)
    pv = jnp.einsum("bhgqs,bhsd->bhgqd", pg, v_blk)
    return pv.reshape(b, hq, bq, -1)
