"""Public op: flash attention (full / causal / sliding-window, GQA)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.local_attention.kernel import flash_attention_pallas
from repro.kernels.local_attention.ref import attention_blockwise, attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Attention over (B, H, T, D) queries and (B, Hkv, S, D) keys/values.

    Dispatch: Pallas kernel on TPU; on CPU, the blockwise (flash-structured,
    O(T·block) memory) jnp path for long sequences — so dry-run lowering
    reflects the kernel's memory/flop profile — and the exact masked-einsum
    reference for short ones.  All three agree numerically (tests).
    """
    kernel = _on_tpu() if use_kernel is None else use_kernel
    if kernel:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=not _on_tpu(),
        )
    if q.shape[2] > 1024 or k.shape[2] > 1024:
        from repro.core.lowering import scan_unroll

        # Under unrolled-cost lowering, bigger blocks keep the HLO compact.
        block = 2048 if scan_unroll() is True else 512
        return attention_blockwise(
            q, k, v, causal=causal, window=window, scale=scale, block=block
        )
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
