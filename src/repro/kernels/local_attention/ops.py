"""Public op: flash attention (full / causal / sliding-window, GQA)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.common import KernelResources, register_kernel_resources
from repro.kernels.local_attention.kernel import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_pallas,
)
from repro.kernels.local_attention.ref import attention_blockwise, attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Attention over (B, H, T, D) queries and (B, Hkv, S, D) keys/values.

    Dispatch: Pallas kernel on TPU; on CPU, the blockwise (flash-structured,
    O(T·block) memory) jnp path for long sequences — so dry-run lowering
    reflects the kernel's memory/flop profile — and the exact masked-einsum
    reference for short ones.  All three agree numerically (tests).
    """
    kernel = _on_tpu() if use_kernel is None else use_kernel
    if kernel:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=not _on_tpu(),
        )
    if q.shape[2] > 1024 or k.shape[2] > 1024:
        from repro.core.lowering import scan_unroll

        # Under unrolled-cost lowering, bigger blocks keep the HLO compact.
        block = 2048 if scan_unroll() is True else 512
        return attention_blockwise(
            q, k, v, causal=causal, window=window, scale=scale, block=block
        )
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)


# --------------------------------------------------------------------------
# Static resource declarations (repro.analysis.resources)
# --------------------------------------------------------------------------

_ATTN_KINDS = ("attn", "local", "global")


@register_kernel_resources("local_attention.flash")
def _flash_attention_resources(cfg, *, t: int = 4096):
    """Flash attention tile footprint (sliding-window for local layers)."""
    import jax.numpy as jnp

    kinds = set(cfg.pattern) & set(_ATTN_KINDS)
    if not kinds:
        return None
    if cfg.num_heads % max(cfg.num_kv_heads, 1):
        raise ValueError(
            f"{cfg.name}: Hq={cfg.num_heads} not a multiple of "
            f"Hkv={cfg.num_kv_heads}"
        )
    d = cfg.head_dim
    bq, bk = DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K
    window = cfg.attn_window if "local" in kinds else None
    t_pad = -(-t // bq) * bq
    s_pad = -(-t // bk) * bk
    n_q_blocks = t_pad // bq
    n_kv_blocks = s_pad // bk
    if window is not None:
        n_kv_steps = min(n_kv_blocks, (window + bq) // bk + 2)
    else:
        n_kv_steps = n_kv_blocks
    isz = jnp.dtype(cfg.dtype).itemsize
    return KernelResources(
        kernel="local_attention.flash",
        location=("src/repro/kernels/local_attention/kernel.py:"
                  "flash_attention_pallas"),
        grid=(cfg.num_heads, n_q_blocks, n_kv_steps),
        blocks=(
            ("q", (1, bq, d), isz), ("k", (1, bk, d), isz),
            ("v", (1, bk, d), isz), ("out", (1, bq, d), isz),
        ),
        scratch=(
            ("m", (bq, 128), 4), ("l", (bq, 128), 4), ("acc", (bq, d), 4),
        ),
    )
