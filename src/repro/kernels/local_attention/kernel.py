"""Pallas TPU kernel: flash attention with sliding-window K/V forwarding.

The eLDST pattern (paper §4.2) at VMEM granularity: each K/V block is pulled
from HBM *once* per query block that needs it, held in VMEM, and consumed by
the MXU — the online-softmax accumulators (m, l, acc) are the token buffers
that let query tiles consume key tiles as a producer/consumer stream instead
of materializing the (T×T) score matrix in memory (the "scratchpad" of the
von-Neumann formulation).

For *local* attention (window W) the kernel visits only ceil(W/Bk)+1 key
blocks per query block — the transmission window of the elevator chain — so
compute and traffic are O(T·W) instead of O(T²).

Grid: (B·H, n_q_blocks, n_kv_steps), kv innermost.  GQA is handled by the
K/V index maps (kv head = q head // group).  Causal/full/windowed variants
share one body; masking is positional.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, out_ref,
    m_ref, l_ref, acc_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    n_kv_steps: int,
    t_real: int,
    s_real: int,
    t_pad: int,
    s_pad: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _reset():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)      # (block_q, d)
    k = k_ref[0].astype(jnp.float32)      # (block_k, d)
    v = v_ref[0].astype(jnp.float32)      # (block_k, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                              # (block_q, block_k)

    # Global positions.  The kv block index is recomputed from (qi, kj) with
    # the same formula as the index map (pre-clamp), then masked.
    kv_block = _kv_block_index(
        qi, kj, s_real - t_real, causal, window, block_q, block_k
    )
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kv_block * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    # Decode alignment: query i sits at absolute position s_real - t_real + i.
    offset = s_real - t_real
    mask = (k_pos < s_real) & (q_pos < t_real)
    if causal:
        mask &= k_pos <= (q_pos + offset)
        if window is not None:
            mask &= k_pos > (q_pos + offset - window)
    elif window is not None:
        mask &= jnp.abs(k_pos - q_pos) < window
    # Out-of-range (clamped) kv blocks contribute nothing.
    valid_block = (kv_block >= 0) & (kv_block * block_k < s_pad)
    mask &= valid_block

    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                    # (block_q, 128) replicated
    m_cur = jnp.max(s, axis=1, keepdims=True)          # (block_q, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))

    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])      # (block_q, 1)
    p = jnp.exp(s - m_new[:, :1])                      # (block_q, block_k)
    p = jnp.where(mask, p, 0.0)

    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kj == n_kv_steps - 1)
    def _finalize():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        out = acc_ref[...] / safe_l
        out_ref[0, :, :] = jnp.where(l > 0.0, out, 0.0).astype(out_ref.dtype)


def _kv_block_index(qi, kj, offset, causal, window, block_q, block_k):
    """KV block visited at step kj for query block qi (pre-clamp, may be <0).

    Windowed: steps sweep backwards from the diagonal block of the *last*
    query row in the block (absolute key position qi·Bq + Bq - 1 + offset).
    """
    if causal and window is not None:
        top = (qi * block_q + block_q - 1 + offset) // block_k
        return top - (pl.num_programs(2) - 1 - kj)
    # Full/causal-full: sweep all blocks from 0; causal masking trims.
    return kj


def _kv_index_map_factory(group, causal, window, block_q, block_k, n_kv_blocks, offset):
    def index_map(bh, qi, kj):
        kv_block = _kv_block_index(qi, kj, offset, causal, window, block_q, block_k)
        kv_block = jnp.clip(kv_block, 0, n_kv_blocks - 1)
        return (bh // group if group > 1 else bh, kv_block, 0)

    return index_map


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention.  q: (B, Hq, T, D); k/v: (B, Hkv, S, D), Hkv | Hq."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    # Pad T and S to block multiples.
    t_pad = -(-t // block_q) * block_q
    s_pad = -(-s // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    # Flatten (B, H) into one grid axis.
    qp = qp.reshape(b * hq, t_pad, d)
    kp = kp.reshape(b * hkv, s_pad, d)
    vp = vp.reshape(b * hkv, s_pad, d)

    n_q_blocks = t_pad // block_q
    n_kv_blocks = s_pad // block_k
    offset = s - t
    if causal and window is not None:
        n_kv_steps = min(n_kv_blocks, (window + block_q) // block_k + 2)
    else:
        n_kv_steps = n_kv_blocks

    kv_index_map = _kv_index_map_factory(
        group, causal, window, block_q, block_k, n_kv_blocks, offset
    )
    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_kv_steps=n_kv_steps,
        t_real=t,
        s_real=s,
        t_pad=t_pad,
        s_pad=s_pad,
    )

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q_blocks, n_kv_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index_map),
            pl.BlockSpec((1, block_k, d), kv_index_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, t_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)

    return out.reshape(b, hq, t_pad, d)[:, :, :t]
