"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper with backend dispatch) and ref.py (pure-jnp oracle used by tests).
"""

from repro.kernels.elevator_scan.ops import elevator_scan
from repro.kernels.local_attention.ops import flash_attention
from repro.kernels.matmul_fwd.ops import matmul_fwd
from repro.kernels.stencil2d.ops import stencil2d
from repro.kernels.token_shift.ops import token_shift
from repro.kernels.wkv.ops import wkv_fused

__all__ = [
    "elevator_scan",
    "flash_attention",
    "matmul_fwd",
    "stencil2d",
    "token_shift",
    "wkv_fused",
]
