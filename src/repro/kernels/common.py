"""Shared carry/grid machinery for the chunked sequence kernels.

Every sequence kernel in this package (``elevator_scan``, ``token_shift``,
``wkv``) runs the same schedule, which is the TPU rendering of the paper's
elevator-node chain (§4.1/§4.3):

* grid ``(batch, ..., seq_chunks)`` with the sequence axis iterating
  *fastest*, so a VMEM scratch is private to its leading-grid tile;
* the inter-chunk carry lives in that scratch — the elevator *token buffer*
  for a Δ=1 edge over chunk space — and is reset at chunk 0 to the boundary
  constant ``C`` (``h0`` or zeros);
* at the end of each grid step the carry is retagged TID → TID+1 by
  overwriting the scratch with this chunk's exit state.

Backward passes run the *reverse* sweep: the same grid, but the block
index maps walk the sequence axis back-to-front (:func:`reversed_chunk`),
so grid step 0 processes the **last** chunk and :func:`reset_carry` seeds
the adjoint carry there (the reverse-boundary constant, e.g. ``dS_out``).
The carry then rides the scratch toward chunk 0 — a Δ=-1 elevator edge.
:func:`rev_cumsum_rows` is the suffix-sum twin of :func:`cumsum_rows` for
the in-kernel adjoint of cumulative decays.

The helpers here centralize that contract plus the chunk/d_block validation
and interpret-mode plumbing the per-kernel ``ops.py`` wrappers share.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "on_tpu",
    "interpret_default",
    "shard_map_norep",
    "reset_carry",
    "reversed_chunk",
    "shift_rows",
    "cumsum_rows",
    "rev_cumsum_rows",
    "validate_divisible",
    "pick_d_block",
    "largest_divisor_chunk",
    "halving_chunk",
    "KernelResources",
    "KERNEL_RESOURCE_SPECS",
    "register_kernel_resources",
]


# --------------------------------------------------------------------------
# Backend dispatch (ops.py plumbing)
# --------------------------------------------------------------------------

def on_tpu() -> bool:
    """True when the Pallas kernels compile for real TPU hardware."""
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    """Interpret-mode default: real lowering on TPU, interpreter elsewhere
    (this container) so the kernels stay testable everywhere."""
    return not on_tpu()


def shard_map_norep(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax versions.

    jax 0.4.x spells the flag ``check_rep``; newer jax renamed it
    ``check_vma`` (and moved shard_map out of experimental — the
    experimental import path still works on both).  Used by the
    sequence-parallel kernel wrappers, whose replicated outputs come from
    masked psums the checker cannot always see through.
    """
    import inspect

    from jax.experimental.shard_map import shard_map

    check_kw = (
        "check_vma"
        if "check_vma" in inspect.signature(shard_map).parameters
        else "check_rep"
    )
    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{check_kw: False},
    )


# --------------------------------------------------------------------------
# In-kernel carry helpers
# --------------------------------------------------------------------------

def reset_carry(carry_ref, value=None, *, seq_axis: int = 2) -> None:
    """Reset the VMEM carry scratch at grid step 0 (the elevator boundary).

    ``value`` is the boundary constant ``C`` (e.g. ``h0``); ``None`` means
    zeros.  ``seq_axis`` names the grid axis that walks the sequence chunks
    — it must be the fastest-iterating axis so the scratch never leaks
    across (batch, head/d_block) tiles.

    For forward sweeps grid step 0 is chunk 0.  For reverse sweeps (block
    index maps built with :func:`reversed_chunk`) grid step 0 is the *last*
    chunk, so the same call seeds the adjoint carry at the reverse
    boundary — pass the incoming output-cotangent block as ``value``.
    """
    s = pl.program_id(seq_axis)

    @pl.when(s == 0)
    def _init():
        if value is None:
            carry_ref[...] = jnp.zeros_like(carry_ref)
        else:
            carry_ref[...] = value.astype(carry_ref.dtype)


def reversed_chunk(n_chunks: int):
    """Block-index component for a back-to-front sweep over the seq axis.

    ``reversed_chunk(n)(s) == n - 1 - s``: grid step ``s`` processes chunk
    ``n-1-s``, so the grid still iterates ascending (Pallas requirement)
    while the *blocks* walk last-to-first.  Combined with
    :func:`reset_carry` this puts the carry reset at the last chunk —
    the reverse elevator boundary.
    """
    return lambda s: n_chunks - 1 - s


def shift_rows(v: jax.Array, delta: int, fill: float) -> jax.Array:
    """Shift rows by ``delta`` (toward higher indices when positive, lower
    when negative), filling vacated rows with ``fill``.

    The in-VMEM rendering of an elevator shift: rows are sublanes, so this
    lowers to sublane rotates plus a select against the boundary constant.
    Negative ``delta`` is the reverse-sweep direction (adjoint flows).
    """
    rows = v.shape[0]
    rolled = jnp.roll(v, delta, axis=0)
    idx = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
    if delta >= 0:
        keep = idx >= delta
    else:
        keep = idx < rows + delta
    return jnp.where(keep, rolled, jnp.asarray(fill, v.dtype))


def cumsum_rows(v: jax.Array, rows: int) -> jax.Array:
    """Inclusive cumulative sum along axis 0 via log-depth doubling.

    Hillis–Steele on the VPU — ``ceil(log2(rows))`` shift+add steps, the
    same forwarding network :func:`shift_rows` models, with 0 as the
    identity boundary constant.  Used instead of ``jnp.cumsum`` inside
    kernels so the lowering stays a static chain of vector ops.
    """
    acc = v
    shift = 1
    while shift < rows:
        acc = acc + shift_rows(acc, shift, 0.0)
        shift *= 2
    return acc


def rev_cumsum_rows(v: jax.Array, rows: int) -> jax.Array:
    """Inclusive *suffix* sum along axis 0: out[s] = sum_{t >= s} v[t].

    The reverse-sweep twin of :func:`cumsum_rows` — the same Hillis–Steele
    doubling with negative shifts.  This is the in-kernel adjoint of a
    cumulative sum: if ``y = cumsum(x)`` then ``dx = rev_cumsum(dy)``,
    which is exactly what the backward kernels need for the cumulative
    log-decay chains.
    """
    acc = v
    shift = 1
    while shift < rows:
        acc = acc + shift_rows(acc, -shift, 0.0)
        shift *= 2
    return acc


# --------------------------------------------------------------------------
# Static resource declarations (repro.analysis.resources)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelResources:
    """Static VMEM footprint of one Pallas kernel configuration.

    Declared by each kernel's ``ops.py`` next to the ``pallas_call`` it
    mirrors (``register_kernel_resources``) and audited — pure shape
    math, nothing traced or executed — by
    :mod:`repro.analysis.resources` against the per-core VMEM budget.

    ``blocks``/``scratch`` are ``(name, block_shape, itemsize)`` tuples:
    exactly the BlockSpec block shapes (ins + outs) and scratch_shapes of
    the ``pallas_call``, so a kernel edit that grows a tile without
    updating its declaration shows up as a divergence in review.
    """

    kernel: str                 # e.g. "wkv.fwd"
    location: str               # repo-path-like site of the pallas_call
    grid: tuple[int, ...]
    blocks: tuple[tuple[str, tuple[int, ...], int], ...]
    scratch: tuple[tuple[str, tuple[int, ...], int], ...] = ()

    def block_bytes(self) -> int:
        return sum(math.prod(s) * isz for _, s, isz in self.blocks)

    def scratch_bytes(self) -> int:
        return sum(math.prod(s) * isz for _, s, isz in self.scratch)

    def vmem_bytes(self, *, double_buffer: int = 2) -> int:
        """Estimated VMEM high-water mark: every in/out block held
        ``double_buffer``-deep (the pipelined prefetch) + scratch."""
        return double_buffer * self.block_bytes() + self.scratch_bytes()

    def grid_steps(self) -> int:
        return math.prod(self.grid) if self.grid else 1


#: name -> spec fn.  A spec fn has signature ``fn(cfg) -> KernelResources
#: | None`` (None: kernel not applicable to this config) and must *raise*
#: (ValueError) on invalid geometry — the audit converts that into an
#: error finding, which is how the wrappers' divisibility validation
#: (``validate_divisible`` / ``pick_d_block`` / chunk resolution) gets
#: checked without building a single array.
KERNEL_RESOURCE_SPECS: dict[str, Callable] = {}


def register_kernel_resources(name: str):
    """Decorator: register a resource-spec fn under ``name``."""

    def deco(fn):
        KERNEL_RESOURCE_SPECS[name] = fn
        return fn

    return deco


# --------------------------------------------------------------------------
# Chunk / block validation (kernel wrappers)
# --------------------------------------------------------------------------

def validate_divisible(name: str, total: int, block: int) -> None:
    if block < 1 or total % block:
        raise ValueError(f"{name}={total} not divisible by block={block}")


def pick_d_block(d: int, cap: int = 512) -> int:
    """Feature-axis block: lane-friendly cap, must tile D exactly."""
    d_block = min(d, cap)
    if d % d_block:
        raise ValueError(f"D={d} not divisible by d_block={d_block}")
    return d_block


def largest_divisor_chunk(t: int, chunk: int) -> int:
    """Largest c <= min(chunk, t) with t % c == 0 (always exists: c=1)."""
    for c in range(min(chunk, t), 0, -1):
        if t % c == 0:
            return c
    return 1


def halving_chunk(t: int, chunk: int) -> int:
    """Shrink ``chunk`` by halving until it divides ``t`` (power-of-two
    kernels: preserves two-ness when the caller starts from a power of two)."""
    c = min(chunk, t)
    while c > 1 and t % c:
        c //= 2
    return c
