"""Public op: tiled matmul with operand-forwarding reuse accounting."""

from __future__ import annotations

import functools

import jax

from repro.core.cost_model import Traffic
from repro.kernels.matmul_fwd.kernel import matmul_fwd_pallas
from repro.kernels.matmul_fwd.ref import matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def matmul_fwd(
    a, b, *, block_m=256, block_n=256, block_k=256, use_kernel: bool | None = None
):
    kernel = _on_tpu() if use_kernel is None else use_kernel
    if kernel:
        return matmul_fwd_pallas(
            a, b, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=not _on_tpu(),
        )
    return matmul_ref(a, b)


def tile_traffic(m, n, k, block_m, block_n, block_k, itemsize=2) -> Traffic:
    """HBM bytes for the tiled schedule (per §3.3's reuse law).

    Naive per-element: 2·M·N·K element loads.  Tiled: each output tile
    re-streams A and B panels once per K-block.
    """
    tiles = (m // block_m) * (n // block_n)
    per_tile = (k // block_k) * (block_m * block_k + block_k * block_n)
    return Traffic(
        dram_bytes=(tiles * per_tile + m * n) * itemsize,
        fabric_bytes=(2 * m * n * k - tiles * per_tile) * itemsize,
    )
