"""Pallas TPU kernel: operand-forwarding matmul (paper Fig. 2/3).

The paper's dMT-CGRA matmul has one thread per C element; only first-row /
first-column threads load from memory, and operands travel thread-to-thread
through the fabric.  The TPU-native equivalent of that reuse is *block
residency*: a (bm×bk) A tile and a (bk×bn) B tile are pulled from HBM once
and consumed by bm·bn MXU MACs — the systolic array IS the forwarding
fabric (each loaded element is reused along the other operand's dimension
exactly like the paper's thread (0,2) → (1,2) → (2,2) chain).

HBM traffic per output tile: K/bk · (bm·bk + bk·bn) instead of the naive
per-element 2K — a reduction of bm·bn/(bm+bn), the same N·K·M → N·M law
as §3.3 at tile granularity.

Grid: (M/bm, N/bn, K/bk), K innermost; float32 accumulator in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def matmul_kernel(a_ref, b_ref, out_ref, acc_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kk == pl.num_programs(2) - 1)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_fwd_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with MXU-aligned VMEM tiling.  A: (M, K), B: (K, N)."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"shape ({m},{k})x({k},{n}) not divisible by blocks "
            f"({block_m},{block_n},{block_k})"
        )

    return pl.pallas_call(
        matmul_kernel,
        grid=(m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
