"""Sequence-parallel WKV: device-space elevator edges over a mesh axis.

PR 1/2 replaced the group-to-group "stage through HBM + barrier" pattern
*within* a chip: the (Dh × Dh) WKV state rides a VMEM carry between
sequence chunks (forward), and the adjoint ``dS`` rides it back (reverse).
The same elevator edge exists *between* chips.  A sequence-sharded model
that all-gathers the state — or worse, the tokens — to stitch shards
together is the paper's Fig. 1b scratchpad pattern at ICI granularity.

This module removes it with the segment-summary protocol:

1. every device runs the existing fused kernel on its local shard with a
   **zero** entering state, additionally emitting the segment summary
   ``(a_seg, S_exit⁰)`` — the decay product (B, H, Dh) and the exit state
   (B, H, Dh, Dh) (``wkv_fused_summary``);
2. the summaries compose across the ``seq`` mesh axis under the
   ``DIAG_STATE`` monoid (``core.chunk_scan.device_linear_scan_carry``):
   log₂(n) point-to-point ppermute hops, each carrying O(Dh²) bytes —
   device-space elevator nodes, never a token re-gather;
3. each shard reconstructs its true entering state
   ``S_in = carry_a ★ h0 + carry_b`` and adds the (linear) entry
   correction ``(r_t ⊙ D_{<t}) @ S_in`` to its local outputs
   (``ref.wkv_entry_correction``); the final state is read off the last
   shard with one masked psum (again O(Dh²)).

**Training falls out by transposition**: the VJP of a ppermute is the
opposite-direction ppermute, so ``jax.grad`` through this path runs the
composition sweep *backward* — the adjoint ``dS``/``d_a`` summaries hop
last-shard→first exactly as ``device_linear_scan_carry(reverse=True)``
would, while each shard's local gradient goes through the reverse
elevator kernel (``bwd.py``) via the ``wkv_diff_summary`` custom VJP.
Only segment summaries ever cross the axis, forward or backward
(asserted on the jaxpr in ``tests/test_multidevice.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import device_comm
from repro.core.chunk_scan import DIAG_STATE, device_linear_scan_carry
from repro.kernels.common import shard_map_norep
from repro.kernels.wkv.ops import wkv_fused_summary
from repro.kernels.wkv.ref import wkv_entry_correction

__all__ = ["wkv_seq_local", "wkv_seqshard"]


def wkv_seq_local(
    r, k, v, w, u, h0, *, axis_name: str, chunk: int = 64,
    use_kernel: bool | None = None,
):
    """Per-shard body of the sequence-parallel WKV (call inside shard_map).

    ``r/k/v/w`` are the *local* sequence shard (B, H, T/n, Dh); ``h0`` is
    the global entering state (replicated over ``axis_name``).  Returns
    ``(out_local, S_out)`` with ``S_out`` the global exit state, identical
    on every shard.
    """
    f32 = jnp.float32
    out0, s0, a_seg = wkv_fused_summary(
        r, k, v, w, u, None, chunk=chunk, use_kernel=use_kernel
    )
    # Compose (A, S) summaries along the mesh axis: the entering state of
    # shard i is carry_a ★ h0 + carry_b (DIAG_STATE monoid, h0 enters
    # shard 0 as the elevator boundary constant).
    carry_a, carry_b = device_linear_scan_carry(
        a_seg, s0, axis_name, monoid=DIAG_STATE
    )
    s_in = DIAG_STATE.scale(carry_a, h0.astype(f32)) + carry_b
    out = (out0.astype(f32) + wkv_entry_correction(r, w, s_in)).astype(r.dtype)
    # Exit state of this shard; the global S_out is the last shard's.  The
    # masked psum moves one more O(Dh²) summary, never activations.
    s_exit = DIAG_STATE.scale(a_seg, s_in) + s0
    idx = jax.lax.axis_index(axis_name)
    n = device_comm.axis_size(axis_name)
    s_out = jax.lax.psum(jnp.where(idx == n - 1, s_exit, 0.0), axis_name)
    return out, s_out


def wkv_seqshard(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array | None = None,
    *,
    mesh,
    seq_axis,
    batch_axis=None,
    chunk: int = 64,
    use_kernel: bool | None = None,
):
    """Sequence-sharded WKV over ``mesh``'s ``seq_axis``.

    Same signature/returns as :func:`repro.kernels.wkv.ops.wkv_fused`
    (``out`` in ``r.dtype``, ``S_out`` float32) plus the mesh placement:
    the T axis of r/k/v/w is sharded over ``seq_axis`` (T must divide
    evenly), the batch axis optionally over ``batch_axis``; u and h0 are
    replicated along ``seq_axis``.  Differentiable — the gradient runs the
    device-space *reverse* elevator (summary ppermutes transposed to the
    opposite direction) composed with the local reverse kernel sweep.
    """
    b, h, t, dh = r.shape
    if h0 is None:
        h0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    seq_spec = P(batch_axis, None, seq_axis, None)
    state_spec = P(batch_axis, None, None, None)
    local = functools.partial(
        wkv_seq_local, axis_name=seq_axis, chunk=chunk, use_kernel=use_kernel
    )
    fn = shard_map_norep(
        local,
        mesh=mesh,
        in_specs=(seq_spec, seq_spec, seq_spec, seq_spec, P(None, None),
                  state_spec),
        out_specs=(seq_spec, state_spec),
    )
    return fn(r, k, v, w, u, h0)
