"""Fused WKV elevator kernel (RWKV6 matrix-state recurrence).

Paper mapping (§4.3, token buffers): the WKV state ``S`` — a (Dh × Dh)
matrix per (batch, head) — is the loop-carried value of a Δ=1 elevator
edge over sequence-chunk space.  The Pallas kernel keeps it in a
``pltpu.VMEM((dh, dh))`` scratch: each grid step along the chunk axis
withdraws the predecessor's token (the entering state), fuses the
intra-chunk decay-ratio attention with the inter-chunk state read and the
state update, and deposits the exit state for its successor.  ``h0`` is
the ``fromThreadOrConst`` boundary constant withdrawn by chunk 0.  The
jnp fallback (``ref.wkv_chunked_ref``) computes identical math but stages
every per-chunk intermediate and the scan carry through HBM — the
Fig. 1b scratchpad pattern the kernel eliminates.

Training closes the same loop in reverse: the backward pass's
loop-carried value is the adjoint state ``dS`` (same (Dh × Dh) shape),
and ``bwd.py`` carries it in a VMEM scratch over a back-to-front chunk
sweep — reset at the *last* chunk to the incoming state cotangent,
per-chunk decays recomputed in-fabric instead of staged through HBM.
``vjp.py`` ties the two sweeps into a ``jax.custom_vjp`` so ``wkv_fused``
is differentiable end-to-end on both the kernel and jnp paths.

The same edge exists between chips: ``seqpar.py`` composes per-device
``(decay-product, exit-state)`` segment summaries across a ``seq`` mesh
axis (the ``DIAG_STATE`` monoid of :mod:`repro.core.chunk_scan`), so a
sequence-sharded model forwards O(Dh²) summaries point-to-point instead
of all-gathering tokens or states — device-space elevator edges, forward
and (by ppermute transposition) reverse for training.

Ships as kernel.py (forward pallas_call, plus the training variant that
records chunk-entry states and the summary variants that emit the segment
decay product), bwd.py (reverse sweep), vjp.py (custom_vjp assembly),
ops.py (dispatch + chunk policy), seqpar.py (sequence-parallel protocol)
and ref.py (sequential + chunked oracles, forward and backward, plus the
jnp segment-summary helpers).
"""

from repro.kernels.wkv.ops import wkv_fused, wkv_fused_summary
from repro.kernels.wkv.ref import (
    wkv_chunked_bwd_ref,
    wkv_chunked_ref,
    wkv_sequential_ref,
)
from repro.kernels.wkv.seqpar import wkv_seq_local, wkv_seqshard

__all__ = [
    "wkv_fused",
    "wkv_fused_summary",
    "wkv_seq_local",
    "wkv_seqshard",
    "wkv_chunked_ref",
    "wkv_chunked_bwd_ref",
    "wkv_sequential_ref",
]
