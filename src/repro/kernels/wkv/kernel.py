"""Pallas TPU kernel: fused RWKV6 WKV recurrence, matrix state in VMEM.

The heaviest loop-carried value in this repo is the WKV state — a
(Dh × Dh) matrix per (batch, head) forwarded from chunk to chunk.  This
kernel is the paper's §4.3 construction applied to it:

* the ``pltpu.VMEM((dh, dh))`` scratch is the elevator *token buffer* of a
  Δ=1 edge over chunk space: chunk ``s`` deposits its exit state, chunk
  ``s+1`` (the next grid step on the same (batch, head) tile) withdraws it
  — a point-to-point hand-off that never touches HBM, where the jnp
  fallback's ``lax.scan`` carry round-trips every chunk (Fig. 1b);
* ``h0`` is the boundary constant ``C`` of ``fromThreadOrConst``: chunk 0
  withdraws it instead of a predecessor token;
* the per-chunk decay tensors (``r_dec``, ``k_inv``, ``k_rem``, cumulative
  log-decays) and the masked score matrix are fused into the same pass —
  in-fabric values on the VPU/MXU, never materialized.

Grid: ``(batch, head, seq_chunks)``, sequence fastest, so the scratch is
private per (batch, head) and reset at chunk 0 — the same schedule as
``elevator_scan`` / ``token_shift`` (see :mod:`repro.kernels.common`).

Recurrence (per head, f32 accumulation):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t · (S_{t-1} + u k_t^T v_t)

Entry points: :func:`wkv_pallas` (inference forward),
:func:`wkv_pallas_train` (training forward: also emits ``s_hist``, the
state entering each chunk — the one residual the reverse sweep in
:mod:`repro.kernels.wkv.bwd` cannot recompute in its own direction), and
the ``*_summary`` variants which additionally emit the segment decay
product ``a_seg`` — the diag-decay half of the (A, S) segment summary the
sequence-parallel protocol (:mod:`repro.kernels.wkv.seqpar`) forwards
across the mesh instead of gathering tokens.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cumsum_rows, reset_carry, validate_divisible


def _wkv_fwd_body(
    r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref, out_ref, s_out_ref, s_ref,
    *, chunk: int, s_hist_ref=None, a_out_ref=None, a_acc_ref=None,
):
    # Boundary: chunk 0 withdraws the constant h0 instead of a token.
    reset_carry(s_ref, h0_ref[0, 0], seq_axis=2)
    if a_acc_ref is not None:
        # Segment-summary mode: the decay product accumulates multiplicatively,
        # so its boundary constant is the monoid identity 1 (not 0).
        reset_carry(a_acc_ref, jnp.ones(a_acc_ref.shape, a_acc_ref.dtype),
                    seq_axis=2)

    if s_hist_ref is not None:
        # Training: record the state *entering* this chunk — the only
        # staged value the reverse sweep (bwd.py) cannot recompute in its
        # own direction (it is a forward-flowing quantity).
        s_hist_ref[0, 0, 0] = s_ref[...]

    r = r_ref[0, 0].astype(jnp.float32)        # (chunk, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (dh,)

    # Decay-ratio factorization, all in registers/VMEM (nothing staged):
    #   cum_excl[t] = sum_{s<t} log w_s, w_total = prod over the chunk.
    logw = jnp.log(jnp.clip(w, 1e-8, 1.0))
    cum_incl = cumsum_rows(logw, chunk)
    cum_excl = cum_incl - logw
    w_total = jnp.exp(cum_incl[-1])            # (dh,)

    if a_acc_ref is not None:
        # Per-segment summary: A_seg = prod over every chunk's w_total — the
        # diag-decay half of the (A, S) pair that crosses the mesh axis in
        # the sequence-parallel protocol (seqpar.py).  Rides its own tiny
        # VMEM carry exactly like S.
        a_acc_ref[...] = a_acc_ref[...] * w_total[None, :]
        a_out_ref[0, 0] = a_acc_ref[0]         # last grid step wins

    r_dec = r * jnp.exp(cum_excl)              # r_t * D_{<t}
    k_inv = k * jnp.exp(-cum_incl)             # k_s / D_{<=s}
    k_rem = k * jnp.exp(cum_incl[-1:] - cum_incl)  # k_s * D_{(s..L]}

    # Intra-chunk attention: A[t,s] = (r_t D_{<t}) · (k_s / D_{<=s}), s < t,
    # plus the u-bonus on the diagonal.
    scores = jax.lax.dot_general(
        r_dec, k_inv, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # (chunk, chunk)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(si < ti, scores, 0.0)
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)  # (chunk, 1)
    intra = jnp.dot(scores, v, preferred_element_type=jnp.float32) + bonus * v

    # Inter-chunk read: withdraw the entering state token from VMEM.
    S = s_ref[...]                              # (dh, dh)
    inter = jnp.dot(r_dec, S, preferred_element_type=jnp.float32)
    out_ref[0, 0] = (intra + inter).astype(out_ref.dtype)

    # State update + token hand-off (retag TID -> TID + 1).
    S_new = S * w_total[:, None] + jax.lax.dot_general(
        k_rem, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s_ref[...] = S_new
    s_out_ref[0, 0] = S_new                     # last grid step wins


def wkv_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref, out_ref, s_out_ref, s_ref,
    *, chunk: int,
):
    _wkv_fwd_body(
        r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref, out_ref, s_out_ref, s_ref,
        chunk=chunk,
    )


def wkv_train_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref,
    out_ref, s_out_ref, s_hist_ref, s_ref, *, chunk: int,
):
    _wkv_fwd_body(
        r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref, out_ref, s_out_ref, s_ref,
        chunk=chunk, s_hist_ref=s_hist_ref,
    )


def wkv_summary_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref,
    out_ref, s_out_ref, a_out_ref, s_ref, a_acc_ref, *, chunk: int,
):
    _wkv_fwd_body(
        r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref, out_ref, s_out_ref, s_ref,
        chunk=chunk, a_out_ref=a_out_ref, a_acc_ref=a_acc_ref,
    )


def wkv_train_summary_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref,
    out_ref, s_out_ref, s_hist_ref, a_out_ref, s_ref, a_acc_ref,
    *, chunk: int,
):
    _wkv_fwd_body(
        r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref, out_ref, s_out_ref, s_ref,
        chunk=chunk, s_hist_ref=s_hist_ref, a_out_ref=a_out_ref,
        a_acc_ref=a_acc_ref,
    )


def _wkv_pallas_call(r, k, v, w, u, h0, *, chunk, interpret, with_hist,
                     with_summary=False):
    b, h, t, dh = r.shape
    validate_divisible("T", t, chunk)
    if u.shape != (h, dh):
        raise ValueError(f"u shape {u.shape} != {(h, dh)}")
    if h0.shape != (b, h, dh, dh):
        raise ValueError(f"h0 shape {h0.shape} != {(b, h, dh, dh)}")
    n_chunks = t // chunk

    grid = (b, h, n_chunks)
    seq_spec = pl.BlockSpec((1, 1, chunk, dh), lambda bi, hi, si: (bi, hi, si, 0))
    state_spec = pl.BlockSpec((1, 1, dh, dh), lambda bi, hi, si: (bi, hi, 0, 0))
    out_specs = (seq_spec, state_spec)
    out_shape = (
        jax.ShapeDtypeStruct((b, h, t, dh), r.dtype),
        jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
    )
    if with_hist:
        out_specs += (pl.BlockSpec(
            (1, 1, 1, dh, dh), lambda bi, hi, si: (bi, hi, si, 0, 0)
        ),)
        out_shape += (
            jax.ShapeDtypeStruct((b, h, n_chunks, dh, dh), jnp.float32),
        )
    if with_summary:
        out_specs += (pl.BlockSpec((1, 1, dh), lambda bi, hi, si: (bi, hi, 0)),)
        out_shape += (jax.ShapeDtypeStruct((b, h, dh), jnp.float32),)
    kernels = {
        (False, False): wkv_kernel,
        (True, False): wkv_train_kernel,
        (False, True): wkv_summary_kernel,
        (True, True): wkv_train_summary_kernel,
    }
    kernel = functools.partial(kernels[(with_hist, with_summary)], chunk=chunk)
    scratch_shapes = [pltpu.VMEM((dh, dh), jnp.float32)]
    if with_summary:
        scratch_shapes.append(pltpu.VMEM((1, dh), jnp.float32))  # A_seg carry
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec,  # r
            seq_spec,  # k
            seq_spec,  # v
            seq_spec,  # w
            pl.BlockSpec((1, dh), lambda bi, hi, si: (hi, 0)),  # u
            state_spec,  # h0
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(r, k, v, w, u, h0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Fused WKV sweep.  r/k/v/w: (B, H, T, Dh); u: (H, Dh);
    h0: (B, H, Dh, Dh).  Returns (out (B,H,T,Dh) r.dtype, S (B,H,Dh,Dh) f32).
    """
    return _wkv_pallas_call(
        r, k, v, w, u, h0, chunk=chunk, interpret=interpret, with_hist=False
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas_train(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Forward sweep for training: like :func:`wkv_pallas` but additionally
    emits ``s_hist`` (B, H, N, Dh, Dh) — the state entering each chunk.

    ``s_hist`` is the one residual the reverse elevator sweep stages
    through HBM: N small (Dh × Dh) tokens per (batch, head), versus the
    ~6 T·Dh decay tensors + (T/chunk)·chunk² score matrices the autodiff
    path saves.  Everything else is recomputed inside the backward kernel.
    """
    return _wkv_pallas_call(
        r, k, v, w, u, h0, chunk=chunk, interpret=interpret, with_hist=True
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas_summary(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Forward sweep emitting the per-segment summary: like
    :func:`wkv_pallas` but additionally returns ``a_seg`` (B, H, Dh), the
    product of every decay in the segment.

    ``(a_seg, S_out)`` is the segment summary of the sequence-parallel
    protocol (:mod:`repro.kernels.wkv.seqpar`): composing it across a mesh
    axis (``core.chunk_scan.DIAG_STATE`` monoid) reconstructs every shard's
    entering state from O(Dh²) bytes per hop — no token re-gather.
    """
    return _wkv_pallas_call(
        r, k, v, w, u, h0, chunk=chunk, interpret=interpret,
        with_hist=False, with_summary=True,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas_train_summary(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Training forward with the segment summary: returns
    ``(out, S_out, s_hist, a_seg)`` — the union of
    :func:`wkv_pallas_train` and :func:`wkv_pallas_summary` outputs in one
    sweep (one HBM read of the inputs)."""
    return _wkv_pallas_call(
        r, k, v, w, u, h0, chunk=chunk, interpret=interpret,
        with_hist=True, with_summary=True,
    )
