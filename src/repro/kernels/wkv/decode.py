"""Pallas TPU decode micro-kernels: persistent WKV state across tokens.

Decode is the repo's worst memory offender: every generated token used to
read and write the full (B, H, Dh, Dh) WKV state through HBM, because the
``t == 1`` dispatch punted to the jnp sequential oracle and the serve loop
re-dispatched per token.  These kernels are the paper's loop-carried-value
argument applied to serving:

* :func:`wkv_decode_pallas` — the single-step kernel on a ``(batch, head)``
  grid.  One token: ``o = r · (S + u kᵀv)``, ``S' = diag(w) S + kᵀv``, f32
  accumulation, bf16 I/O like the fused path.  No chunk machinery, no
  score matrices — two rank-1 updates and a matvec, fused in one pass so
  the state is read from HBM exactly once and written exactly once.
* :func:`wkv_decode_window_pallas` — the multi-token variant: a
  ``(B, H, K, Dh)`` window of K decode steps swept in ONE kernel
  invocation on a ``(batch, head, K)`` grid with S held in a VMEM scratch
  — the same Δ=1 elevator carry the chunked kernel uses over chunk space,
  now over *decode steps*.  One HBM read + one write of S per K tokens
  instead of per token; the K-1 intermediate states ride the fabric
  (``cost_model.wkv_decode_traffic`` counts exactly these bytes).  K is
  arbitrary (no divisibility constraint — there is no chunk structure).

Unlike the chunked kernel there is no decay-ratio factorization: the
sequential form is exact and the per-step work is O(Dh²), so nothing is
gained by exponent bookkeeping — and losing it removes the clip-range
coupling between window length and decay magnitude.

Both entry points are differentiable through :func:`wkv_decode_diff`
(recompute-over-stage: the backward is the sequential manual sweep
``wkv_chunked_bwd_ref(chunk=1)``; the only residuals are the primals).
Dispatch (``ops.wkv_fused(decode=True)``) sends windows up to
:data:`DECODE_WINDOW_MAX` tokens here and longer stateful sweeps (e.g.
long-prompt prefill-into-cache) to the chunked kernel, where the MXU score
matrices start paying for themselves.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import reset_carry
from repro.kernels.wkv.ref import wkv_chunked_bwd_ref, wkv_sequential_ref

# Stateful (decode) dispatches at or below this many tokens take the
# window kernel; above it the chunked elevator kernel wins (intra-chunk
# score matmuls amortize on the MXU).  64 = one chunk of the fused path.
DECODE_WINDOW_MAX = 64

__all__ = [
    "DECODE_WINDOW_MAX",
    "wkv_decode_pallas",
    "wkv_decode_window_pallas",
    "wkv_decode_diff",
]


def _decode_token(r, k, v, w, u, S):
    """One WKV step on (1, dh) token rows against the (dh, dh) state.

    Returns ``(o, S_new)`` in f32.  ``o = r·(S + u kᵀv)`` splits into the
    state matvec plus a u-weighted rank-1 bonus: ``o = r @ S + (r·u·k) v``.
    """
    kv = jax.lax.dot_general(
        k, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )                                                   # kᵀv: (dh, dh)
    inter = jnp.dot(r, S, preferred_element_type=jnp.float32)   # (1, dh)
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)  # (1, 1)
    o = inter + bonus * v
    S_new = S * w[0][:, None] + kv
    return o, S_new


def wkv_decode_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref,
                      out_ref, s_out_ref):
    """Single step, grid (batch, head): state read once, written once."""
    r = r_ref[0, 0].astype(jnp.float32)                 # (1, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                    # (dh,)
    o, S_new = _decode_token(r, k, v, w, u, h0_ref[0, 0])
    out_ref[0, 0] = o.astype(out_ref.dtype)
    s_out_ref[0, 0] = S_new


def wkv_decode_window_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, h0_ref,
                             out_ref, s_out_ref, s_ref):
    """K-step window, grid (batch, head, K): S rides the VMEM scratch.

    Grid step ``i`` withdraws the state deposited by step ``i-1`` (step 0
    withdraws the boundary constant ``h0``) — the elevator hand-off of the
    chunked kernel with decode steps as the chunk axis.  HBM sees one read
    (``h0``) and one write (``s_out``, last grid step wins) per K tokens.
    """
    reset_carry(s_ref, h0_ref[0, 0], seq_axis=2)
    r = r_ref[0, 0].astype(jnp.float32)                 # (1, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)
    o, S_new = _decode_token(r, k, v, w, u, s_ref[...])
    out_ref[0, 0] = o.astype(out_ref.dtype)
    s_ref[...] = S_new                                  # hand-off: TID -> TID+1
    s_out_ref[0, 0] = S_new                             # last grid step wins


def _validate(r, u, h0):
    b, h, t, dh = r.shape
    if u.shape != (h, dh):
        raise ValueError(f"u shape {u.shape} != {(h, dh)}")
    if h0.shape != (b, h, dh, dh):
        raise ValueError(f"h0 shape {h0.shape} != {(b, h, dh, dh)}")


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_decode_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array,
    *,
    interpret: bool = False,
):
    """Single decode step.  r/k/v/w: (B, H, 1, Dh); u: (H, Dh);
    h0: (B, H, Dh, Dh).  Returns (out (B,H,1,Dh) r.dtype, S (B,H,Dh,Dh) f32).
    """
    b, h, t, dh = r.shape
    if t != 1:
        raise ValueError(f"wkv_decode_pallas is single-step; got T={t}")
    _validate(r, u, h0)
    seq_spec = pl.BlockSpec((1, 1, 1, dh), lambda bi, hi: (bi, hi, 0, 0))
    state_spec = pl.BlockSpec((1, 1, dh, dh), lambda bi, hi: (bi, hi, 0, 0))
    return pl.pallas_call(
        wkv_decode_kernel,
        grid=(b, h),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, dh), lambda bi, hi: (hi, 0)),  # u
            state_spec,
        ],
        out_specs=(seq_spec, state_spec),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, 1, dh), r.dtype),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ),
        interpret=interpret,
    )(r, k, v, w, u, h0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_decode_window_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array,
    *,
    interpret: bool = False,
):
    """K-token decode window.  r/k/v/w: (B, H, K, Dh), any K >= 1 (no
    divisibility constraint); u: (H, Dh); h0: (B, H, Dh, Dh).  Returns
    (out (B,H,K,Dh) r.dtype, S (B,H,Dh,Dh) f32) — bit-identical to K
    single steps chained, with one HBM round-trip of S instead of K.
    """
    b, h, t, dh = r.shape
    _validate(r, u, h0)
    seq_spec = pl.BlockSpec((1, 1, 1, dh), lambda bi, hi, ti: (bi, hi, ti, 0))
    state_spec = pl.BlockSpec((1, 1, dh, dh), lambda bi, hi, ti: (bi, hi, 0, 0))
    return pl.pallas_call(
        wkv_decode_window_kernel,
        grid=(b, h, t),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, dh), lambda bi, hi, ti: (hi, 0)),  # u
            state_spec,
        ],
        out_specs=(seq_spec, state_spec),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, t, dh), r.dtype),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, h0)


# --------------------------------------------------------------------------
# Differentiable wrapper (ops.wkv_fused decode dispatch)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def wkv_decode_diff(interpret, use_pallas, r, k, v, w, u, h0):
    """Differentiable decode-window WKV.  Returns ``(out, S_out)`` with
    ``out`` in ``r.dtype`` and ``S_out`` float32.

    Forward: the single-step kernel (K == 1) or the window kernel
    (``use_pallas=True``), else the jnp sequential oracle — for decode
    windows the sequential form IS the cheapest jnp rendering (no chunk
    structure to exploit).  Backward: the manual sequential sweep
    (``wkv_chunked_bwd_ref`` at chunk 1) — recompute-over-stage, so the
    only residuals are the primal inputs.
    """
    if use_pallas:
        if r.shape[2] == 1:
            return wkv_decode_pallas(r, k, v, w, u, h0, interpret=interpret)
        return wkv_decode_window_pallas(r, k, v, w, u, h0, interpret=interpret)
    out, s_out = wkv_sequential_ref(r, k, v, w, u, h0)
    return out.astype(r.dtype), s_out


def _wkv_decode_fwd(interpret, use_pallas, r, k, v, w, u, h0):
    out = wkv_decode_diff(interpret, use_pallas, r, k, v, w, u, h0)
    return out, (r, k, v, w, u, h0)


def _wkv_decode_bwd(interpret, use_pallas, res, cts):
    r, k, v, w, u, h0 = res
    d_out, d_s_out = cts
    dr, dk, dv, dw, du, dh0 = wkv_chunked_bwd_ref(
        r, k, v, w, u, h0, d_out, d_s_out, chunk=1
    )
    return (
        dr.astype(r.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        dw.astype(w.dtype),
        du.astype(u.dtype),
        dh0.astype(h0.dtype),
    )


wkv_decode_diff.defvjp(_wkv_decode_fwd, _wkv_decode_bwd)
