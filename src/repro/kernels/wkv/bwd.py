"""Pallas TPU kernel: reverse elevator sweep for the fused WKV backward.

The training-loop twin of :mod:`repro.kernels.wkv.kernel`.  The heaviest
loop-carried value of the backward pass is the adjoint state ``dS`` — a
(Dh × Dh) matrix per (batch, head) flowing from chunk ``s+1`` to chunk
``s``.  This kernel carries it exactly the way the forward carries ``S``,
with the sweep direction reversed:

* same ``(batch, head, seq_chunks)`` grid, but the block index maps walk
  the sequence axis back-to-front (:func:`repro.kernels.common.reversed_chunk`)
  — a Δ=-1 elevator edge over chunk space;
* the ``pltpu.VMEM((dh, dh))`` scratch is the adjoint token buffer, reset
  at the *last* chunk (grid step 0 of the reversed sweep) to the incoming
  state cotangent ``dS_out`` — the reverse ``fromThreadOrConst`` boundary;
* **recompute over stage**: the per-chunk decay tensors (cumulative
  log-decays, ``r_dec``/``k_inv``/``k_rem``) and the masked score matrix
  are recomputed from the primal inputs inside the kernel — in-fabric VPU
  work — instead of being saved by the forward and round-tripped through
  HBM the way ``jax.grad`` of the chunked reference stages them.  The one
  staged residual is ``s_hist`` (the state entering each chunk, N small
  (Dh × Dh) tokens), because it flows *forward* and cannot be produced by
  a backward sweep;
* the adjoint of a forward prefix-sum (the cumulative log-decay chains) is
  a *suffix* sum — :func:`repro.kernels.common.rev_cumsum_rows`, the same
  Hillis–Steele forwarding network run with negative shifts.

Per chunk (length L, entering state S, exit-state adjoint G = scratch):

    dr_dec = dscores @ k_inv + do @ S^T          dscores = mask(do @ V^T)
    dk     = (dscores^T r_dec) ⊙ e^{-cum} + (V G^T) ⊙ e^{cum[-1]-cum} + bonus
    dv     = scores^T do + k_rem G + bonus
    dlogw  = rev_cumsum(dcum_incl) + rev_cumsum_excl(dcum_excl)
    G_prev = diag(w_total) G + r_dec^T do        (the carried token)

``du`` accumulates per (batch, head) tile in a VMEM scratch and is summed
over batch outside; ``dh0`` is the carry after chunk 0 (last grid step).

The same reversal exists one level up: under sequence sharding
(``seqpar.py``) the ``dS`` emitted here as ``dh0`` becomes a shard's
exit-state adjoint, and the device-space carry composition transposes
into reverse-direction ppermute hops — this kernel is the in-chip leg of
that sweep, the ICI hops are its between-chip continuation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    cumsum_rows,
    reset_carry,
    rev_cumsum_rows,
    reversed_chunk,
    validate_divisible,
)


def wkv_bwd_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s_hist_ref, do_ref, ds_out_ref,
    dr_ref, dk_ref, dv_ref, dw_ref, du_ref, dh0_ref,
    ds_ref, du_acc_ref,
    *, chunk: int,
):
    # Reverse boundary: the last chunk (grid step 0) withdraws the output
    # state cotangent instead of a successor token; du starts at zero.
    reset_carry(ds_ref, ds_out_ref[0, 0], seq_axis=2)
    reset_carry(du_acc_ref, seq_axis=2)

    dh = r_ref.shape[-1]
    r = r_ref[0, 0].astype(jnp.float32)        # (chunk, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (dh,)
    do = do_ref[0, 0].astype(jnp.float32)      # (chunk, dh)
    S = s_hist_ref[0, 0, 0]                    # (dh, dh) entering state
    dS = ds_ref[...]                           # (dh, dh) exit-state adjoint

    # Recomputed decays — identical math to the forward kernel, in-fabric.
    logw = jnp.log(jnp.clip(w, 1e-8, 1.0))
    cum_incl = cumsum_rows(logw, chunk)
    cum_excl = cum_incl - logw
    w_total = jnp.exp(cum_incl[-1])            # (dh,)
    r_dec = r * jnp.exp(cum_excl)
    k_inv = k * jnp.exp(-cum_incl)
    k_rem = k * jnp.exp(cum_incl[-1:] - cum_incl)

    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lower = si < ti
    scores = jnp.where(lower, jax.lax.dot_general(
        r_dec, k_inv, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ), 0.0)
    dscores = jnp.where(lower, jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ), 0.0)

    dov = jnp.sum(do * v, axis=1, keepdims=True)            # (chunk, 1)

    # Adjoints of the decay-weighted operands.
    d_rdec = jnp.dot(dscores, k_inv, preferred_element_type=jnp.float32) + \
        jax.lax.dot_general(do, S, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    d_kinv = jax.lax.dot_general(
        dscores, r_dec, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d_krem = jax.lax.dot_general(
        v, dS, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    dr = d_rdec * jnp.exp(cum_excl) + u[None, :] * k * dov
    dk = (d_kinv * jnp.exp(-cum_incl)
          + d_krem * jnp.exp(cum_incl[-1:] - cum_incl)
          + r * u[None, :] * dov)
    dv = (jax.lax.dot_general(scores, do, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
          + jnp.dot(k_rem, dS, preferred_element_type=jnp.float32)
          + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * do)

    # logw adjoint: suffix sums (adjoint of the forward prefix sums), with
    # the cum_incl[-1] consumers (k_rem numerator, w_total in the exit
    # decay) folded onto the last row first.
    dcum_excl = d_rdec * r_dec
    dcum_incl = -d_kinv * k_inv - d_krem * k_rem
    last = (jnp.sum(d_krem * k_rem, axis=0)
            + w_total * jnp.sum(S * dS, axis=1))            # (dh,)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, dh), 0)
    dcum_incl = dcum_incl + jnp.where(row == chunk - 1, last[None, :], 0.0)
    dlogw = (rev_cumsum_rows(dcum_incl, chunk)
             + rev_cumsum_rows(dcum_excl, chunk) - dcum_excl)
    in_range = (w >= 1e-8) & (w <= 1.0)
    dw = jnp.where(in_range, dlogw / jnp.clip(w, 1e-8, 1.0), 0.0)

    dr_ref[0, 0] = dr.astype(dr_ref.dtype)
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)
    dw_ref[0, 0] = dw.astype(dw_ref.dtype)

    # du partial: accumulate over this (batch, head) tile's chunks.
    du_acc_ref[...] = du_acc_ref[...] + jnp.sum(r * k * dov, axis=0,
                                                keepdims=True)
    du_ref[0, 0] = du_acc_ref[0]               # last grid step wins

    # Adjoint token hand-off (retag TID -> TID - 1): the entering-state
    # adjoint becomes the predecessor chunk's exit-state adjoint.
    dS_prev = dS * w_total[:, None] + jax.lax.dot_general(
        r_dec, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    ds_ref[...] = dS_prev
    dh0_ref[0, 0] = dS_prev                    # last grid step = chunk 0


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas_bwd(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    s_hist: jax.Array,
    d_out: jax.Array,
    d_s_out: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Reverse chunk sweep.  r/k/v/w/d_out: (B, H, T, Dh); u: (H, Dh);
    s_hist: (B, H, N, Dh, Dh) chunk-entry states from the training forward;
    d_s_out: (B, H, Dh, Dh).

    Returns ``(dr, dk, dv, dw, du_part, dh0)`` — dr/dk/dv/dw in the primal
    dtypes, ``du_part`` (B, H, Dh) per-batch partials (sum over batch for
    the u cotangent), ``dh0`` (B, H, Dh, Dh) float32.
    """
    b, h, t, dh = r.shape
    validate_divisible("T", t, chunk)
    n_chunks = t // chunk
    if s_hist.shape != (b, h, n_chunks, dh, dh):
        raise ValueError(
            f"s_hist shape {s_hist.shape} != {(b, h, n_chunks, dh, dh)}"
        )

    grid = (b, h, n_chunks)
    rev = reversed_chunk(n_chunks)
    rev_seq = pl.BlockSpec(
        (1, 1, chunk, dh), lambda bi, hi, si: (bi, hi, rev(si), 0)
    )
    rev_hist = pl.BlockSpec(
        (1, 1, 1, dh, dh), lambda bi, hi, si: (bi, hi, rev(si), 0, 0)
    )
    state_spec = pl.BlockSpec((1, 1, dh, dh), lambda bi, hi, si: (bi, hi, 0, 0))
    kernel = functools.partial(wkv_bwd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            rev_seq,   # r
            rev_seq,   # k
            rev_seq,   # v
            rev_seq,   # w
            pl.BlockSpec((1, dh), lambda bi, hi, si: (hi, 0)),  # u
            rev_hist,  # s_hist (entry state per chunk)
            rev_seq,   # d_out
            state_spec,  # d_s_out (reverse boundary constant)
        ],
        out_specs=(
            rev_seq,   # dr
            rev_seq,   # dk
            rev_seq,   # dv
            rev_seq,   # dw
            pl.BlockSpec((1, 1, dh), lambda bi, hi, si: (bi, hi, 0)),  # du
            state_spec,  # dh0
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, h, t, dh), r.dtype),
            jax.ShapeDtypeStruct((b, h, t, dh), k.dtype),
            jax.ShapeDtypeStruct((b, h, t, dh), v.dtype),
            jax.ShapeDtypeStruct((b, h, t, dh), w.dtype),
            jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),  # dS adjoint carry
            pltpu.VMEM((1, dh), jnp.float32),   # du accumulator
        ],
        interpret=interpret,
    )(r, k, v, w, u, s_hist, d_out, d_s_out)
