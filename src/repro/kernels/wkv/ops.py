"""Public op: fused WKV recurrence with automatic backend dispatch.

``use_kernel=None`` auto-selects (the ``elevator_scan`` convention): the
Pallas kernel on TPU, the jnp chunked reference elsewhere.  ``use_kernel``
is the escape hatch — ``False`` forces the jnp path (models on CPU),
``True`` forces the kernel (interpret mode off-TPU, for parity tests).
Both paths are differentiable through the same ``custom_vjp``
(:mod:`repro.kernels.wkv.vjp`): the kernel path pairs the forward elevator
sweep with the reverse VMEM-adjoint sweep (``bwd.py``), so auto mode is
safe under ``jax.grad`` — the kernel is the TPU default for training too,
not just inference.

Chunk policy: ``chunk`` is a *request*.  When it does not divide T the
dispatch picks the largest valid divisor and warns — never the old silent
``chunk = t`` rewrite, which could blow the decay-ratio exponent range for
long odd sequences (``wkv_chunked_ref`` itself now raises instead).  The
warning fires once per distinct ``(T, chunk)`` pair: dispatch runs at
trace time under the model's outer jit, and a per-retrace warning is pure
log spam.

Decode dispatch: ``decode=True`` marks a *stateful serving* call (the
model threads it from ``decode_step``).  Windows up to
:data:`~repro.kernels.wkv.decode.DECODE_WINDOW_MAX` tokens take the
persistent-state decode kernels (:mod:`repro.kernels.wkv.decode`): S is
read from HBM once and written once per window, intermediate states ride
a VMEM carry, and there is no chunk-divisibility constraint (a decode
window has no chunk structure).  Longer stateful sweeps — e.g. filling
the cache from a long prompt — fall through to the chunked elevator
kernel, where the intra-chunk score matmuls amortize on the MXU.
``decode=None`` (the default) infers ``t == 1``, so plain single-token
calls hit the decode path with no caller change.
"""

from __future__ import annotations

import contextlib
import warnings

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    interpret_default,
    largest_divisor_chunk,
    on_tpu,
    register_kernel_resources,
    KernelResources,
)
from repro.kernels.wkv.decode import DECODE_WINDOW_MAX, wkv_decode_diff
from repro.kernels.wkv.ref import wkv_sequential_ref
from repro.kernels.wkv.vjp import wkv_diff, wkv_diff_summary

# (T, chunk) pairs already warned about, keyed by warn scope — dedupes
# across retraces/calls *within* a scope, so two models (or two test
# cases) hitting the same awkward (T, chunk) each get their own warning.
# The old module-global flat set deduped across unrelated configs: the
# second model's chunk adjustment was silent for the whole process life.
_CHUNK_WARNED: dict[str | None, set[tuple[int, int]]] = {}

# Active scope stack (chunk_warning_scope); empty -> the None scope.
_WARN_SCOPE: list[str | None] = []


def reset_chunk_warnings(scope: str | None = None, *, all_scopes: bool = False):
    """Forget warned (T, chunk) pairs — one scope, or every scope."""
    if all_scopes:
        _CHUNK_WARNED.clear()
    else:
        _CHUNK_WARNED.pop(scope, None)


@contextlib.contextmanager
def chunk_warning_scope(tag: str | None):
    """Scope chunk-adjustment warnings to ``tag`` for the duration —
    model code wraps its dispatch so each config warns independently."""
    _WARN_SCOPE.append(tag)
    try:
        yield
    finally:
        _WARN_SCOPE.pop()


def resolve_chunk(t: int, chunk: int, *, scope: str | None = None) -> int:
    """Largest divisor of ``t`` no larger than ``chunk``; warns on adjust
    (once per distinct ``(t, chunk)`` per warn scope)."""
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    c = largest_divisor_chunk(t, chunk)
    if c != min(chunk, t):
        key = scope if scope is not None else (
            _WARN_SCOPE[-1] if _WARN_SCOPE else None
        )
        seen = _CHUNK_WARNED.setdefault(key, set())
        if (t, chunk) not in seen:
            seen.add((t, chunk))
            warnings.warn(
                f"wkv chunk={chunk} does not divide T={t}; using chunk={c}",
                stacklevel=3,
            )
    return c


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def wkv_fused(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array | None = None,
    *,
    chunk: int = 64,
    use_kernel: bool | None = None,
    decode: bool | None = None,
    warn_scope: str | None = None,
):
    """RWKV6 WKV:  S_t = diag(w_t) S_{t-1} + k_t^T v_t;
    o_t = r_t · (S_{t-1} + u k_t^T v_t).

    r/k/v/w: (B, H, T, Dh); u: (H, Dh); h0: (B, H, Dh, Dh) or None (zeros).
    Returns ``(out, S_out)`` with ``out`` (B,H,T,Dh) in ``r.dtype`` and
    ``S_out`` (B,H,Dh,Dh) in float32.  Differentiable on every path.

    ``decode`` marks a stateful serving call (see module docstring):
    windows of at most ``DECODE_WINDOW_MAX`` tokens take the
    persistent-state decode kernels; ``None`` infers ``t == 1``.

    bf16 I/O: r/k/v/w may arrive in bf16 (or any float dtype) — no
    caller-side upcast needed.  Every backend accumulates in float32
    internally and ``out`` comes back in the input dtype, so feeding bf16
    halves the unavoidable HBM traffic without touching the recurrence
    math (see ``cost_model.wkv_traffic``'s ``io`` term).
    """
    b, h, t, dh = r.shape
    if h0 is None:
        h0 = jnp.zeros((b, h, dh, dh), jnp.float32)

    kernel = on_tpu() if use_kernel is None else use_kernel
    if decode is None:
        decode = t == 1
    if decode and t <= DECODE_WINDOW_MAX:
        if kernel:
            # Persistent-state decode kernel: one HBM round-trip of S per
            # window, VMEM carry between the window's tokens.
            return wkv_decode_diff(interpret_default(), True, r, k, v, w, u, h0)
        # jnp fallback: the sequential oracle is the cheapest form for a
        # short stateful window (no chunk structure to exploit), and
        # autodiff through a few steps is trivial.
        out, s_out = wkv_sequential_ref(r, k, v, w, u, h0)
        return out.astype(r.dtype), s_out
    c = resolve_chunk(t, chunk, scope=warn_scope)
    return wkv_diff(c, interpret_default(), bool(kernel), r, k, v, w, u, h0)


def wkv_fused_summary(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    h0: jax.Array | None = None,
    *,
    chunk: int = 64,
    use_kernel: bool | None = None,
    warn_scope: str | None = None,
):
    """Like :func:`wkv_fused` but additionally returns ``a_seg`` (B, H, Dh)
    float32 — the segment decay product, i.e. the diag half of the
    ``(A, S)`` segment summary.

    This is the local building block of the sequence-parallel protocol
    (:mod:`repro.kernels.wkv.seqpar`): each device calls it on its shard
    with a zero entering state, then only ``(a_seg, S_out)`` — O(Dh²) per
    (batch, head) — crosses the mesh axis.  Dispatch/chunk policy and
    differentiability match :func:`wkv_fused` (the ``a_seg`` cotangent
    folds into ``dw`` in closed form, see ``vjp.wkv_diff_summary``).
    """
    b, h, t, dh = r.shape
    if h0 is None:
        h0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    kernel = on_tpu() if use_kernel is None else use_kernel
    c = resolve_chunk(t, chunk, scope=warn_scope)
    return wkv_diff_summary(
        c, interpret_default(), bool(kernel), r, k, v, w, u, h0
    )


# --------------------------------------------------------------------------
# Static resource declarations (repro.analysis.resources)
# --------------------------------------------------------------------------

_WKV_DH = 64  # RWKV6 head-dim convention (model.recurrent.RWKV_HEAD_DIM)


def _wkv_geometry(cfg, t: int, chunk: int):
    import jax.numpy as jnp

    if cfg.d_model % _WKV_DH:
        raise ValueError(
            f"{cfg.name}: d_model={cfg.d_model} not divisible by the WKV "
            f"head dim {_WKV_DH}"
        )
    h = cfg.d_model // _WKV_DH
    c = resolve_chunk(t, chunk)
    isz = jnp.dtype(cfg.dtype).itemsize
    return h, c, isz


@register_kernel_resources("wkv.fwd")
def _wkv_fwd_resources(cfg, *, t: int = 4096, chunk: int = 64):
    """Chunked forward elevator sweep (inference: no state history)."""
    if "rwkv" not in tuple(cfg.pattern):
        return None
    dh = _WKV_DH
    h, c, isz = _wkv_geometry(cfg, t, chunk)
    seq = (1, 1, c, dh)
    state = (1, 1, dh, dh)
    return KernelResources(
        kernel="wkv.fwd",
        location="src/repro/kernels/wkv/kernel.py:_wkv_pallas_call",
        grid=(1, h, t // c),
        blocks=(
            ("r", seq, isz), ("k", seq, isz), ("v", seq, isz),
            ("w", seq, isz), ("u", (1, dh), isz), ("h0", state, 4),
            ("out", seq, isz), ("s_out", state, 4),
        ),
        scratch=(("S", (dh, dh), 4),),
    )


@register_kernel_resources("wkv.train")
def _wkv_train_resources(cfg, *, t: int = 4096, chunk: int = 64):
    """Forward sweep with the per-chunk state history the VJP replays."""
    base = _wkv_fwd_resources(cfg, t=t, chunk=chunk)
    if base is None:
        return None
    dh = _WKV_DH
    return KernelResources(
        kernel="wkv.train",
        location="src/repro/kernels/wkv/kernel.py:_wkv_pallas_call",
        grid=base.grid,
        blocks=base.blocks + (("s_hist", (1, 1, 1, dh, dh), 4),),
        scratch=base.scratch,
    )


@register_kernel_resources("wkv.decode_window")
def _wkv_decode_resources(cfg, *, window: int = DECODE_WINDOW_MAX):
    """Persistent-state decode window: S rides VMEM across the window."""
    if "rwkv" not in tuple(cfg.pattern):
        return None
    import jax.numpy as jnp

    if cfg.d_model % _WKV_DH:
        raise ValueError(
            f"{cfg.name}: d_model={cfg.d_model} not divisible by the WKV "
            f"head dim {_WKV_DH}"
        )
    dh = _WKV_DH
    h = cfg.d_model // dh
    isz = jnp.dtype(cfg.dtype).itemsize
    seq = (1, 1, 1, dh)
    state = (1, 1, dh, dh)
    return KernelResources(
        kernel="wkv.decode_window",
        location="src/repro/kernels/wkv/decode.py:wkv_decode_window_pallas",
        grid=(1, h, window),
        blocks=(
            ("r", seq, isz), ("k", seq, isz), ("v", seq, isz),
            ("w", seq, isz), ("u", (1, dh), isz), ("h0", state, 4),
            ("out", seq, isz), ("s_out", state, 4),
        ),
        scratch=(("S", (dh, dh), 4),),
    )
