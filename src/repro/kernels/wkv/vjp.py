"""custom_vjp assembly: differentiable WKV through either backend.

``wkv_diff(chunk, interpret, use_pallas)(r, k, v, w, u, h0)`` is the
differentiable core behind :func:`repro.kernels.wkv.ops.wkv_fused`:

* **forward** — the Pallas elevator kernel (``use_pallas=True``) or the
  jnp chunked reference.  Under ``jax.grad`` the Pallas path runs the
  training variant (:func:`~repro.kernels.wkv.kernel.wkv_pallas_train`),
  whose only extra output is ``s_hist``, the chunk-entry states;
* **backward** — the reverse elevator sweep
  (:func:`~repro.kernels.wkv.bwd.wkv_pallas_bwd`) carrying the (Dh × Dh)
  adjoint state in VMEM, or its jnp oracle
  (:func:`~repro.kernels.wkv.ref.wkv_chunked_bwd_ref`).

Both backward paths follow recompute-over-stage: residuals are the primal
inputs (plus ``s_hist`` on the kernel path); the decay tensors and score
matrices that ``jax.grad`` of the chunked reference would save and
round-trip through HBM are recomputed at use.  This is what lets
``apply_rwkv_block`` keep the kernel as the TPU default during training
instead of falling back to the staged autodiff path.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.wkv.bwd import wkv_pallas_bwd
from repro.kernels.wkv.kernel import wkv_pallas, wkv_pallas_train
from repro.kernels.wkv.ref import wkv_chunked_bwd_ref, wkv_chunked_ref

__all__ = ["wkv_diff"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def wkv_diff(chunk, interpret, use_pallas, r, k, v, w, u, h0):
    """Differentiable fused WKV.  Returns ``(out, S_out)`` like
    :func:`~repro.kernels.wkv.kernel.wkv_pallas` (out in ``r.dtype``,
    ``S_out`` float32)."""
    if use_pallas:
        return wkv_pallas(r, k, v, w, u, h0, chunk=chunk, interpret=interpret)
    out, s_out = wkv_chunked_ref(r, k, v, w, u, h0, chunk=chunk)
    return out.astype(r.dtype), s_out


def _wkv_diff_fwd(chunk, interpret, use_pallas, r, k, v, w, u, h0):
    if use_pallas:
        out, s_out, s_hist = wkv_pallas_train(
            r, k, v, w, u, h0, chunk=chunk, interpret=interpret
        )
    else:
        out, s_out = wkv_chunked_ref(r, k, v, w, u, h0, chunk=chunk)
        out = out.astype(r.dtype)
        s_hist = None  # jnp backward recomputes entry states from h0
    return (out, s_out), (r, k, v, w, u, h0, s_hist)


def _wkv_diff_bwd(chunk, interpret, use_pallas, res, cts):
    r, k, v, w, u, h0, s_hist = res
    d_out, d_s_out = cts
    if use_pallas:
        dr, dk, dv, dw, du_part, dh0 = wkv_pallas_bwd(
            r, k, v, w, u, s_hist, d_out, d_s_out,
            chunk=chunk, interpret=interpret,
        )
        du = du_part.sum(axis=0)
    else:
        dr, dk, dv, dw, du, dh0 = wkv_chunked_bwd_ref(
            r, k, v, w, u, h0, d_out, d_s_out, chunk=chunk
        )
    return (
        dr.astype(r.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        dw.astype(w.dtype),
        du.astype(u.dtype),
        dh0.astype(h0.dtype),
    )


wkv_diff.defvjp(_wkv_diff_fwd, _wkv_diff_bwd)
