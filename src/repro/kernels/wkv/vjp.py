"""custom_vjp assembly: differentiable WKV through either backend.

``wkv_diff(chunk, interpret, use_pallas)(r, k, v, w, u, h0)`` is the
differentiable core behind :func:`repro.kernels.wkv.ops.wkv_fused`:

* **forward** — the Pallas elevator kernel (``use_pallas=True``) or the
  jnp chunked reference.  Under ``jax.grad`` the Pallas path runs the
  training variant (:func:`~repro.kernels.wkv.kernel.wkv_pallas_train`),
  whose only extra output is ``s_hist``, the chunk-entry states;
* **backward** — the reverse elevator sweep
  (:func:`~repro.kernels.wkv.bwd.wkv_pallas_bwd`) carrying the (Dh × Dh)
  adjoint state in VMEM, or its jnp oracle
  (:func:`~repro.kernels.wkv.ref.wkv_chunked_bwd_ref`).

``wkv_diff_summary`` is the segment-summary twin used by the
sequence-parallel protocol (:mod:`repro.kernels.wkv.seqpar`): its forward
additionally returns the segment decay product ``a_seg`` (B, H, Dh), and
its backward folds the ``a_seg`` cotangent into ``dw`` in closed form —
``a_seg = exp(Σ_t log w_t)`` means ``∂a/∂w_t = a_seg / w_t`` for every in-
range ``t``, one elementwise term on top of the shared reverse sweep.  The
``d_a`` cotangent is exactly what flows back through the device-space
carry composition (ppermute transposes) during sequence-sharded training.

Both backward paths follow recompute-over-stage: residuals are the primal
inputs (plus ``s_hist`` on the kernel path); the decay tensors and score
matrices that ``jax.grad`` of the chunked reference would save and
round-trip through HBM are recomputed at use.  This is what lets
``apply_rwkv_block`` keep the kernel as the TPU default during training
instead of falling back to the staged autodiff path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv.bwd import wkv_pallas_bwd
from repro.kernels.wkv.kernel import (
    wkv_pallas,
    wkv_pallas_summary,
    wkv_pallas_train,
    wkv_pallas_train_summary,
)
from repro.kernels.wkv.ref import (
    wkv_chunked_bwd_ref,
    wkv_chunked_ref,
    wkv_segment_decay,
)

__all__ = ["wkv_diff", "wkv_diff_summary"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def wkv_diff(chunk, interpret, use_pallas, r, k, v, w, u, h0):
    """Differentiable fused WKV.  Returns ``(out, S_out)`` like
    :func:`~repro.kernels.wkv.kernel.wkv_pallas` (out in ``r.dtype``,
    ``S_out`` float32)."""
    if use_pallas:
        return wkv_pallas(r, k, v, w, u, h0, chunk=chunk, interpret=interpret)
    out, s_out = wkv_chunked_ref(r, k, v, w, u, h0, chunk=chunk)
    return out.astype(r.dtype), s_out


def _wkv_diff_fwd(chunk, interpret, use_pallas, r, k, v, w, u, h0):
    if use_pallas:
        out, s_out, s_hist = wkv_pallas_train(
            r, k, v, w, u, h0, chunk=chunk, interpret=interpret
        )
    else:
        out, s_out = wkv_chunked_ref(r, k, v, w, u, h0, chunk=chunk)
        out = out.astype(r.dtype)
        s_hist = None  # jnp backward recomputes entry states from h0
    return (out, s_out), (r, k, v, w, u, h0, s_hist)


def _base_bwd(chunk, interpret, use_pallas, res, d_out, d_s_out):
    """Shared reverse sweep for both custom_vjps; float32 cotangents."""
    r, k, v, w, u, h0, s_hist = res
    if use_pallas:
        dr, dk, dv, dw, du_part, dh0 = wkv_pallas_bwd(
            r, k, v, w, u, s_hist, d_out, d_s_out,
            chunk=chunk, interpret=interpret,
        )
        du = du_part.sum(axis=0)
    else:
        dr, dk, dv, dw, du, dh0 = wkv_chunked_bwd_ref(
            r, k, v, w, u, h0, d_out, d_s_out, chunk=chunk
        )
    return dr, dk, dv, dw, du, dh0


def _cast_grads(res, grads):
    r, k, v, w, u, h0 = res[:6]
    dr, dk, dv, dw, du, dh0 = grads
    return (
        dr.astype(r.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        dw.astype(w.dtype),
        du.astype(u.dtype),
        dh0.astype(h0.dtype),
    )


def _wkv_diff_bwd(chunk, interpret, use_pallas, res, cts):
    d_out, d_s_out = cts
    grads = _base_bwd(chunk, interpret, use_pallas, res, d_out, d_s_out)
    return _cast_grads(res, grads)


wkv_diff.defvjp(_wkv_diff_fwd, _wkv_diff_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def wkv_diff_summary(chunk, interpret, use_pallas, r, k, v, w, u, h0):
    """Differentiable fused WKV with the segment summary: returns
    ``(out, S_out, a_seg)`` — ``a_seg`` (B, H, Dh) f32 is the segment
    decay product (see :func:`~repro.kernels.wkv.ref.wkv_segment_decay`).
    ``(a_seg, S_out)`` is the (decay, state) pair the sequence-parallel
    carry composes across the mesh axis."""
    if use_pallas:
        return wkv_pallas_summary(
            r, k, v, w, u, h0, chunk=chunk, interpret=interpret
        )
    out, s_out = wkv_chunked_ref(r, k, v, w, u, h0, chunk=chunk)
    return out.astype(r.dtype), s_out, wkv_segment_decay(w)


def _wkv_diff_summary_fwd(chunk, interpret, use_pallas, r, k, v, w, u, h0):
    if use_pallas:
        out, s_out, s_hist, a_seg = wkv_pallas_train_summary(
            r, k, v, w, u, h0, chunk=chunk, interpret=interpret
        )
    else:
        out, s_out = wkv_chunked_ref(r, k, v, w, u, h0, chunk=chunk)
        out = out.astype(r.dtype)
        s_hist = None
        a_seg = wkv_segment_decay(w)
    return (out, s_out, a_seg), (r, k, v, w, u, h0, s_hist)


def _wkv_diff_summary_bwd(chunk, interpret, use_pallas, res, cts):
    d_out, d_s_out, d_a = cts
    dr, dk, dv, dw, du, dh0 = _base_bwd(
        chunk, interpret, use_pallas, res, d_out, d_s_out
    )
    # a_seg cotangent: a_seg = exp(Σ_t logw_t) ⇒ dlogw_t += d_a ⊙ a_seg for
    # every t, and dw_t += dlogw_t / w_t on the in-range (unclipped) steps.
    # Recomputed from the primal w — no extra residual.
    w32 = res[3].astype(jnp.float32)
    a_seg = wkv_segment_decay(res[3])
    in_range = (w32 >= 1e-8) & (w32 <= 1.0)
    dw = dw + jnp.where(
        in_range, (d_a * a_seg)[:, :, None, :] / jnp.clip(w32, 1e-8, 1.0), 0.0
    )
    return _cast_grads(res, (dr, dk, dv, dw, du, dh0))


wkv_diff_summary.defvjp(_wkv_diff_summary_fwd, _wkv_diff_summary_bwd)
