"""Pure-jnp oracles for the fused WKV kernel.

The RWKV6 (Finch) WKV recurrence, per head with ``Dh``-dim keys/values:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S is Dh x Dh)
    o_t = r_t · (S_{t-1} + u k_t^T v_t)

* :func:`wkv_sequential_ref` — O(T) sequential scan, the ground-truth
  oracle for tests.
* :func:`wkv_chunked_ref` — the decay-ratio chunked form (two einsums per
  chunk + a ``lax.scan`` carry over chunk space).  Mathematically the
  schedule the Pallas kernel fuses, but staged through HBM: the six
  per-chunk decay tensors (logw, cum_incl, cum_excl, r_dec, k_inv, k_rem),
  the masked score matrix and the scan carry all materialize — the paper's
  Fig. 1b scratchpad pattern.  Kept as the
  dispatch fallback for non-TPU backends and as a second oracle.

Unlike the pre-kernel ``_wkv_chunked`` this raises on ``t % chunk != 0``
instead of silently rewriting ``chunk = t``; the dispatch layer
(:mod:`repro.kernels.wkv.ops`) picks the largest valid divisor explicitly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lowering import scan_unroll
from repro.kernels.common import validate_divisible


def wkv_sequential_ref(r, k, v, w, u, h0):
    """O(T) sequential oracle.  All of r/k/v/w: (B, H, T, Dh); u: (H, Dh);
    h0: (B, H, Dh, Dh).  Returns (out (B,H,T,Dh) f32, S_out (B,H,Dh,Dh) f32).
    """
    b, h, t, dh = r.shape

    def step(S, inputs):
        rt, kt, vt, wt = inputs
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        out = jnp.einsum("bhd,bhde->bhe", rt, S + u.reshape(1, h, dh, 1) * kv)
        S = S * wt[..., None] + kv
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 2, 0) for a in (r, k, v, w))
    S, outs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 2), S


def wkv_chunked_ref(r, k, v, w, u, h0, chunk: int, stage=None):
    """Chunked WKV (decay-ratio trick).  Same signature/returns as
    :func:`wkv_sequential_ref` plus the static ``chunk``; ``chunk`` must
    divide T exactly (no silent fallback — see module docstring).

    ``stage`` is an identity hook applied to every per-chunk intermediate
    (default: no-op).  Benchmarks pass
    :func:`repro.core.scratchpad.stage_through_memory` to materialize the
    Fig. 1b scratchpad staging this math implies, keeping the staged
    baseline and the oracle one implementation.
    """
    if stage is None:
        stage = lambda x: x  # noqa: E731
    b, h, t, dh = r.shape
    validate_divisible("T", t, chunk)
    n = t // chunk
    rc = r.reshape(b, h, n, chunk, dh).astype(jnp.float32)
    kc = k.reshape(b, h, n, chunk, dh).astype(jnp.float32)
    vc = v.reshape(b, h, n, chunk, dh).astype(jnp.float32)
    wc = w.reshape(b, h, n, chunk, dh).astype(jnp.float32)

    logw = stage(jnp.log(jnp.clip(wc, 1e-8, 1.0)))
    # cum_excl[t] = sum_{s<t} log w_s  (decay applied to the entering state).
    cum_incl = stage(jnp.cumsum(logw, axis=3))
    cum_excl = stage(cum_incl - logw)
    # w_total = prod over the chunk.
    w_total = jnp.exp(cum_incl[:, :, :, -1])                  # (B,H,N,Dh)

    r_dec = stage(rc * jnp.exp(cum_excl))                     # r_t * D_{<t}
    k_inv = stage(kc * jnp.exp(-cum_incl))                    # k_s / D_{<=s}
    k_rem = stage(kc * jnp.exp(cum_incl[:, :, :, -1:] - cum_incl))  # k_s * D_{(s..L]}

    # Intra-chunk pair scores: A[t,s] = (r_t D_{<t}) · (k_s / D_{<=s}), s < t.
    scores = jnp.einsum("bhntd,bhnsd->bhnts", r_dec, k_inv)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = stage(jnp.where(mask, scores, 0.0))
    u_b = u.reshape(1, h, 1, 1, dh)
    bonus = jnp.einsum("bhntd,bhntd->bhnt", rc * u_b, kc)     # u-weighted diag
    intra = jnp.einsum("bhnts,bhnsd->bhntd", scores, vc)
    intra = stage(intra + bonus[..., None] * vc)

    def chunk_step(S, inputs):
        r_d, k_r, v_, wt = inputs                             # (B,H,chunk,Dh)...
        inter = jnp.einsum("bhtd,bhde->bhte", r_d, S)
        S_new = stage(S * wt[..., None] + jnp.einsum("bhtd,bhte->bhde", k_r, v_))
        return S_new, inter

    per_chunk = (
        jnp.moveaxis(r_dec, 2, 0),
        jnp.moveaxis(k_rem, 2, 0),
        jnp.moveaxis(vc, 2, 0),
        jnp.moveaxis(w_total, 2, 0),
    )
    S_out, inter = jax.lax.scan(
        chunk_step, h0.astype(jnp.float32), per_chunk, unroll=scan_unroll()
    )
    inter = jnp.moveaxis(inter, 0, 2)                         # (B,H,N,chunk,Dh)

    out = (intra + inter).reshape(b, h, t, dh)
    return out, S_out
