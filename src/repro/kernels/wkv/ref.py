"""Pure-jnp oracles for the fused WKV kernel.

The RWKV6 (Finch) WKV recurrence, per head with ``Dh``-dim keys/values:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S is Dh x Dh)
    o_t = r_t · (S_{t-1} + u k_t^T v_t)

* :func:`wkv_sequential_ref` — O(T) sequential scan, the ground-truth
  oracle for tests.
* :func:`wkv_chunked_ref` — the decay-ratio chunked form (two einsums per
  chunk + a ``lax.scan`` carry over chunk space).  Mathematically the
  schedule the Pallas kernel fuses, but staged through HBM: the six
  per-chunk decay tensors (logw, cum_incl, cum_excl, r_dec, k_inv, k_rem),
  the masked score matrix and the scan carry all materialize — the paper's
  Fig. 1b scratchpad pattern.  Kept as the
  dispatch fallback for non-TPU backends and as a second oracle.
* :func:`wkv_segment_decay` / :func:`wkv_entry_correction` — the jnp side
  of the per-segment summary protocol: the decay product ``A_seg`` and the
  linear contribution of an entering state to a segment's outputs.  Used
  by the sequence-parallel path (``seqpar.py``) on the jnp backend (the
  Pallas path emits ``A_seg`` from the kernel itself).
* :func:`wkv_chunked_bwd_ref` — the hand-derived chunked *backward* sweep:
  the math the reverse Pallas kernel (``bwd.py``) fuses, in plain jnp.
  Recomputes the per-chunk decays and entry states from the primals
  (recompute-over-stage: the only saved values are the inputs), then walks
  chunks back-to-front carrying the (Dh × Dh) adjoint state ``dS``.
  Oracle for the kernel VJP and the manual backward of the jnp dispatch
  path — validated against ``jax.grad`` of :func:`wkv_sequential_ref`.

Unlike the pre-kernel ``_wkv_chunked`` this raises on ``t % chunk != 0``
instead of silently rewriting ``chunk = t``; the dispatch layer
(:mod:`repro.kernels.wkv.ops`) picks the largest valid divisor explicitly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lowering import scan_unroll
from repro.kernels.common import validate_divisible


def wkv_sequential_ref(r, k, v, w, u, h0):
    """O(T) sequential oracle.  All of r/k/v/w: (B, H, T, Dh); u: (H, Dh);
    h0: (B, H, Dh, Dh).  Returns (out (B,H,T,Dh) f32, S_out (B,H,Dh,Dh) f32).
    """
    b, h, t, dh = r.shape

    def step(S, inputs):
        rt, kt, vt, wt = inputs
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        out = jnp.einsum("bhd,bhde->bhe", rt, S + u.reshape(1, h, dh, 1) * kv)
        S = S * wt[..., None] + kv
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 2, 0) for a in (r, k, v, w))
    S, outs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 2), S


def wkv_chunked_ref(r, k, v, w, u, h0, chunk: int, stage=None):
    """Chunked WKV (decay-ratio trick).  Same signature/returns as
    :func:`wkv_sequential_ref` plus the static ``chunk``; ``chunk`` must
    divide T exactly (no silent fallback — see module docstring).

    ``stage`` is an identity hook applied to every per-chunk intermediate
    (default: no-op).  Benchmarks pass
    :func:`repro.core.scratchpad.stage_through_memory` to materialize the
    Fig. 1b scratchpad staging this math implies, keeping the staged
    baseline and the oracle one implementation.
    """
    if stage is None:
        stage = lambda x: x  # noqa: E731
    b, h, t, dh = r.shape
    validate_divisible("T", t, chunk)
    n = t // chunk
    rc = r.reshape(b, h, n, chunk, dh).astype(jnp.float32)
    kc = k.reshape(b, h, n, chunk, dh).astype(jnp.float32)
    vc = v.reshape(b, h, n, chunk, dh).astype(jnp.float32)
    wc = w.reshape(b, h, n, chunk, dh).astype(jnp.float32)

    logw = stage(jnp.log(jnp.clip(wc, 1e-8, 1.0)))
    # cum_excl[t] = sum_{s<t} log w_s  (decay applied to the entering state).
    cum_incl = stage(jnp.cumsum(logw, axis=3))
    cum_excl = stage(cum_incl - logw)
    # w_total = prod over the chunk.
    w_total = jnp.exp(cum_incl[:, :, :, -1])                  # (B,H,N,Dh)

    r_dec = stage(rc * jnp.exp(cum_excl))                     # r_t * D_{<t}
    k_inv = stage(kc * jnp.exp(-cum_incl))                    # k_s / D_{<=s}
    k_rem = stage(kc * jnp.exp(cum_incl[:, :, :, -1:] - cum_incl))  # k_s * D_{(s..L]}

    # Intra-chunk pair scores: A[t,s] = (r_t D_{<t}) · (k_s / D_{<=s}), s < t.
    scores = jnp.einsum("bhntd,bhnsd->bhnts", r_dec, k_inv)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = stage(jnp.where(mask, scores, 0.0))
    u_b = u.reshape(1, h, 1, 1, dh)
    bonus = jnp.einsum("bhntd,bhntd->bhnt", rc * u_b, kc)     # u-weighted diag
    intra = jnp.einsum("bhnts,bhnsd->bhntd", scores, vc)
    intra = stage(intra + bonus[..., None] * vc)

    def chunk_step(S, inputs):
        r_d, k_r, v_, wt = inputs                             # (B,H,chunk,Dh)...
        inter = jnp.einsum("bhtd,bhde->bhte", r_d, S)
        S_new = stage(S * wt[..., None] + jnp.einsum("bhtd,bhte->bhde", k_r, v_))
        return S_new, inter

    per_chunk = (
        jnp.moveaxis(r_dec, 2, 0),
        jnp.moveaxis(k_rem, 2, 0),
        jnp.moveaxis(vc, 2, 0),
        jnp.moveaxis(w_total, 2, 0),
    )
    S_out, inter = jax.lax.scan(
        chunk_step, h0.astype(jnp.float32), per_chunk, unroll=scan_unroll()
    )
    inter = jnp.moveaxis(inter, 0, 2)                         # (B,H,N,chunk,Dh)

    out = (intra + inter).reshape(b, h, t, dh)
    return out, S_out


def wkv_segment_decay(w):
    """Segment decay product ``A_seg`` (B, H, Dh): the diag-decay half of
    the (A, S) segment summary.

    ``S_exit = A_seg[..., None] * S_enter + S_exit_from_zero`` — the
    DIAG_STATE monoid action (:mod:`repro.core.chunk_scan`).  Uses the same
    decay clip as the kernels so summaries composed across devices match
    the fused sweep exactly.
    """
    logw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-8, 1.0))
    return jnp.exp(jnp.sum(logw, axis=2))


def wkv_entry_correction(r, w, s_in):
    """Contribution of a segment's *entering* state to every output token.

    ``o_t`` depends linearly on the entering state: ``o_t += (r_t ⊙ D_{<t})
    @ S_in`` with ``D_{<t}`` the decay product over the segment's earlier
    tokens.  The sequence-parallel path runs the fused kernel with a zero
    entry, composes the (A, S) summaries across the mesh to obtain
    ``s_in`` (B, H, Dh, Dh), and adds this term — only the O(Dh²) summary
    ever crossed the axis.  Exponents here are ≤ 0 (pure decays), so long
    segments underflow toward 0 instead of overflowing.
    """
    f32 = jnp.float32
    logw = jnp.log(jnp.clip(w.astype(f32), 1e-8, 1.0))
    cum_excl = jnp.cumsum(logw, axis=2) - logw
    r_dec = r.astype(f32) * jnp.exp(cum_excl)
    return jnp.einsum("bhtd,bhde->bhte", r_dec, s_in.astype(f32))


def wkv_chunked_bwd_ref(r, k, v, w, u, h0, d_out, d_s_out, chunk: int):
    """Chunked WKV backward: cotangents for (r, k, v, w, u, h0).

    Inputs are the forward primals plus the output cotangents ``d_out``
    (B,H,T,Dh) and ``d_s_out`` (B,H,Dh,Dh).  Returns
    ``(dr, dk, dv, dw, du, dh0)`` in float32 with primal shapes.

    Derivation (per chunk of length L, local time t, entering state S):

        o_t    = (r_t D_{<t}) · S  +  Σ_{s<t} A[t,s] v_s  +  (r_t·u k_t) v_t
        S_exit = diag(W) S + k_rem^T V,   W = D_{<=L-1}

    so with ``G`` the adjoint of this chunk's exit state, the adjoint of
    the *entering* state is ``diag(W) G + r_dec^T do`` — the reverse
    recurrence the back-to-front sweep carries.  All decay tensors are
    recomputed from the primals; the entry states come from a cheap
    forward pre-pass over chunk summaries (one rank-L update per chunk).
    The ``w`` gradient flows through the cumulative log-decays: adjoints
    of ``cumsum`` chains are *suffix* sums (``rev_cumsum``), the reverse
    twin of the forward's prefix sums.
    """
    b, h, t, dh = r.shape
    validate_divisible("T", t, chunk)
    n = t // chunk
    f32 = jnp.float32
    rc = r.reshape(b, h, n, chunk, dh).astype(f32)
    kc = k.reshape(b, h, n, chunk, dh).astype(f32)
    vc = v.reshape(b, h, n, chunk, dh).astype(f32)
    wc = w.reshape(b, h, n, chunk, dh).astype(f32)
    do = d_out.reshape(b, h, n, chunk, dh).astype(f32)
    dS_out = d_s_out.astype(f32)

    logw = jnp.log(jnp.clip(wc, 1e-8, 1.0))
    cum_incl = jnp.cumsum(logw, axis=3)
    cum_excl = cum_incl - logw
    w_total = jnp.exp(cum_incl[:, :, :, -1])                  # (B,H,N,Dh)
    r_dec = rc * jnp.exp(cum_excl)
    k_inv = kc * jnp.exp(-cum_incl)
    k_rem = kc * jnp.exp(cum_incl[:, :, :, -1:] - cum_incl)

    # Forward pre-pass: recompute the state *entering* each chunk.
    def fstep(S, inp):
        k_r, v_, wt = inp
        S_new = S * wt[..., None] + jnp.einsum("bhtd,bhte->bhde", k_r, v_)
        return S_new, S

    _, S_e = jax.lax.scan(
        fstep, h0.astype(f32),
        (jnp.moveaxis(k_rem, 2, 0), jnp.moveaxis(vc, 2, 0),
         jnp.moveaxis(w_total, 2, 0)),
        unroll=scan_unroll(),
    )
    S_e = jnp.moveaxis(S_e, 0, 2)                              # (B,H,N,Dh,Dh)

    # Reverse sweep: G[c] = adjoint of chunk c's exit state.
    def bstep(dS, inp):
        wt, r_d, do_ = inp
        dS_prev = dS * wt[..., None] + jnp.einsum("bhtd,bhte->bhde", r_d, do_)
        return dS_prev, dS

    rev = lambda a: jnp.flip(jnp.moveaxis(a, 2, 0), 0)  # noqa: E731
    dh0, G_rev = jax.lax.scan(
        bstep, dS_out, (rev(w_total), rev(r_dec), rev(do)),
        unroll=scan_unroll(),
    )
    G = jnp.moveaxis(jnp.flip(G_rev, 0), 0, 2)                 # (B,H,N,Dh,Dh)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(mask, jnp.einsum("bhntd,bhnsd->bhnts", r_dec, k_inv), 0.0)
    dscores = jnp.where(mask, jnp.einsum("bhnte,bhnse->bhnts", do, vc), 0.0)

    u_b = u.reshape(1, h, 1, 1, dh).astype(f32)
    dov = jnp.sum(do * vc, axis=-1, keepdims=True)             # (B,H,N,L,1)

    d_rdec = (jnp.einsum("bhnts,bhnsd->bhntd", dscores, k_inv)
              + jnp.einsum("bhnte,bhnde->bhntd", do, S_e))
    d_kinv = jnp.einsum("bhnts,bhntd->bhnsd", dscores, r_dec)
    d_krem = jnp.einsum("bhnse,bhnde->bhnsd", vc, G)

    dr = d_rdec * jnp.exp(cum_excl) + u_b * kc * dov
    dk = (d_kinv * jnp.exp(-cum_incl)
          + d_krem * jnp.exp(cum_incl[:, :, :, -1:] - cum_incl)
          + rc * u_b * dov)
    dv = (jnp.einsum("bhnts,bhnte->bhnse", scores, do)
          + jnp.einsum("bhnsd,bhnde->bhnse", k_rem, G)
          + jnp.sum(rc * u_b * kc, axis=-1, keepdims=True) * do)

    # logw adjoint: every use of cum_incl/cum_excl folds back through
    # suffix sums (the adjoint of cumsum).  The cum_incl[-1] terms (k_rem's
    # numerator and w_total's use in the exit-state decay) land on the last
    # row before the suffix sum distributes them to every earlier step.
    dcum_excl = d_rdec * r_dec
    dcum_incl = -d_kinv * k_inv - d_krem * k_rem
    last = (jnp.sum(d_krem * k_rem, axis=3)
            + w_total * jnp.einsum("bhnde,bhnde->bhnd", S_e, G))
    dcum_incl = dcum_incl.at[:, :, :, -1].add(last)
    rev_incl = jnp.flip(jnp.cumsum(jnp.flip(dcum_incl, 3), axis=3), 3)
    rev_excl = jnp.flip(jnp.cumsum(jnp.flip(dcum_excl, 3), axis=3), 3) - dcum_excl
    dlogw = rev_incl + rev_excl
    in_range = (wc >= 1e-8) & (wc <= 1.0)
    dw = jnp.where(in_range, dlogw / jnp.clip(wc, 1e-8, 1.0), 0.0)

    du = jnp.einsum("bhntd,bhntd,bhnt->hd", rc, kc, dov[..., 0])

    rs = lambda a: a.reshape(b, h, t, dh)  # noqa: E731
    return rs(dr), rs(dk), rs(dv), rs(dw), du, dh0
