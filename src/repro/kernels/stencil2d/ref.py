"""Pure-jnp oracle for the 5-point stencil (hotspot/SRAD/pathfinder class)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stencil2d_ref(x: jax.Array, coeffs: jax.Array, boundary: float = 0.0) -> jax.Array:
    """out[i,j] = c0*x[i,j] + c1*x[i-1,j] + c2*x[i+1,j] + c3*x[i,j-1] + c4*x[i,j+1].

    x: (H, W); coeffs: (5,).  Out-of-grid neighbors read ``boundary``.
    """
    x32 = x.astype(jnp.float32)
    padded = jnp.pad(x32, 1, constant_values=boundary)
    up = padded[:-2, 1:-1]
    down = padded[2:, 1:-1]
    left = padded[1:-1, :-2]
    right = padded[1:-1, 2:]
    c = coeffs.astype(jnp.float32)
    out = c[0] * x32 + c[1] * up + c[2] * down + c[3] * left + c[4] * right
    return out.astype(x.dtype)
