"""Public op: 5-point stencil sweep (hotspot/SRAD building block)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.stencil2d.kernel import stencil2d_pallas
from repro.kernels.stencil2d.ref import stencil2d_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def stencil2d(x, coeffs, *, boundary: float = 0.0, use_kernel: bool | None = None):
    kernel = _on_tpu() if use_kernel is None else use_kernel
    if kernel:
        h = x.shape[0]
        block_h = 128
        while h % block_h:
            block_h //= 2
        return stencil2d_pallas(
            x, coeffs, block_h=block_h, boundary=boundary, interpret=not _on_tpu()
        )
    return stencil2d_ref(x, coeffs, boundary)
