"""Pallas TPU kernel: 5-point stencil with row-halo forwarding.

The hotspot/SRAD pattern from the paper's benchmark set (Table 3).  The
GPGPU version stages a (block+halo)² tile in shared memory behind a barrier;
here each row block is loaded from HBM once and the *halo rows* arrive as
additional BlockSpec views of the same array (index maps i-1 / i / i+1) —
the Mosaic pipeline keeps them in VMEM, so the neighbor exchange is in-fabric
forwarding, not extra HBM traffic.  Column neighbors are VREG lane rotates.

Grid: (n_row_blocks,).  Block = (block_h, W); boundary handled by clamped
index maps + positional masks (the elevator constant).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def stencil2d_kernel(
    prev_ref, cur_ref, next_ref, c_ref, out_ref, *, block_h: int, h: int, w: int,
    boundary: float,
):
    i = pl.program_id(0)
    n_blocks = pl.num_programs(0)

    cur = cur_ref[...].astype(jnp.float32)      # (block_h, w)
    prev = prev_ref[...].astype(jnp.float32)    # block above (clamped at 0)
    nxt = next_ref[...].astype(jnp.float32)     # block below (clamped at end)
    c = c_ref[...].astype(jnp.float32)          # (1, 8) padded coeff row
    bval = jnp.float32(boundary)

    # Row neighbors: shift within the block; the boundary rows take the
    # forwarded halo row from the neighboring block (elevator edge).
    up = jnp.concatenate([prev[-1:, :], cur[:-1, :]], axis=0)
    down = jnp.concatenate([cur[1:, :], nxt[:1, :]], axis=0)
    # Grid edges: no producer -> elevator constant.
    row_idx = i * block_h + jax.lax.broadcasted_iota(jnp.int32, cur.shape, 0)
    up = jnp.where(row_idx == 0, bval, up)
    down = jnp.where(row_idx == h - 1, bval, down)

    # Column neighbors: lane rotates with boundary fill.
    col_idx = jax.lax.broadcasted_iota(jnp.int32, cur.shape, 1)
    left = jnp.where(col_idx == 0, bval, jnp.roll(cur, 1, axis=1))
    right = jnp.where(col_idx == w - 1, bval, jnp.roll(cur, -1, axis=1))

    out = c[0, 0] * cur + c[0, 1] * up + c[0, 2] * down + c[0, 3] * left + c[0, 4] * right
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_h", "boundary", "interpret"))
def stencil2d_pallas(
    x: jax.Array,
    coeffs: jax.Array,
    *,
    block_h: int = 128,
    boundary: float = 0.0,
    interpret: bool = False,
) -> jax.Array:
    """x: (H, W) with H % block_h == 0; coeffs: (5,)."""
    h, w = x.shape
    if h % block_h:
        raise ValueError(f"H={h} not divisible by block_h={block_h}")
    n_blocks = h // block_h
    cpad = jnp.zeros((1, 8), coeffs.dtype).at[0, :5].set(coeffs)

    kernel = functools.partial(
        stencil2d_kernel, block_h=block_h, h=h, w=w, boundary=boundary
    )
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_h, w), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((block_h, w), lambda i: (i, 0)),
            pl.BlockSpec((block_h, w), lambda i: (jnp.minimum(i + 1, pl.num_programs(0) - 1), 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_h, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=interpret,
    )(x, x, x, cpad)
