"""Pure-jnp oracle for the token-shift kernel.

out[b, t, d] = sum_{k=0..K-1} w[k, d] * x[b, t-k, d]   (x[t<0] = 0)

A depthwise *causal* short convolution — the paper's 1D convolution
(Fig. 1) expressed as elevator shifts, and exactly the short-conv /
token-shift used by RecurrentGemma (width-4 conv1d) and RWKV (Δ=1 lerp).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def token_shift_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, T, D); w: (K, D) per-channel taps, tap k reads x[t-k]."""
    k, d = w.shape
    out = jnp.zeros_like(x, dtype=jnp.float32)
    x32 = x.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    for tap in range(k):
        shifted = jnp.pad(x32, ((0, 0), (tap, 0), (0, 0)))[:, : x.shape[1]]
        out = out + w32[tap] * shifted
    return out.astype(x.dtype)
