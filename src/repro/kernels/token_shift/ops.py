"""Public op: fused token-shift / short causal depthwise conv."""

from __future__ import annotations

import jax

from repro.kernels.common import interpret_default, on_tpu
from repro.kernels.token_shift.kernel import token_shift_pallas
from repro.kernels.token_shift.ref import token_shift_ref


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def token_shift(x: jax.Array, w: jax.Array, *, use_kernel: bool | None = None):
    """out[b,t,d] = Σ_k w[k,d]·x[b,t-k,d] (causal, zero-padded history)."""
    kernel = on_tpu() if use_kernel is None else use_kernel
    if kernel:
        return token_shift_pallas(x, w, interpret=interpret_default())
    return token_shift_ref(x, w)
