"""Public op: fused token-shift / short causal depthwise conv."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.token_shift.kernel import token_shift_pallas
from repro.kernels.token_shift.ref import token_shift_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def token_shift(x: jax.Array, w: jax.Array, *, use_kernel: bool | None = None):
    """out[b,t,d] = Σ_k w[k,d]·x[b,t-k,d] (causal, zero-padded history)."""
    kernel = _on_tpu() if use_kernel is None else use_kernel
    if kernel:
        return token_shift_pallas(x, w, interpret=not _on_tpu())
    return token_shift_ref(x, w)
