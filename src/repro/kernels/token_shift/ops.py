"""Public op: fused token-shift / short causal depthwise conv."""

from __future__ import annotations

import jax

from repro.kernels.common import (
    KernelResources,
    interpret_default,
    on_tpu,
    pick_d_block,
    register_kernel_resources,
    validate_divisible,
)
from repro.kernels.token_shift.kernel import token_shift_pallas
from repro.kernels.token_shift.ref import token_shift_ref


# NOTE: intentionally un-jitted — called under the model's outer jit; a
# nested jit would cache across the scan_unroll() lowering flag.
def token_shift(x: jax.Array, w: jax.Array, *, use_kernel: bool | None = None):
    """out[b,t,d] = Σ_k w[k,d]·x[b,t-k,d] (causal, zero-padded history)."""
    kernel = on_tpu() if use_kernel is None else use_kernel
    if kernel:
        return token_shift_pallas(x, w, interpret=interpret_default())
    return token_shift_ref(x, w)


# --------------------------------------------------------------------------
# Static resource declarations (repro.analysis.resources)
# --------------------------------------------------------------------------

@register_kernel_resources("token_shift.fwd")
def _token_shift_resources(cfg, *, t: int = 4096, chunk: int = 256):
    """Fused causal depthwise conv (the RG-LRU temporal mixer)."""
    if "rec" not in tuple(cfg.pattern):
        return None
    import jax.numpy as jnp

    taps = cfg.conv_width
    d = cfg.d_rnn
    c = min(chunk, t)
    validate_divisible("T", t, c)
    if c < taps:
        raise ValueError(f"chunk {c} must be >= taps {taps}")
    d_block = pick_d_block(d)
    isz = jnp.dtype(cfg.dtype).itemsize
    seq = (1, c, d_block)
    return KernelResources(
        kernel="token_shift.fwd",
        location="src/repro/kernels/token_shift/kernel.py:token_shift_pallas",
        grid=(1, d // d_block, t // c),
        blocks=(
            ("x", seq, isz), ("w", (taps, d_block), isz), ("out", seq, isz),
        ),
        scratch=(("tail", (taps - 1, d_block), 4),),
    )
