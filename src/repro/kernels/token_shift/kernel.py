"""Pallas TPU kernel: fused token-shift (depthwise causal short conv).

The paper's convolution example (Fig. 1c): each thread loads its element
*once* and receives the neighboring elements through elevator shifts instead
of re-loading them.  On TPU:

* each sequence chunk is loaded into VMEM exactly once (HBM traffic = N
  elements, vs. K*N for the naive per-tap gather — the paper's Fig. 1a);
* the K-1 trailing rows of the previous chunk persist in a VMEM scratch — a
  (K-1)-entry *token buffer* forwarding values across the chunk boundary;
* the shifted operands are produced by sublane rotates inside VMEM (fabric
  forwarding), multiplied by per-channel taps and accumulated on the VPU.

Grid: (batch, d_blocks, seq_chunks), sequence fastest so the scratch carry
is private per (batch, d_block) and reset at chunk 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pick_d_block, reset_carry, validate_divisible

MAX_TAPS = 8  # hardware-aligned token-buffer budget (paper uses 16)


def token_shift_kernel(x_ref, w_ref, out_ref, carry_ref, *, taps: int, chunk: int):
    reset_carry(carry_ref, seq_axis=2)

    x = x_ref[0].astype(jnp.float32)          # (chunk, d_block)
    w = w_ref[...].astype(jnp.float32)        # (taps, d_block)
    carry = carry_ref[...]                    # (taps-1, d_block) prev tail

    # Extended block: previous chunk's tail followed by this chunk.  The
    # elevator shift for tap k is then a static slice of `ext`.
    ext = jnp.concatenate([carry, x], axis=0)  # (chunk + taps - 1, d_block)

    acc = w[0] * x
    for k in range(1, taps):
        # Rows [taps-1-k : taps-1-k+chunk] of ext == x shifted down by k.
        shifted = jax.lax.dynamic_slice_in_dim(ext, taps - 1 - k, chunk, axis=0)
        acc = acc + w[k] * shifted

    out_ref[0, :, :] = acc.astype(out_ref.dtype)
    # Forward this chunk's tail into the token buffer for the next chunk.
    carry_ref[...] = x[chunk - (taps - 1):, :]


@functools.partial(jax.jit, static_argnames=("interpret", "chunk"))
def token_shift_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Depthwise causal conv: out[t] = Σ_k w[k]·x[t-k].  x: (B,T,D), w: (K,D)."""
    b, t, d = x.shape
    taps = w.shape[0]
    if taps < 2 or taps > MAX_TAPS:
        raise ValueError(f"taps must be in [2, {MAX_TAPS}], got {taps}")
    if w.shape[1] != d:
        raise ValueError(f"w dim {w.shape[1]} != D {d}")
    chunk = min(chunk, t)
    validate_divisible("T", t, chunk)
    if chunk < taps:
        raise ValueError(f"chunk {chunk} must be >= taps {taps}")
    d_block = pick_d_block(d)

    grid = (b, d // d_block, t // chunk)
    kernel = functools.partial(token_shift_kernel, taps=taps, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((taps, d_block), lambda bi, di, si: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((b, t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((taps - 1, d_block), jnp.float32)],
        interpret=interpret,
    )(x, w)
