"""Donation/aliasing verifier: every registered serve/train jit must
alias its state pytree in place.

PR 4's perf story — decode state resident across windows instead of
copied per dispatch — rests on ``donate_argnums`` showing up as
``input_output_alias`` in the compiled HLO.  A new jit that forgets the
donation ships silently: the code still runs, it just pays a full cache
copy per dispatch.  This pass lowers each *registered* entrypoint with
abstract (ShapeDtypeStruct) arguments — nothing executes — compiles it,
and errors unless the HLO text shows input/output aliasing.

Entrypoints come from registration hooks next to the jits they describe
(:func:`repro.serve.engine.audit_jit_entrypoints`,
:func:`repro.train.step.audit_jit_entrypoints`), so adding a jit without
registering it is a reviewable one-liner away from being audited.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.analysis.findings import Finding, error, info

PASS = "donation"


@dataclasses.dataclass(frozen=True)
class JitEntry:
    """One registered jitted entrypoint: the jit object plus abstract
    arguments sufficient to lower it without executing anything."""

    name: str                 # e.g. "serve.window"
    fn: Any                   # the jax.jit-wrapped callable
    args: tuple               # ShapeDtypeStruct pytrees (or None leaves)
    location: str             # repo-path-like location of the jit
    #: Human label for what must alias — or ``None`` for a *read-only*
    #: entrypoint that must NOT alias (e.g. the serve shadow checksum,
    #: which would destroy the live decode state if it donated it).
    donated: str | None = "state"
    #: The declared ``donate_argnums`` — the *positions* the host-tier
    #: lifetime audit (``repro.analysis.hostsafety``) treats as consumed
    #: at every call site.  ``None`` for read-only entrypoints.
    donate_argnums: tuple[int, ...] | None = (1,)
    #: Source symbol whose AST-derived donor entry must agree (the jit
    #: attribute or the factory that builds it); cross-checked by
    #: ``tests/test_hostsafety.py`` so the static registry and the live
    #: declarations cannot drift apart.
    donor: str | None = None


def check_entry(entry: JitEntry) -> list[Finding]:
    """Lower + compile ``entry`` abstractly; require input_output_alias
    (or, for ``donated=None`` read-only entries, require its absence)."""
    try:
        hlo = entry.fn.lower(*entry.args).compile().as_text()
    except Exception as e:  # noqa: BLE001 — a broken lowering IS a finding
        return [error(
            PASS, entry.location,
            f"{entry.name}: failed to lower/compile for audit: {e!r}",
        )]
    if entry.donated is None:
        if "input_output_alias" in hlo:
            return [error(
                PASS, entry.location,
                f"{entry.name}: read-only entrypoint aliases its input — "
                "a donated argument here would consume live state the "
                "serve loop still owns",
            )]
        return [info(
            PASS, entry.location,
            f"{entry.name}: read-only (no aliasing), state survives",
        )]
    if "input_output_alias" not in hlo:
        return [error(
            PASS, entry.location,
            f"{entry.name}: compiled HLO shows no input_output_alias — "
            f"the {entry.donated} pytree is copied per dispatch "
            f"(missing donate_argnums?)",
        )]
    n = hlo.count("input_output_alias")
    return [info(
        PASS, entry.location,
        f"{entry.name}: {entry.donated} aliased in place",
        alias_sites=n,
    )]


def run(cfg) -> list[Finding]:
    """Audit every registered serve + train jit for ``cfg`` (reduced to
    its smoke-size family member: donation is shape-independent and the
    audit compiles, so small shapes keep it cheap)."""
    from repro.analysis.registry import jit_entries

    findings: list[Finding] = []
    for entry in jit_entries(cfg.reduced()):
        findings += check_entry(entry)
    return findings
