"""Pallas resource checker: static VMEM footprints vs the per-core budget.

Every Pallas kernel in the repo declares its geometry next to its
``pallas_call`` (``kernels.common.register_kernel_resources``): grid,
BlockSpec block shapes, scratch shapes.  This pass evaluates those
declarations for a config — *full-size*, not the smoke-reduced variant,
because the whole point is catching a production shape that only blows
VMEM on hardware — and checks, with pure shape arithmetic:

* the estimated VMEM high-water mark (double-buffered in/out blocks +
  scratch) fits the per-core budget;
* the grid is well-formed (every dim >= 1);
* the geometry validators the wrappers share (``validate_divisible``,
  ``pick_d_block``, chunk resolution) accept the config — a spec fn
  raising is converted into an error finding, so an indivisible
  ``d_rnn`` or a chunk smaller than ``conv_width`` is caught before any
  array exists;
* for the WKV decode window, the declared state tile agrees with the
  cost model's per-window state bytes (``wkv_decode_traffic`` direct) —
  the kernel and the model it is benchmarked against cannot drift apart.

Nothing is traced, lowered, or executed: this pass is plain integer math
over declared shapes, so it runs in microseconds for any config.
"""

from __future__ import annotations

import math

from repro.analysis.findings import Finding, error, info

PASS = "resources"

#: Per-core VMEM budget (bytes).  TPU v4/v5 cores expose ~16 MiB of VMEM;
#: a kernel whose working set exceeds this fails to compile on hardware —
#: on this CPU container it would only fail in interpret-mode silence.
VMEM_BUDGET_BYTES = 16 * 2 ** 20


def _load_specs():
    """Import every kernel ops module (registration side effects), then
    return the registry."""
    import repro.kernels.elevator_scan.ops  # noqa: F401
    import repro.kernels.local_attention.ops  # noqa: F401
    import repro.kernels.token_shift.ops  # noqa: F401
    import repro.kernels.wkv.ops  # noqa: F401
    from repro.kernels.common import KERNEL_RESOURCE_SPECS

    return KERNEL_RESOURCE_SPECS


def check_resources(res, *, budget: int = VMEM_BUDGET_BYTES,
                    what: str = "") -> list[Finding]:
    """Budget + well-formedness checks for one declaration."""
    findings: list[Finding] = []
    label = f"{what}{res.kernel}"
    if not res.grid or any(g < 1 for g in res.grid):
        findings.append(error(
            PASS, res.location,
            f"{label}: malformed grid {res.grid}",
        ))
        return findings
    vm = res.vmem_bytes()
    if vm > budget:
        findings.append(error(
            PASS, res.location,
            f"{label}: estimated VMEM {vm / 2**10:.0f} KiB exceeds the "
            f"{budget / 2**20:.0f} MiB per-core budget "
            f"(blocks {res.block_bytes()} B x2 + scratch "
            f"{res.scratch_bytes()} B)",
            vmem_bytes=vm, budget_bytes=budget,
        ))
    else:
        findings.append(info(
            PASS, res.location,
            f"{label}: grid {res.grid} ({res.grid_steps()} steps), "
            f"estimated VMEM {vm / 2**10:.0f} KiB of "
            f"{budget / 2**20:.0f} MiB",
            vmem_bytes=vm, grid_steps=res.grid_steps(),
        ))
    return findings


def crosscheck_decode_state(cfg, res) -> list[Finding]:
    """Declared WKV decode state tile vs the cost model's per-window
    state bytes (``wkv_decode_traffic`` direct: one read + one write)."""
    from repro.core import cost_model

    dh = None
    declared = 0
    for name, shape, isz in res.blocks:
        if name in ("h0", "s_out"):
            declared += math.prod(shape) * isz
            dh = shape[-1]
    if dh is None:
        return [error(
            PASS, res.location,
            f"{cfg.name} {res.kernel}: no state blocks (h0/s_out) declared "
            f"— cannot cross-check against wkv_decode_traffic",
        )]
    b = 1
    h = res.grid[1]
    k = res.grid[2]
    costs = {c.variant: c for c in cost_model.wkv_decode_traffic(b, h, dh, k)}
    tok_io = cost_model.wkv_decode_token_io(b, h, dh, k)
    modeled = costs["direct"].traffic.dram_bytes - tok_io
    # Declared per-(batch,head) tile x the (b, h) grid extent = the HBM
    # bytes the window actually moves for S.
    counted = declared * res.grid[0] * h
    if counted != modeled:
        return [error(
            PASS, res.location,
            f"{cfg.name} {res.kernel}: declared state traffic {counted} B "
            f"!= cost model's {modeled} B per window — kernel and "
            f"wkv_decode_traffic drifted apart",
            counted_bytes=counted, modeled_bytes=modeled,
        )]
    return [info(
        PASS, res.location,
        f"{cfg.name} {res.kernel}: state HBM traffic matches "
        f"wkv_decode_traffic direct ({counted} B/window)",
        state_bytes=counted,
    )]


def run(cfg, *, budget: int = VMEM_BUDGET_BYTES) -> list[Finding]:
    """Audit every registered kernel declaration applicable to ``cfg``
    (the FULL config — production shapes, not the smoke reduction)."""
    specs = _load_specs()
    findings: list[Finding] = []
    applicable = 0
    for name in sorted(specs):
        try:
            res = specs[name](cfg)
        except Exception as e:  # noqa: BLE001 — invalid geometry IS a finding
            findings.append(error(
                PASS, f"src/repro/kernels:{name}",
                f"{cfg.name} {name}: invalid kernel geometry: {e}",
            ))
            continue
        if res is None:
            continue
        applicable += 1
        findings += check_resources(res, budget=budget, what=f"{cfg.name} ")
        if name == "wkv.decode_window":
            findings += crosscheck_decode_state(cfg, res)
    if applicable == 0:
        findings.append(error(
            PASS, "src/repro/kernels/common.py:KERNEL_RESOURCE_SPECS",
            f"{cfg.name}: no registered kernel resource spec applies — "
            f"registry wiring is broken for pattern {tuple(cfg.pattern)}",
        ))
    return findings
