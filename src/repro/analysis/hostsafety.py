"""Host-tier safety audit: donation lifetime + lock discipline, pure AST.

The device tier is already statically audited (collectives in the jaxpr,
``input_output_alias`` in the HLO, VMEM geometry) — but the two worst
bugs of this repo's history lived in the *host* code that drives those
jits: a stale watchdog thread writing an abandoned step's result past
the generation fence (PR 6), and a fleet no-progress guard sampling
``busy`` before the round it was guarding (PR 9).  Both were invisible
to tier-1 because donation is a no-op on CPU and thread interleavings
are nondeterministic.  This pass walks the host source as an AST —
no jax import, nothing compiles — and checks two families of invariant:

**(a) donation lifetime.**  A ``jax.jit(..., donate_argnums=...)``
consumes the donated operand's buffers at call time; on TPU any later
read is silent garbage.  The pass derives a donation registry from the
source itself (attribute-bound jits, jit *factories* and attributes
bound to factory results, resolved across modules), then dataflow-walks
every function: a donated pytree that is read, or passed to a second
donating call, before being re-bound is an error.  Loops are walked
twice so loop-carried re-passes (the retry path, ``generate()``'s window
loop) are seen.  Calls routed through the engine's ``_dispatch`` wrapper
are understood: the donated key is the corresponding element of the
``args`` tuple, and inside ``_dispatch`` itself ``fn(*args)`` donates
``args``.  Intentional reads carry a ``# hostsafety: ok(<reason>)``
waiver on (or one line above) the flagged line; waived findings are
listed in the table as INFO.

**(b) lock discipline.**  Inventories ``threading.Lock``/``Thread`` use,
builds the lock-acquisition-order graph (a cycle is a deadlock finding),
and flags: writes to shared state (self attributes, closure names)
inside a thread target but outside any lock; attributes written both by
a thread target and, un-locked, by other methods; result writes in an
*abandonable* thread (its launcher joins with a timeout) whose lock
region has no generation fence (the PR 6 class); and loop guards that
``raise`` on a mix of state sampled before and after the loop's mutating
call (the PR 9 class).

The dynamic complement — the runtime witness for what this pass claims
statically — is :mod:`repro.serve.interleave`, which forces preemption
at exactly the boundaries audited here.

API for mutation tests: :func:`run_on_sources` takes a mapping of
repo-path labels to source text, so fixture copies with reintroduced
bugs audit under their real locations without touching the tree.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path

from repro.analysis.findings import Finding, Severity, error, info, warn

PASS = "hostsafety"

#: This pass is pure AST: the CLI runs it before jax is ever imported
#: (tier-1 lane 0), so it must stay importable and runnable jax-free.
JAX_FREE = True

_REPO = Path(__file__).resolve().parents[3]

#: Host modules under audit, repo-relative.  Order is display order.
HOST_MODULES = (
    "src/repro/serve/engine.py",
    "src/repro/serve/fleet.py",
    "src/repro/serve/health.py",
    "src/repro/serve/chaos.py",
    "src/repro/serve/paging.py",
    "src/repro/serve/interleave.py",
    "src/repro/ft/watchdog.py",
    "src/repro/checkpoint/checkpoint.py",
    "src/repro/train/step.py",
    "src/repro/launch/dryrun.py",
    "src/repro/launch/serve.py",
    "src/repro/launch/train.py",
)

WAIVER_RE = re.compile(r"#\s*hostsafety:\s*ok\(([^)]*)\)")

#: Dispatch wrappers: calling ``<obj>.<name>(kind, fn, args, ...)``
#: invokes ``fn(*args)`` — if ``fn`` donates, the donated key is the
#: matching element of the ``args`` tuple.  Inside the wrapper itself,
#: ``<fn_param>(*<args_param>)`` donates ``<args_param>``.
DISPATCH_WRAPPERS = {
    "_dispatch": {"fn_arg": 1, "args_arg": 2,
                  "fn_param": "fn", "args_param": "args"},
}

#: Method names that mutate their receiver in place (shared-write rule).
_MUTATORS = frozenset({
    "append", "appendleft", "extend", "add", "update", "insert", "pop",
    "popleft", "remove", "discard", "clear", "setdefault", "put",
})

#: Receiver constructors recognized as locks.
_LOCK_CTORS = frozenset({"Lock", "RLock", "make_lock"})


# --------------------------------------------------------------------------
# small AST helpers
# --------------------------------------------------------------------------

def _key_of(node) -> str | None:
    """Canonical dotted key for a Name/Attribute chain, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _key_of(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _own_walk(fn):
    """Walk ``fn``'s body without descending into nested function/lambda
    scopes (their statements belong to the nested scope)."""
    stack = list(fn.body)
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(n))


def _int_constants(node) -> tuple[int, ...]:
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, int) \
                and not isinstance(n.value, bool):
            out.add(n.value)
    return tuple(sorted(out))


def _is_jax_jit(call) -> bool:
    f = call.func
    return (isinstance(f, ast.Attribute) and f.attr == "jit"
            and isinstance(f.value, ast.Name) and f.value.id == "jax")


def _donating_argnums(node) -> tuple[int, ...] | None:
    """donate_argnums of a ``jax.jit(...)`` call node, else None.

    Handles tuple literals and conditional forms like
    ``(0,) if donate else ()`` (the union of ints found).
    """
    if not isinstance(node, ast.Call) or not _is_jax_jit(node):
        return None
    for kw in node.keywords:
        if kw.arg == "donate_argnums":
            nums = _int_constants(kw.value)
            return nums or None
    return None


def _lock_ctor_name(node) -> bool:
    """True if ``node`` is a call to a recognized lock constructor."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    name = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else None)
    return name in _LOCK_CTORS


# --------------------------------------------------------------------------
# donation registry (derived from the source, cross-module)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Donor:
    name: str                  # attribute or function name
    kind: str                  # "attr" | "factory"
    argnums: tuple[int, ...]
    module: str                # repo-relative path
    line: int


@dataclass
class DonationRegistry:
    """What donates, derived from the AST: attributes bound to donating
    jits (directly or via a factory) and factories whose result donates."""

    attr_donors: dict[str, Donor] = field(default_factory=dict)
    factories: dict[str, Donor] = field(default_factory=dict)


def collect_registry(sources: dict[str, str]) -> DonationRegistry:
    reg = DonationRegistry()
    trees = {}
    for rel, src in sources.items():
        try:
            trees[rel] = ast.parse(src)
        except SyntaxError:
            continue  # surfaced as a finding by the module audit

    # Phase 1: jit-literal attribute donors + factories.
    for rel, tree in trees.items():
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                nums = _donating_argnums(node.value)
                if nums and isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self":
                    reg.attr_donors[t.attr] = Donor(
                        t.attr, "attr", nums, rel, node.lineno)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                nums: set[int] = set()
                returns_callable = False
                for n in _own_walk(node):
                    got = _donating_argnums(n)
                    if got:
                        nums.update(got)
                    if isinstance(n, ast.Return) and n.value is not None:
                        if isinstance(n.value, ast.Name) \
                                or _donating_argnums(n.value):
                            returns_callable = True
                if nums and returns_callable:
                    reg.factories[node.name] = Donor(
                        node.name, "factory", tuple(sorted(nums)), rel,
                        node.lineno)

    # Phase 2: attributes bound to a factory's result
    # (``self._prefill = make_cache_prefill_step(...)``), including
    # factories imported from another audited module.
    for rel, tree in trees.items():
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
                continue
            t, v = node.targets[0], node.value
            if not (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"
                    and isinstance(v, ast.Call)):
                continue
            fname = v.func.attr if isinstance(v.func, ast.Attribute) else (
                v.func.id if isinstance(v.func, ast.Name) else None)
            if fname in reg.factories:
                reg.attr_donors[t.attr] = Donor(
                    t.attr, "attr", reg.factories[fname].argnums, rel,
                    node.lineno)
    return reg


# --------------------------------------------------------------------------
# per-module audit context
# --------------------------------------------------------------------------

class _ModuleCtx:
    """Shared per-module facts: source lines (for waivers), path label."""

    def __init__(self, path: str, src: str, registry: DonationRegistry):
        self.path = path
        self.lines = src.splitlines()
        self.registry = registry

    def waiver(self, line: int) -> str | None:
        """Waiver reason if ``# hostsafety: ok(<reason>)`` sits on
        ``line`` or anywhere in the contiguous comment block directly
        above it (comments are invisible to the AST, so this reads the
        raw source)."""
        if not 1 <= line <= len(self.lines):
            return None
        m = WAIVER_RE.search(self.lines[line - 1])
        if m:
            return m.group(1).strip()
        ln = line - 1
        while ln >= 1 and self.lines[ln - 1].lstrip().startswith("#"):
            m = WAIVER_RE.search(self.lines[ln - 1])
            if m:
                return m.group(1).strip()
            ln -= 1
        return None


class _Reporter:
    """Finding sink with waiver handling and per-(rule, line) dedup."""

    def __init__(self, ctx: _ModuleCtx, qual: str, out: list[Finding],
                 waived: list[str]):
        self.ctx = ctx
        self.qual = qual
        self.out = out
        self.waived = waived
        self._seen: set[tuple] = set()

    def flag(self, rule: str, node, message: str,
             severity: Severity = Severity.ERROR):
        line = getattr(node, "lineno", 0)
        dkey = (rule, line, self.qual)
        if dkey in self._seen:
            return
        self._seen.add(dkey)
        loc = f"{self.ctx.path}:{self.qual}"
        reason = self.ctx.waiver(line)
        if reason is not None:
            self.waived.append(f"{loc} line {line} [{rule}]: {reason}")
            self.out.append(info(
                PASS, loc,
                f"[{rule}] line {line}: waived — {reason}", line=line))
            return
        mk = error if severity >= Severity.ERROR else warn
        self.out.append(mk(PASS, loc, f"[{rule}] line {line}: {message}",
                           line=line))


# --------------------------------------------------------------------------
# pass (a): donation lifetime dataflow
# --------------------------------------------------------------------------

class _DonationWalk:
    """Abstract interpreter over one function body tracking which dotted
    keys currently name donated (consumed) pytrees."""

    def __init__(self, ctx: _ModuleCtx, fn, qual: str, rep: _Reporter,
                 summary_mode: bool = False, dispatch_spec=None):
        self.ctx = ctx
        self.fn = fn
        self.qual = qual
        self.rep = rep
        self.summary_mode = summary_mode
        # Inside a dispatch wrapper (or a closure nested in one),
        # ``fn(*args)`` donates ``args``.
        self.dispatch_spec = dispatch_spec
        self.donors: dict[str, tuple[int, ...]] = {}
        self.tuples: dict[str, list] = {}
        self.donated: dict[str, int] = {}
        self.nested: dict[str, set[str]] = {}
        self.local: set[str] = set()
        self.effects: set[str] = set()     # summary mode: donated free keys
        self.sites = 0                     # donating calls walked

    # -- entry ------------------------------------------------------------

    def run(self):
        args = self.fn.args
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            self.local.add(a.arg)
        if args.vararg:
            self.local.add(args.vararg.arg)
        if args.kwarg:
            self.local.add(args.kwarg.arg)
        self.exec_block(self.fn.body)

    # -- state save/restore for branches ----------------------------------

    def _snap(self):
        return (dict(self.donors), dict(self.tuples), dict(self.donated))

    def _restore(self, snap):
        self.donors, self.tuples, self.donated = (
            dict(snap[0]), dict(snap[1]), dict(snap[2]))

    def _merge(self, a, b):
        self.donors = {**a[0], **b[0]}
        self.tuples = {**a[1], **b[1]}
        self.donated = {**a[2], **b[2]}

    # -- statements -------------------------------------------------------

    def exec_block(self, stmts):
        for st in stmts:
            self.exec_stmt(st)

    def exec_stmt(self, st):
        if isinstance(st, ast.Assign):
            self.read(st.value)
            for t in st.targets:
                self.assign_target(t, st.value)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self.read(st.value)
            self.assign_target(st.target, st.value)
        elif isinstance(st, ast.AugAssign):
            self.read(st.value)
            self.read(st.target)
            self.assign_target(st.target, None)
        elif isinstance(st, ast.Expr):
            self.read(st.value)
        elif isinstance(st, ast.Return):
            if st.value is not None:
                self.read(st.value)
        elif isinstance(st, ast.If):
            self.read(st.test)
            self._branch(st.body, st.orelse)
        elif isinstance(st, ast.While):
            self.read(st.test)
            self._loop(st.body)
            self.read(st.test)
            self.exec_block(st.orelse)
        elif isinstance(st, ast.For):
            self.read(st.iter)
            self.assign_target(st.target, None)
            self._loop(st.body)
            self.exec_block(st.orelse)
        elif isinstance(st, ast.With):
            for item in st.items:
                self.read(item.context_expr)
                if item.optional_vars is not None:
                    self.assign_target(item.optional_vars, None)
            self.exec_block(st.body)
        elif isinstance(st, ast.Try):
            self.exec_block(st.body)
            post = self._snap()
            merged = post
            for h in st.handlers:
                self._restore(post)
                if h.name:
                    self.local.add(h.name)
                self.exec_block(h.body)
                got = self._snap()
                merged = ({**merged[0], **got[0]}, {**merged[1], **got[1]},
                          {**merged[2], **got[2]})
            self._restore(merged)
            self.exec_block(st.orelse)
            self.exec_block(st.finalbody)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.local.add(st.name)
            sub = _DonationWalk(self.ctx, st, f"{self.qual}.{st.name}",
                                self.rep, summary_mode=True,
                                dispatch_spec=self.dispatch_spec)
            # Nested closures see the enclosing donation registry state.
            sub.donors = dict(self.donors)
            sub.tuples = dict(self.tuples)
            sub.run()
            self.nested[st.name] = sub.effects
            self.sites += sub.sites
        elif isinstance(st, ast.Raise):
            if st.exc is not None:
                self.read(st.exc)
            if st.cause is not None:
                self.read(st.cause)
        elif isinstance(st, ast.Assert):
            self.read(st.test)
            if st.msg is not None:
                self.read(st.msg)
        elif isinstance(st, ast.Delete):
            for t in st.targets:
                self.assign_target(t, None)
        elif isinstance(st, (ast.ClassDef,)):
            pass  # nested classes: out of scope
        # Pass/Break/Continue/Import/Global/Nonlocal: nothing to track.

    def _branch(self, body, orelse):
        pre = self._snap()
        self.exec_block(body)
        a = self._snap()
        self._restore(pre)
        self.exec_block(orelse)
        b = self._snap()
        self._merge(a, b)

    def _loop(self, body):
        # Two passes: the second sees the first iteration's donations, so
        # loop-carried use-after-donate (the PR-retry shape) surfaces.
        # The reporter dedups by (rule, line).
        self.exec_block(body)
        self.exec_block(body)

    # -- donation core ----------------------------------------------------

    def _donated_hit(self, key: str) -> int | None:
        for d, line in self.donated.items():
            if key == d or key.startswith(d + "."):
                return line
        return None

    def check_read(self, key: str, node):
        line = self._donated_hit(key)
        if line is not None:
            self.rep.flag(
                "use-after-donate", node,
                f"'{key}' read after its buffers were donated at line "
                f"{line} — on TPU this is silent garbage; re-bind the key "
                "from the jit's result (or waive an intentional read)")

    def donate_key(self, key: str | None, node):
        self.sites += 1
        if key is None:
            return
        if key in self.donated:
            self.rep.flag(
                "use-after-donate", node,
                f"'{key}' passed to a donating jit again after being "
                f"donated at line {self.donated[key]} — the second call "
                "consumes already-freed buffers")
        self.donated[key] = getattr(node, "lineno", 0)
        root = key.split(".", 1)[0]
        if self.summary_mode and root not in self.local:
            self.effects.add(key)

    def donate_expr(self, e, call):
        key = _key_of(e)
        if key is None:
            self.read(e)
        else:
            self.check_read(key, e)   # reading a donated key to re-donate
            self.donate_key(key, call)

    def _apply_effects(self, name: str, node):
        for key in sorted(self.nested.get(name, ())):
            self.donate_key(key, node)

    def clear_key(self, key: str):
        for d in [d for d in self.donated
                  if d == key or d.startswith(key + ".")]:
            del self.donated[d]

    # -- assignment -------------------------------------------------------

    def assign_target(self, t, value):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self.assign_target(e, None)
            return
        if isinstance(t, ast.Starred):
            self.assign_target(t.value, None)
            return
        key = _key_of(t)
        if key is None:
            # Subscript etc: evaluate the receiver as a read.
            for child in ast.iter_child_nodes(t):
                self.read(child)
            return
        self.clear_key(key)
        if isinstance(t, ast.Name):
            self.local.add(key)
            self.donors.pop(key, None)
            self.tuples.pop(key, None)
            if value is not None:
                nums = self._callee_argnums(value)
                if nums:
                    self.donors[key] = nums
                elif isinstance(value, ast.Name) and value.id in self.donors:
                    self.donors[key] = self.donors[value.id]
                elif isinstance(value, ast.Tuple):
                    self.tuples[key] = [_key_of(e) for e in value.elts]

    def _callee_argnums(self, value) -> tuple[int, ...] | None:
        """If evaluating ``value`` yields a donating callable (a donating
        ``jax.jit`` literal or a factory call), its argnums."""
        nums = _donating_argnums(value)
        if nums:
            return nums
        if isinstance(value, ast.Call):
            f = value.func
            fname = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else None)
            donor = self.ctx.registry.factories.get(fname or "")
            if donor is not None:
                return donor.argnums
        return None

    # -- expressions ------------------------------------------------------

    def read(self, e):
        if e is None:
            return
        if isinstance(e, ast.Name):
            self.check_read(e.id, e)
        elif isinstance(e, ast.Attribute):
            key = _key_of(e)
            if key is not None:
                self.check_read(key, e)
            else:
                self.read(e.value)
        elif isinstance(e, ast.Call):
            self.handle_call(e)
        elif isinstance(e, ast.IfExp):
            self.read(e.test)
            pre = self._snap()
            self.read(e.body)
            a = self._snap()
            self._restore(pre)
            self.read(e.orelse)
            b = self._snap()
            self._merge(a, b)
        elif isinstance(e, ast.Lambda):
            pass  # separate scope; donation-irrelevant in this codebase
        else:
            for child in ast.iter_child_nodes(e):
                if isinstance(child, (ast.expr, ast.comprehension,
                                      ast.keyword)):
                    if isinstance(child, ast.comprehension):
                        self.read(child.iter)
                        for cond in child.ifs:
                            self.read(cond)
                    elif isinstance(child, ast.keyword):
                        self.read(child.value)
                    else:
                        self.read(child)

    # -- calls ------------------------------------------------------------

    def _resolve_callee(self, f) -> tuple[int, ...] | None:
        if isinstance(f, ast.Name):
            return self.donors.get(f.id)
        if isinstance(f, ast.Attribute):
            donor = self.ctx.registry.attr_donors.get(f.attr)
            if donor is not None:
                return donor.argnums
            return None
        if isinstance(f, ast.Call):
            # ``self._window_step(k)(...)``: the factory result, invoked.
            return self._callee_argnums(f)
        return None

    def handle_call(self, call):
        f = call.func
        # Dispatch wrapper call sites: ``<obj>._dispatch(kind, fn, args)``.
        if isinstance(f, ast.Attribute) and f.attr in DISPATCH_WRAPPERS:
            self._handle_dispatch_call(call, DISPATCH_WRAPPERS[f.attr])
            return
        # Inside a wrapper: ``fn(*args)`` donates the args tuple.
        spec = self.dispatch_spec
        if (spec is not None and isinstance(f, ast.Name)
                and f.id == spec["fn_param"]
                and any(isinstance(a, ast.Starred)
                        and isinstance(a.value, ast.Name)
                        and a.value.id == spec["args_param"]
                        for a in call.args)):
            self.donate_key(spec["args_param"], call)
            for a in call.args:
                if not isinstance(a, ast.Starred):
                    self.read(a)
            for kw in call.keywords:
                self.read(kw.value)
            return

        argnums = self._resolve_callee(f)
        if isinstance(f, ast.Attribute):
            self.read(f.value)
        elif isinstance(f, ast.Call):
            for child in ast.iter_child_nodes(f):
                if isinstance(child, ast.expr) and child is not f.func:
                    self.read(child)
        if argnums:
            self.sites += 1
        for i, a in enumerate(call.args):
            if isinstance(a, ast.Starred):
                self.read(a.value)
            elif argnums and i in argnums:
                self.donate_expr(a, call)
            else:
                if isinstance(a, ast.Name) and a.id in self.nested:
                    self._apply_effects(a.id, call)
                self.read(a)
        for kw in call.keywords:
            if isinstance(kw.value, ast.Name) and kw.value.id in self.nested:
                self._apply_effects(kw.value.id, call)
            self.read(kw.value)
        # Calling a nested closure directly runs its donation effects.
        if isinstance(f, ast.Name) and f.id in self.nested:
            self._apply_effects(f.id, call)

    def _handle_dispatch_call(self, call, spec):
        fn_i, args_i = spec["fn_arg"], spec["args_arg"]
        fn_expr = call.args[fn_i] if len(call.args) > fn_i else None
        args_expr = call.args[args_i] if len(call.args) > args_i else None
        argnums = (self._resolve_callee(fn_expr)
                   if fn_expr is not None else None)
        for i, a in enumerate(call.args):
            if i == args_i and argnums:
                continue
            self.read(a)
        for kw in call.keywords:
            self.read(kw.value)
        if args_expr is None:
            return
        if not argnums:
            self.read(args_expr)
            return
        self.sites += 1
        if isinstance(args_expr, ast.Tuple):
            for i, e in enumerate(args_expr.elts):
                if i in argnums:
                    self.donate_expr(e, call)
                else:
                    self.read(e)
        elif isinstance(args_expr, ast.Name):
            keys = self.tuples.get(args_expr.id)
            if keys is not None:
                for n in argnums:
                    if n < len(keys):
                        self.donate_key(keys[n], call)
            else:
                self.donate_key(args_expr.id, call)
        else:
            self.read(args_expr)


def _audit_donation(ctx: _ModuleCtx, tree, out: list[Finding],
                    waived: list[str]) -> int:
    """Walk every function in the module; returns donation sites seen."""
    sites = 0

    def visit(node, prefix):
        nonlocal sites
        for child in node.body if hasattr(node, "body") else ():
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                rep = _Reporter(ctx, qual, out, waived)
                spec = DISPATCH_WRAPPERS.get(child.name)
                walk = _DonationWalk(ctx, child, qual, rep,
                                     dispatch_spec=spec)
                walk.run()
                sites += walk.sites
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}.{child.name}" if prefix
                      else child.name)

    visit(tree, "")
    return sites


# --------------------------------------------------------------------------
# pass (b): lock discipline
# --------------------------------------------------------------------------

@dataclass
class _ClassLocks:
    qual: str
    locks: set[str] = field(default_factory=set)       # self.<attr> locks
    thread_targets: dict[str, object] = field(default_factory=dict)
    abandonable: bool = False    # some launcher joins with a timeout


def _collect_class_locks(cls: ast.ClassDef, prefix: str) -> _ClassLocks:
    qual = f"{prefix}.{cls.name}" if prefix else cls.name
    cl = _ClassLocks(qual=qual)
    methods = {m.name: m for m in cls.body
               if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
    for m in methods.values():
        for node in ast.walk(m):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t, v = node.targets[0], node.value
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self" and _lock_ctor_name(v):
                    cl.locks.add(t.attr)
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "Thread":
                    for kw in node.keywords:
                        if kw.arg != "target":
                            continue
                        tgt = kw.value
                        if isinstance(tgt, ast.Name) \
                                and tgt.id in _local_defs(m):
                            cl.thread_targets[f"{m.name}.{tgt.id}"] = (
                                _local_defs(m)[tgt.id])
                        elif isinstance(tgt, ast.Attribute) \
                                and tgt.attr in methods:
                            cl.thread_targets[tgt.attr] = methods[tgt.attr]
                if isinstance(f, ast.Attribute) and f.attr == "join":
                    timed = bool(node.args) or any(
                        kw.arg == "timeout" for kw in node.keywords)
                    if timed:
                        cl.abandonable = True
    return cl


def _local_defs(fn) -> dict[str, object]:
    return {n.name: n for n in _own_walk(fn)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


class _LockWalk:
    """Walk one thread-target function: writes to shared state must hold
    a lock; abandonable threads need a generation fence in the locked
    result-write region."""

    def __init__(self, ctx: _ModuleCtx, cl: _ClassLocks, fn, qual: str,
                 rep: _Reporter):
        self.ctx = ctx
        self.cl = cl
        self.fn = fn
        self.qual = qual
        self.rep = rep
        self.local: set[str] = set()
        self.shared_writes: set[str] = set()   # self attrs written here

    def run(self):
        args = self.fn.args
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            self.local.add(a.arg)
        # Names assigned anywhere in the target are locals (Python scoping:
        # assignment without nonlocal makes the name local).
        for n in _own_walk(self.fn):
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    self._collect_local(t)
            elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
                self._collect_local(n.target)
            elif isinstance(n, ast.Nonlocal):
                for name in n.names:
                    self.local.discard(name)
        self.walk_block(self.fn.body, held=())

    def _collect_local(self, t):
        if isinstance(t, ast.Name):
            self.local.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self._collect_local(e)
        elif isinstance(t, ast.Starred):
            self._collect_local(t.value)

    def _is_lock_key(self, key: str | None) -> bool:
        return key is not None and key.startswith("self.") \
            and key.split(".")[1] in self.cl.locks

    def _shared_write_key(self, t) -> str | None:
        """Dotted key if ``t`` is a write to shared state (self attr /
        subscript on one, or a closure name), else None."""
        node = t.value if isinstance(t, ast.Subscript) else t
        key = _key_of(node)
        if key is None:
            return None
        root = key.split(".", 1)[0]
        if root == "self":
            return key
        if root not in self.local:
            return key
        return None

    def walk_block(self, stmts, held):
        for st in stmts:
            self.walk_stmt(st, held)

    def walk_stmt(self, st, held):
        if isinstance(st, ast.With):
            new = list(held)
            for item in st.items:
                key = _key_of(item.context_expr)
                if self._is_lock_key(key):
                    new.append(key)
            if len(new) > len(held) and new[-1] not in held:
                block_writes: list[tuple] = []
                self._scan_locked_block(st.body, block_writes)
                if self.cl.abandonable and block_writes \
                        and not self._has_fence(st.body):
                    self.rep.flag(
                        "stale-thread-write", st,
                        "result write in an abandonable thread (its "
                        "launcher joins with a timeout) lacks a generation "
                        "fence: a timed-out, abandoned run can still "
                        "publish its result — the PR 6 watchdog race")
            self.walk_block(st.body, tuple(new))
            return
        targets = []
        if isinstance(st, ast.Assign):
            targets = st.targets
        elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
            targets = [st.target]
        for t in targets:
            self._flag_write(t, st, held)
        if isinstance(st, ast.Expr) and isinstance(st.value, ast.Call):
            f = st.value.func
            if isinstance(f, ast.Attribute) and f.attr in _MUTATORS:
                key = self._shared_write_key(f.value)
                if key is not None and not held:
                    self._unlocked(key, st)
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.stmt):
                self.walk_stmt(child, held)
            elif hasattr(child, "body") and isinstance(
                    child, (ast.ExceptHandler,)):
                self.walk_block(child.body, held)

    def _flag_write(self, t, st, held):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self._flag_write(e, st, held)
            return
        key = self._shared_write_key(t)
        if key is None:
            return
        self.shared_writes.add(key)
        if not held:
            self._unlocked(key, st)

    def _unlocked(self, key, st):
        self.rep.flag(
            "unlocked-thread-write", st,
            f"'{key}' is written inside a background thread with no lock "
            "held — racing every reader in the launching thread")

    def _scan_locked_block(self, stmts, out):
        for n in stmts:
            for t in ([*n.targets] if isinstance(n, ast.Assign)
                      else [n.target] if isinstance(n, (ast.AugAssign,
                                                        ast.AnnAssign))
                      else []):
                key = self._shared_write_key(t) if not isinstance(
                    t, (ast.Tuple, ast.List)) else None
                if key is not None:
                    out.append((key, n))
            if isinstance(n, ast.Expr) and isinstance(n.value, ast.Call):
                f = n.value.func
                if isinstance(f, ast.Attribute) and f.attr in _MUTATORS:
                    key = self._shared_write_key(f.value)
                    if key is not None:
                        out.append((key, n))
            for child in ast.iter_child_nodes(n):
                if isinstance(child, ast.stmt):
                    self._scan_locked_block([child], out)
                elif isinstance(child, ast.ExceptHandler):
                    self._scan_locked_block(child.body, out)

    def _has_fence(self, stmts) -> bool:
        """A generation fence: an If comparing a plain name against
        shared state, whose body bails out (return/continue/raise)."""
        for n in stmts:
            if not isinstance(n, ast.If):
                continue
            cmp_ok = any(
                isinstance(c, ast.Compare)
                and any(isinstance(x, ast.Name)
                        for x in [c.left, *c.comparators])
                and any(isinstance(x, ast.Attribute)
                        for x in [c.left, *c.comparators])
                for c in ast.walk(n.test))
            bails = any(isinstance(x, (ast.Return, ast.Continue, ast.Raise))
                        for x in ast.walk(n))
            if cmp_ok and bails:
                return True
        return False


def _audit_guard_epochs(ctx: _ModuleCtx, fn, qual: str, rep: _Reporter):
    """The PR 9 class: a loop guard that raises on a mix of state sampled
    *before* the round's mutating call and state sampled after it."""
    for loop in ast.walk(fn):
        if not isinstance(loop, (ast.While, ast.For)):
            continue
        seq = loop.body
        assigned_at: dict[str, int] = {}
        mut_at: list[int] = []
        for i, st in enumerate(seq):
            if isinstance(st, ast.Expr) and isinstance(st.value, ast.Call):
                f = st.value.func
                if isinstance(f, ast.Attribute):
                    root = f.value
                    while isinstance(root, (ast.Attribute, ast.Subscript)):
                        root = root.value
                    if isinstance(root, ast.Name) and (
                            root.id == "self" or root.id in assigned_at):
                        mut_at.append(i)
            if isinstance(st, ast.Assign):
                for t in st.targets:
                    if isinstance(t, ast.Name):
                        assigned_at[t.id] = i
            if not isinstance(st, ast.If):
                continue
            if not any(isinstance(x, ast.Raise) for x in ast.walk(st)):
                continue
            muts_before = [m for m in mut_at if m < i]
            if not muts_before:
                continue
            last_mut = muts_before[-1]
            test = st.test
            conjuncts = (test.values if isinstance(test, ast.BoolOp)
                         and isinstance(test.op, ast.And) else [test])
            stale, fresh = [], False
            for c in conjuncts:
                names = {n.id for n in ast.walk(c)
                         if isinstance(n, ast.Name)
                         and not self_attr_root(n, c)}
                attrs = any(isinstance(n, ast.Attribute)
                            for n in ast.walk(c))
                stale_names = {n for n in names
                               if n in assigned_at
                               and assigned_at[n] < last_mut}
                fresh_names = {n for n in names
                               if n in assigned_at
                               and assigned_at[n] > last_mut}
                if attrs or fresh_names:
                    fresh = True
                    continue  # delta compares (before vs after) count fresh
                if stale_names:
                    stale.append((c, sorted(stale_names)))
            if stale and fresh:
                c, names = stale[0]
                mut_line = seq[last_mut].lineno
                rep.flag(
                    "guard-epoch-mix", st,
                    f"loop guard raises on {'/'.join(names)!s} sampled "
                    f"before the round's mutating call at line {mut_line}, "
                    "mixed with state sampled after it — the PR 9 "
                    "no-progress-guard race; sample every conjunct after "
                    "the round")


def self_attr_root(name_node, within):
    """True if ``name_node`` is the root of an Attribute chain (so it is
    the receiver, e.g. ``self`` in ``self.shared``, not a value read)."""
    for n in ast.walk(within):
        if isinstance(n, ast.Attribute) and n.value is name_node:
            return True
    return False


def _audit_locks(ctx: _ModuleCtx, tree, out: list[Finding],
                 waived: list[str], edges: set[tuple[str, str]],
                 inventory: dict):
    n_locks = n_threads = 0

    def walk_edges(fn, qual, lock_keys, cls_qual):
        # Lexical lock-nesting edges for the acquisition-order graph,
        # plus bare acquire() discipline lint — over *every* method.
        rep = _Reporter(ctx, qual, out, waived)

        def rec(stmts, held):
            for st in stmts:
                if isinstance(st, ast.With):
                    new = list(held)
                    for item in st.items:
                        key = _key_of(item.context_expr)
                        if key in lock_keys:
                            full = f"{cls_qual}.{key.split('.', 1)[1]}"
                            if held:
                                edges.add((held[-1], full))
                            new.append(full)
                    rec(st.body, tuple(new))
                    continue
                for node in ast.walk(st):
                    if isinstance(node, ast.Call) \
                            and isinstance(node.func, ast.Attribute) \
                            and node.func.attr in ("acquire", "release") \
                            and _key_of(node.func.value) in lock_keys:
                        rep.flag(
                            "bare-acquire", node,
                            f"bare .{node.func.attr}() on a lock — use a "
                            "with-block so the discipline is statically "
                            "checkable (and exception-safe)",
                            severity=Severity.WARN)
                for child in ast.iter_child_nodes(st):
                    if isinstance(child, ast.stmt):
                        rec([child], held)
                    elif isinstance(child, ast.ExceptHandler):
                        rec(child.body, held)

        rec(fn.body, ())

    def visit(node, prefix):
        nonlocal n_locks, n_threads
        for child in node.body if hasattr(node, "body") else ():
            if isinstance(child, ast.ClassDef):
                cl = _collect_class_locks(child, prefix)
                n_locks += len(cl.locks)
                n_threads += len(cl.thread_targets)
                lock_keys = {f"self.{a}" for a in cl.locks}
                for m in child.body:
                    if isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                        qual = f"{cl.qual}.{m.name}"
                        walk_edges(m, qual, lock_keys, cl.qual)
                        rep = _Reporter(ctx, qual, out, waived)
                        _audit_guard_epochs(ctx, m, qual, rep)
                for tname, tfn in cl.thread_targets.items():
                    qual = f"{cl.qual}.{tname}"
                    rep = _Reporter(ctx, qual, out, waived)
                    lw = _LockWalk(ctx, cl, tfn, qual, rep)
                    lw.run()
                    _check_cross_thread(ctx, child, cl, lw, out, waived)
                visit(child, cl.qual)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                rep = _Reporter(ctx, qual, out, waived)
                _audit_guard_epochs(ctx, child, qual, rep)

    visit(tree, "")
    inventory["locks"] = inventory.get("locks", 0) + n_locks
    inventory["threads"] = inventory.get("threads", 0) + n_threads


def _check_cross_thread(ctx, cls, cl, lw: _LockWalk, out, waived):
    """Attributes written by the thread target AND, un-locked, by other
    methods of the class: both sides of the race must hold the lock."""
    thread_attrs = {k for k in lw.shared_writes if k.startswith("self.")}
    if not thread_attrs:
        return
    target_names = {getattr(fn, "name", "") for fn in
                    cl.thread_targets.values()}
    for m in cls.body:
        if not isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if m.name in target_names:
            continue
        # Constructors run before any thread of this object can exist.
        if m.name in ("__init__", "__post_init__"):
            continue
        qual = f"{cl.qual}.{m.name}"
        rep = _Reporter(ctx, qual, out, waived)

        def rec(stmts, held, m=m, rep=rep):
            for st in stmts:
                if isinstance(st, ast.With):
                    new = held or any(
                        _key_of(item.context_expr) is not None
                        and _key_of(item.context_expr).startswith("self.")
                        and _key_of(item.context_expr).split(".")[1]
                        in cl.locks
                        for item in st.items)
                    rec(st.body, new)
                    continue
                targets = ([*st.targets] if isinstance(st, ast.Assign)
                           else [st.target]
                           if isinstance(st, (ast.AugAssign, ast.AnnAssign))
                           else [])
                flat = []
                for t in targets:
                    flat.extend(t.elts if isinstance(t, (ast.Tuple,
                                                         ast.List)) else [t])
                for t in flat:
                    node = t.value if isinstance(t, ast.Subscript) else t
                    key = _key_of(node)
                    if key in thread_attrs and not held:
                        rep.flag(
                            "unlocked-shared-write", st,
                            f"'{key}' is written by thread target "
                            f"'{cl.qual}' and here without the lock — "
                            "both sides of a cross-thread write must "
                            "synchronize")
                for child in ast.iter_child_nodes(st):
                    if isinstance(child, ast.stmt):
                        rec([child], held)
                    elif isinstance(child, ast.ExceptHandler):
                        rec(child.body, held)

        rec(m.body, False)


def _cycle(edges: set[tuple[str, str]]) -> list[str] | None:
    graph: dict[str, set[str]] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
    seen: dict[str, int] = {}  # 1 = in stack, 2 = done

    def dfs(n, path):
        seen[n] = 1
        for m in graph.get(n, ()):
            if seen.get(m) == 1:
                return path[path.index(n):] + [m] if n in path else [n, m]
            if seen.get(m) is None:
                got = dfs(m, path + [m])
                if got:
                    return got
        seen[n] = 2
        return None

    for n in list(graph):
        if seen.get(n) is None:
            got = dfs(n, [n])
            if got:
                return got
    return None


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def run_on_sources(sources: dict[str, str]) -> list[Finding]:
    """Audit a {repo-path: source-text} mapping (real tree or fixtures)."""
    registry = collect_registry(sources)
    out: list[Finding] = []
    waived: list[str] = []
    edges: set[tuple[str, str]] = set()
    inventory: dict = {}
    total_sites = 0
    for rel, src in sources.items():
        try:
            tree = ast.parse(src)
        except SyntaxError as e:
            out.append(error(PASS, f"{rel}:<module>",
                             f"[parse] source does not parse: {e}"))
            continue
        ctx = _ModuleCtx(rel, src, registry)
        total_sites += _audit_donation(ctx, tree, out, waived)
        _audit_locks(ctx, tree, out, waived, edges, inventory)

    cyc = _cycle(edges)
    if cyc:
        out.append(error(
            PASS, "src/repro/analysis/hostsafety.py:lock-order",
            f"[lock-cycle] lock acquisition order has a cycle: "
            f"{' -> '.join(cyc)} — two threads taking these in opposite "
            "order deadlock"))
    n_err = sum(1 for f in out if f.severity >= Severity.ERROR)
    n_don_waived = sum(1 for w in waived if "[use-after-donate]" in w)
    out.append(info(
        PASS, "src/repro/analysis/hostsafety.py:donation-lifetime",
        f"{len(registry.attr_donors)} donating attributes + "
        f"{len(registry.factories)} donating factories derived from the "
        f"AST; {total_sites} donating call sites dataflow-walked, "
        f"{n_don_waived} waived, {n_err} violations",
        donors=len(registry.attr_donors) + len(registry.factories),
        sites=total_sites, waived=n_don_waived))
    out.append(info(
        PASS, "src/repro/analysis/hostsafety.py:lock-discipline",
        f"{inventory.get('locks', 0)} locks, "
        f"{inventory.get('threads', 0)} thread targets inventoried; "
        f"{len(edges)} nested acquisition edge(s), "
        f"{'CYCLE' if cyc else 'acyclic'}",
        locks=inventory.get("locks", 0),
        threads=inventory.get("threads", 0), edges=len(edges)))
    for w in waived:
        out.append(info(PASS,
                        "src/repro/analysis/hostsafety.py:waivers",
                        f"waiver: {w}"))
    return out


def derived_registry() -> DonationRegistry:
    """The donation registry derived from the real tree (for the
    cross-check against ``audit_jit_entrypoints`` declarations)."""
    return collect_registry(_read_tree_sources())


def _read_tree_sources() -> dict[str, str]:
    sources = {}
    for rel in HOST_MODULES:
        p = _REPO / rel
        if p.exists():
            sources[rel] = p.read_text()
    return sources


def run(cfg=None) -> list[Finding]:
    """Audit the real tree.  ``cfg`` is ignored: host-tier safety is a
    property of the source, not of any model configuration."""
    del cfg
    return run_on_sources(_read_tree_sources())
