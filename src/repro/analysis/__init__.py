"""repro.analysis: static audits over jaxpr/HLO — run before anything does.

Passes (each ``repro.analysis.<name>.run(cfg) -> list[Finding]``):

* ``hostsafety``  — jax-free AST audit of the host tier: donated-buffer
  lifetimes at every jit call site, and lock discipline across the
  watchdog/saver/monitor threads (``JAX_FREE = True`` — runs before
  anything imports jax, let alone compiles);
* ``resources``   — Pallas VMEM footprints vs the per-core budget (pure
  shape math over declared kernel geometry);
* ``ringslack``   — local-attention ring slack for windowed decode;
* ``dtype_flow``  — bf16 I/O contract, caller-side upcast lint, f32
  state/accumulation witnesses;
* ``collectives`` — per-mesh-axis collective traffic: gather ban,
  summary-size budgets, cost-model cross-check;
* ``donation``    — every registered serve/train jit shows
  ``input_output_alias`` in its compiled HLO;
* ``retrace``     — serve-loop jits compile once per shape bucket.

CLI: ``python -m repro.analysis --arch rwkv6-1.6b [--strict] [--json]``;
``--passes hostsafety --strict`` is the jax-free tier-1 lane 0.

This module imports lazily (no jax at import time) so the CLI can
configure fake devices before jax initializes.
"""

from __future__ import annotations

_LAZY = {
    "Finding": ("repro.analysis.findings", "Finding"),
    "Severity": ("repro.analysis.findings", "Severity"),
    "errors": ("repro.analysis.findings", "errors"),
    "format_table": ("repro.analysis.findings", "format_table"),
    "DEFAULT_ARCHS": ("repro.analysis.registry", "DEFAULT_ARCHS"),
    "PASS_MODULES": ("repro.analysis.registry", "PASS_MODULES"),
    "jit_entries": ("repro.analysis.registry", "jit_entries"),
    "run_passes": ("repro.analysis.registry", "run_passes"),
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod, attr = _LAZY[name]
        return getattr(importlib.import_module(mod), attr)
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")
