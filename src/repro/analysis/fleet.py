"""Fleet-readiness audit: the static contracts snapshot handoff rests on.

A replica fleet (:mod:`repro.serve.fleet`) only delivers its guarantee —
kill a replica mid-decode, every in-flight stream finishes bit-identical
on survivors — if three engine-side contracts hold.  Each is checkable
by tracing, without running a fleet:

* **Replica entrypoints are donation-audited.**  Every jit a replica
  dispatches must be registered with the donation pass (a fleet
  multiplies any per-dispatch copy by N replicas), and the shadow
  checksum entry must be registered *read-only* (``donated=None``): it
  recomputes checksums over live state the serve loop still owns, so an
  aliased lowering there would consume the replica's decode state
  mid-session.
* **Checksum emission is present in the window and admit jits.**  The
  silent-corruption chain (exit(n) == entry(n+1)) only exists if every
  state-mutating dispatch emits per-slot entry/exit checksums as its
  trailing outputs — (B,) ``uint32`` each, the exact-equality integer
  wraparound sums.  A refactor that drops them reverts detection to
  ``isfinite``-only without failing any dispatch.
* **Handoff meta is well-formed.**  A router hands off from a dead
  replica's snapshot after validating its ``meta`` vector; the engine's
  :meth:`~repro.serve.engine.ServeEngine._serve_meta` layout and the
  fleet's :data:`~repro.serve.fleet.META_LEN` parser must agree on
  length and field positions (request count at index 3 is what stops a
  fleet resuming the wrong serve's streams).
"""

from __future__ import annotations

from repro.analysis.findings import Finding, error, info

PASS = "fleet"
LOCATION = "src/repro/serve/fleet.py:FleetRouter"

#: Entries whose trailing two outputs must be the (B,) uint32 entry/exit
#: checksum pair.
CHECKSUM_ENTRIES = ("serve.serve_window", "serve.admit",
                    "serve.paged_window", "serve.paged_admit")


def run(cfg) -> list[Finding]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.model import model as M
    from repro.serve import engine as E
    from repro.serve import fleet as F

    rcfg = cfg.reduced()
    if rcfg.frontend or rcfg.is_enc_dec:
        return [info(
            PASS, LOCATION,
            f"{cfg.name}: frontend/enc-dec engines are not fleet-served "
            f"(token-only replicas)",
        )]

    findings: list[Finding] = []
    batch = 2
    entries = {e.name: e for e in E.audit_jit_entrypoints(rcfg, batch=batch)}

    shadow = entries.get("serve.shadow_checksum")
    if shadow is None:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: shadow-checksum jit is not registered for the "
            f"donation audit — the spot-check path is un-audited",
        ))
    elif shadow.donated is not None:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: shadow-checksum entry registered as donating "
            f"{shadow.donated!r} — it must be read-only (donated=None) or "
            f"the spot check consumes the live decode state",
        ))

    for name in CHECKSUM_ENTRIES:
        e = entries.get(name)
        if e is None:
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: {name} is not registered — replica "
                f"entrypoint missing from the donation audit",
            ))
            continue
        out = jax.eval_shape(e.fn, *e.args)
        tail = out[-2:] if isinstance(out, tuple) and len(out) >= 2 else ()
        bad = [t for t in tail
               if getattr(t, "shape", None) != (batch,)
               or getattr(t, "dtype", None) != jnp.uint32]
        if len(tail) != 2 or bad:
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: {name} does not emit the trailing (B,) "
                f"uint32 entry/exit checksum pair — silent-corruption "
                f"chaining is broken for this dispatch",
            ))

    eng = E.ServeEngine(rcfg, params=M.abstract_params(rcfg))
    meta = eng._serve_meta(batch, 4, 32, 7, 0, None)
    if meta.shape != (F.META_LEN,) or meta.dtype != np.int64:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: snapshot meta is {meta.dtype}{meta.shape}, the "
            f"fleet handoff parser expects int64 ({F.META_LEN},) — "
            f"read_snapshot_host would reject every snapshot",
        ))
    elif [int(m) for m in meta[:5]] != [batch, 4, 32, 7, 0]:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: snapshot meta field order changed "
            f"({meta.tolist()[:5]} for b=2 k=4 iw=32 n=7 seed=0) — the "
            f"handoff validator reads the request count at index 3 and "
            f"would trust the wrong field",
        ))

    if not findings:
        findings.append(info(
            PASS, LOCATION,
            f"{cfg.name}: {len(CHECKSUM_ENTRIES)} replica dispatch jits "
            f"emit checksum pairs, shadow checksum is read-only, handoff "
            f"meta layout matches the fleet parser",
            checksum_entries=len(CHECKSUM_ENTRIES),
        ))
    return findings
