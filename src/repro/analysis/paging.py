"""Paged-KV audit: page-table well-formedness and pool byte budgets.

The paged serve engine replaces per-slot dense KV rings with pooled
pages (:mod:`repro.serve.paging`).  Its failure modes are silent: a page
mapped by two live slots corrupts both streams with no error, a freed
page still reachable from an active row resurrects stale (or poisoned)
KV, and a mis-sized pool quietly forfeits the footprint win the pool
exists for.  This pass proves the invariants statically — abstract
shapes and host-side controller bookkeeping only, nothing executes on
device:

* **geometry** — every KV node's page table covers exactly its dense-
  equivalent view (``nl == ceil(s_view / page_size)``), pools reserve
  the null page, and prefix *sharing* is only offered on nodes that can
  never wrap (``s_view == max_len``);
* **audit liveness** — the controller's page-table audit actually fires
  on each class of corruption (double-map, freed-page reach, leak),
  probed by injecting each one into a mock table;
* **bytes** — a pool sized to the modeled pages-in-flight high-water
  mark (:func:`repro.core.cost_model.serve_paged_pool`) stays strictly
  below the dense ``slots × max_len`` footprint at full config shapes.
"""

from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding, error, info

PASS = "paging"
LOCATION = "src/repro/serve/paging.py:PagedController"

#: Reference ragged workload (prompt, budget) for the byte model —
#: spread over the position range the way the serve bench's specs are.
_WORKLOAD = [(48, 80), (200, 56), (24, 16), (96, 160), (130, 24),
             (60, 100), (300, 40), (16, 48)]


def _mock_state(controller, tables_by_slot):
    """The abstract paged state with page tables materialized from the
    controller's admission rows — what the audit walks; ``k``/``v`` stay
    ShapeDtypeStructs (nothing device-side)."""
    from repro.model.attention import PagedKVCache
    from repro.serve import paging as P

    nodes, treedef = P.flatten_nodes(controller._abstract)
    for gi, ni in enumerate(controller.kv_index):
        node = nodes[ni]
        tbl = np.full((controller.batch, controller.geoms[gi].nl), -1,
                      np.int32)
        for slot, rows in tables_by_slot.items():
            tbl[slot] = rows[gi]
        nodes[ni] = PagedKVCache(node.k, node.v, tbl, node.length,
                                 node.s_view, node.page_size)
    return treedef.unflatten(nodes)


def run(cfg, *, batch: int = 4, max_len: int = 512,
        page_size: int = 32) -> list[Finding]:
    """Audit the paged-KV contracts for ``cfg`` at serving shapes."""
    from repro.core import cost_model as CM
    from repro.model import model as M
    from repro.serve import paging as P

    findings: list[Finding] = []
    spec = M.PageSpec(page_size=page_size, shared_pages=2)
    abstract = M.abstract_decode_state(
        cfg, batch=batch, max_len=max_len,
        insert_window=page_size, paged=spec,
    )
    ctl = P.PagedController(cfg, abstract, batch=batch, max_len=max_len,
                            shared_map={0: (1, 2)})
    ctl._abstract = abstract
    if not ctl.geoms:
        return [info(
            PASS, LOCATION,
            f"{cfg.name}: no attention KV state — paging trivially holds",
        )]

    # -- geometry ---------------------------------------------------------
    for gi, g in enumerate(ctl.geoms):
        if g.nl != -(-g.s_view // g.page_size):
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: node{gi} page table has {g.nl} entries for "
                f"a {g.s_view}-position view of {g.page_size}-token pages",
                node=gi))
        if g.page_size % 32:
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: node{gi} page size {g.page_size} is not a "
                f"multiple of the 32-token admit bucket", node=gi))
        share_ok = g.role == ("share" if g.s_view == max_len else "copy")
        if not share_ok:
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: node{gi} (s_view={g.s_view}, "
                f"max_len={max_len}) has role {g.role!r} — prefix pages "
                f"may only be shared on views that can never wrap",
                node=gi))

    # -- controller schedule: admissions, a free, a recycle ---------------
    tables: dict[int, list] = {}
    for slot, total in ((0, 3 * page_size), (1, 2 * page_size)):
        alloc = ctl.try_admit(slot, total, None, 0)
        if alloc is None:
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: dense-equivalent pool refused slot {slot} "
                f"({total} positions) with everything free"))
            return findings
        tables[slot] = alloc[0]
    ctl.free_slot(0)
    del tables[0]
    msgs = ctl.audit(_mock_state(ctl, tables),
                     np.asarray([False, True] + [False] * (batch - 2)),
                     [-1, 1] + [-1] * (batch - 2))
    if msgs:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: clean admit/free schedule flagged: {msgs[0]}",
            violations=len(msgs)))

    # -- audit liveness: each corruption class must be caught -------------
    re_alloc = ctl.try_admit(0, 3 * page_size, None, 0)
    tables[0] = re_alloc[0]
    active = np.asarray([True, True] + [False] * (batch - 2))
    probes = {
        # Slot 1's first page also mapped by slot 0's row -> double-map.
        "double-mapped": {0: [np.concatenate([r[:1], t[1:]])
                              for r, t in zip(tables[1], tables[0])],
                          1: tables[1]},
    }
    for name, tbl in probes.items():
        ctl.violations.clear()
        if not ctl.audit(_mock_state(ctl, tbl), active, [0, 1]):
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: audit did not flag a {name} page — the "
                f"check is dead"))
    # Freed-page reach: free slot 0 but leave its row mapped and active.
    ctl.free_slot(0)
    ctl.violations.clear()
    if not any("freed" in m or "leaked" in m for m in ctl.audit(
            _mock_state(ctl, tables), active, [0, 1])):
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: audit did not flag an active row reaching a "
            f"freed page — the check is dead"))
    # Leak: owner says a slot holds pages, slot table says no request.
    leak = ctl.try_admit(0, 3 * page_size, None, 0)
    ctl.violations.clear()
    if not any("leaked" in m for m in ctl.audit(
            _mock_state(ctl, {0: leak[0], 1: tables[1]}),
            np.asarray([False, True] + [False] * (batch - 2)),
            [-1, 1] + [-1] * (batch - 2))):
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: audit did not flag pages owned by a slot with "
            f"no request — leaked pages are invisible"))
    ctl.free_slot(0)
    ctl.violations.clear()
    # The engine's release discipline feeds this audit: with every
    # free_slot honored, a full admit/free cycle must end page-clean.
    leftover = ctl.audit(
        _mock_state(ctl, {1: tables[1]}),
        np.asarray([False, True] + [False] * (batch - 2)),
        [-1, 1] + [-1] * (batch - 2))
    if leftover:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: pages survived their slot's release: "
            f"{leftover[0]}", violations=len(leftover)))

    # -- bytes: modeled-peak pool strictly below the dense footprint ------
    prompts = [p for p, _ in _WORKLOAD]
    budgets = [t for _, t in _WORKLOAD]
    peak, dense_pages = CM.serve_paged_pool(
        prompts, budgets, slots=batch, page_size=page_size)
    sized = P.PagedController(
        cfg,
        M.abstract_decode_state(
            cfg, batch=batch, max_len=max_len, insert_window=page_size,
            paged=M.PageSpec(page_size=page_size, private_pages=peak),
        ),
        batch=batch, max_len=max_len)
    pool_b, dense_b = sized.pool_bytes(), sized.dense_bytes()
    if pool_b >= dense_b:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: pool sized to the modeled peak "
            f"({peak}/{dense_pages} pages) still needs {pool_b} bytes vs "
            f"{dense_b} dense — the pool never wins at these shapes",
            pool_bytes=pool_b, dense_bytes=dense_b))

    if not findings:
        findings.append(info(
            PASS, LOCATION,
            f"{cfg.name}: page tables well-formed over "
            f"{len(ctl.geoms)} KV nodes, audit fires on double-map / "
            f"freed-reach / leak, and a peak-sized pool "
            f"({peak}/{dense_pages} pages) costs {pool_b} bytes vs "
            f"{dense_b} dense",
            kv_nodes=len(ctl.geoms), peak_pages=peak,
            dense_pages=dense_pages, pool_bytes=pool_b,
            dense_bytes=dense_b))
    return findings
