"""Structured findings: the one result type every analysis pass returns.

A pass *traces* a callable (it never executes device code) and reports
what it proved or failed to prove as a list of :class:`Finding`s — each
with a severity, the pass that produced it, a repo-path-like location
(``src/repro/serve/engine.py:ServeEngine._serve_window``) so the reader
can jump to the contract being checked, a one-line message, and optional
numeric metrics (byte counts, cache sizes, divergence percentages).

Severity contract:

* ``ERROR`` — a static invariant is violated: shipping this would
  regress a guarantee the repo relies on (missing donation, a gather
  over the seq axis, a VMEM blowout).  The CLI exits nonzero.
* ``WARN`` — suspicious but not provably wrong (e.g. a chunk request
  the dispatch had to adjust).  ``--strict`` promotes these to the
  exit code.
* ``INFO`` — the positive evidence: what was audited and the numbers
  that came out (counted bytes, cache sizes), kept in the table so a
  clean run still shows *what* was proven, not just silence.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any


class Severity(enum.IntEnum):
    INFO = 0
    WARN = 1
    ERROR = 2

    def __str__(self) -> str:  # table cells: "error", not "Severity.ERROR"
        return self.name.lower()


@dataclasses.dataclass(frozen=True)
class Finding:
    """One fact a pass established (or failed to establish)."""

    pass_name: str           # e.g. "collectives", "donation"
    severity: Severity
    location: str            # repo-path-like: "src/.../engine.py:ServeEngine._serve_window"
    message: str
    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)

    def with_pass(self, pass_name: str) -> "Finding":
        return dataclasses.replace(self, pass_name=pass_name)


def info(pass_name: str, location: str, message: str, **metrics) -> Finding:
    return Finding(pass_name, Severity.INFO, location, message, metrics)


def warn(pass_name: str, location: str, message: str, **metrics) -> Finding:
    return Finding(pass_name, Severity.WARN, location, message, metrics)


def error(pass_name: str, location: str, message: str, **metrics) -> Finding:
    return Finding(pass_name, Severity.ERROR, location, message, metrics)


def errors(findings: list[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity >= Severity.ERROR]


def worst(findings: list[Finding]) -> Severity:
    return max((f.severity for f in findings), default=Severity.INFO)


def format_table(findings: list[Finding], *, title: str | None = None) -> str:
    """Render findings as a fixed-width table, most severe first."""
    lines: list[str] = []
    if title:
        lines.append(title)
    if not findings:
        lines.append("  (no findings)")
        return "\n".join(lines)
    rows = []
    for f in sorted(findings, key=lambda f: (-int(f.severity), f.pass_name)):
        met = " ".join(f"{k}={v}" for k, v in f.metrics.items())
        rows.append((str(f.severity), f.pass_name, f.location,
                     f.message + (f"  [{met}]" if met else "")))
    widths = [max(len(r[i]) for r in rows) for i in range(3)]
    for sev, pas, loc, msg in rows:
        lines.append(
            f"  {sev:<{widths[0]}}  {pas:<{widths[1]}}  {loc:<{widths[2]}}  {msg}"
        )
    return "\n".join(lines)
