"""Pass registry + audited-entrypoint aggregation.

Passes are registered here by name; jitted entrypoints are *not* — they
are declared next to the jits they describe
(``repro.serve.engine.audit_jit_entrypoints``,
``repro.train.step.audit_jit_entrypoints``) and aggregated by
:func:`jit_entries`, so adding a serve/train jit and registering it for
audit is one diff in one file.
"""

from __future__ import annotations

import importlib

from repro.analysis.findings import Finding

#: Arch families the CLI / tier-1 lane audit by default: one per layer
#: pattern family (pure RWKV, recurrent+local hybrid, local/global attn).
DEFAULT_ARCHS = ("rwkv6-1.6b", "recurrentgemma-2b", "gemma3-1b")

#: pass name -> module (each module exposes ``run(cfg) -> list[Finding]``
#: and a ``PASS`` constant matching its key here).  Ordered: the jax-free
#: host-tier AST audit first (it needs nothing to import, let alone
#: compile), pure shape math next, tracing passes after, the one
#: executing pass (retrace) last — so a host-code or geometry error
#: surfaces before anything compiles.
PASS_MODULES = {
    "hostsafety": "repro.analysis.hostsafety",
    "resources": "repro.analysis.resources",
    "ringslack": "repro.analysis.ringslack",
    "paging": "repro.analysis.paging",
    "dtype_flow": "repro.analysis.dtype_flow",
    "collectives": "repro.analysis.collectives",
    "donation": "repro.analysis.donation",
    "fleet": "repro.analysis.fleet",
    "retrace": "repro.analysis.retrace",
}


def get_pass(name: str):
    if name not in PASS_MODULES:
        raise KeyError(
            f"unknown analysis pass {name!r}; have {sorted(PASS_MODULES)}"
        )
    return importlib.import_module(PASS_MODULES[name])


def jit_entries(cfg):
    """Every registered jitted entrypoint for ``cfg`` (serve + train)."""
    from repro.serve import engine
    from repro.train import step

    return list(engine.audit_jit_entrypoints(cfg)) + list(
        step.audit_jit_entrypoints(cfg)
    )


def run_passes(cfg, passes=None) -> list[Finding]:
    """Run ``passes`` (default: all, in registry order) over ``cfg``."""
    names = list(PASS_MODULES) if passes is None else list(passes)
    findings: list[Finding] = []
    for name in names:
        findings += get_pass(name).run(cfg)
    return findings
