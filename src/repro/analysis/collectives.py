"""Collective-traffic audit: what crosses a mesh axis, proven from the jaxpr.

The paper's core claim is that a dataflow fabric makes communication
*statically knowable*; the jaxpr is where that property lives in JAX — a
collective primitive either appears with a token-sized operand or it does
not, before anything runs.  This pass walks a traced program and, per
mesh axis:

* forbids gather-class collectives (``all_gather`` / ``all_to_all``) —
  those are exactly the "regressed to re-gathering activations" failure
  the segment-summary protocol (kernels/wkv/seqpar) exists to avoid;
* bounds every point-to-point collective operand (``ppermute`` / ``psum``)
  by a caller-supplied element budget (``B·H·Dh²`` for WKV summaries);
* counts the total bytes crossing the axis and cross-checks them against
  the cost model (:func:`repro.core.cost_model.wkv_seqshard_traffic`),
  flagging divergence — so the model can no longer drift from the
  program it claims to describe.

This generalizes (and replaced) the hand-rolled walker that lived inline
in ``tests/test_multidevice.py``.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.analysis.findings import Finding, error, info

PASS = "collectives"

#: Gather-class collectives: moving one of these over a sequence axis
#: means token activations crossed the mesh — the protocol regressed.
GATHER_COLLECTIVES = ("all_gather", "all_to_all", "all_gather_invariant")

#: Point-to-point / reduction collectives the summary protocol is allowed
#: to use; their operands must stay summary-sized.
P2P_COLLECTIVES = ("ppermute", "psum", "psum_invariant")


def iter_eqns(jaxpr):
    """Yield every eqn in ``jaxpr`` and (recursively) in any sub-jaxpr
    reachable through eqn params (pjit bodies, scan bodies, custom_vjp
    closures, shard_map bodies, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for item in vals:
                sub = getattr(item, "jaxpr", item)
                if hasattr(sub, "eqns"):
                    yield from iter_eqns(sub)


def eqn_axes(eqn) -> tuple:
    """Mesh-axis names an eqn communicates over (collectives spell them
    ``axes`` or ``axis_name``, scalar or tuple)."""
    ax = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    return ax if isinstance(ax, tuple) else (ax,)


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective eqn over the audited axis."""

    primitive: str
    elements: int            # per-device elements moved (largest operand)
    shape: tuple[int, ...]
    reverse: bool = False    # ppermute running high->low shard index


def _closed(jaxpr):
    return getattr(jaxpr, "jaxpr", jaxpr)


def collect_collectives(closed, axis: str) -> list[CollectiveOp]:
    """Every collective over ``axis``, with its largest non-scalar operand.

    Scalar operands (e.g. the constant-folded ``psum(1)`` behind
    ``axis_size``) are ignored: they never reach the fabric.
    """
    ops = []
    for eqn in iter_eqns(_closed(closed)):
        name = eqn.primitive.name
        if name not in GATHER_COLLECTIVES + P2P_COLLECTIVES:
            continue
        if axis not in eqn_axes(eqn):
            continue
        sized = [
            tuple(v.aval.shape) for v in eqn.invars
            if hasattr(v, "aval") and v.aval.shape
        ]
        if not sized:
            continue
        shape = max(sized, key=lambda s: int(np.prod(s)))
        rev = False
        if name == "ppermute":
            rev = any(src > dst for src, dst in eqn.params.get("perm", ()))
        ops.append(CollectiveOp(name, int(np.prod(shape)), shape, rev))
    return ops


def has_reverse_hops(closed, axis: str) -> bool:
    """True iff some ppermute over ``axis`` runs high->low shard index —
    the device-space *reverse* elevator a transposed carry must contain."""
    return any(op.reverse for op in collect_collectives(closed, axis)
               if op.primitive == "ppermute")


def counted_axis_elements(closed, axis: str) -> int:
    """Per-device elements sent over ``axis``: the sum over collective
    eqns of their (largest) operand size — the static count the cost
    model's fabric-bytes term must match."""
    return sum(op.elements for op in collect_collectives(closed, axis))


def audit_collectives(closed, *, axis: str, max_elements: int,
                      what: str = "program",
                      location: str = "src/repro/kernels/wkv/seqpar.py:wkv_seqshard",
                      itemsize: int = 4,
                      require: bool = True) -> list[Finding]:
    """The per-axis budget audit (the former test_multidevice walker).

    Errors: a gather-class collective over ``axis``; a point-to-point
    operand above ``max_elements``; no collectives at all when
    ``require`` (a program claiming to communicate but not communicating
    usually means the audit traced the wrong thing).
    """
    findings: list[Finding] = []
    ops = collect_collectives(closed, axis)
    gathers = [op for op in ops if op.primitive in GATHER_COLLECTIVES]
    for op in gathers:
        findings.append(error(
            PASS, location,
            f"{what}: gather collective '{op.primitive}' over axis "
            f"'{axis}' moves {op.elements} elements {op.shape} — token "
            f"data crossed the mesh",
            elements=op.elements,
        ))
    p2p = [op for op in ops if op.primitive in P2P_COLLECTIVES]
    if require and not ops:
        findings.append(error(
            PASS, location,
            f"{what}: no collectives found over axis '{axis}' — the "
            f"audited trace does not communicate on this axis",
        ))
        return findings
    biggest = max((op.elements for op in p2p), default=0)
    if biggest > max_elements:
        off = [op for op in p2p if op.elements > max_elements]
        findings.append(error(
            PASS, location,
            f"{what}: collective operand of {biggest} elements exceeds "
            f"the per-hop budget {max_elements} "
            f"({[(o.primitive, o.shape) for o in off]})",
            elements=biggest, budget=max_elements,
        ))
    per_dev = sum(op.elements for op in p2p)
    findings.append(info(
        PASS, location,
        f"{what}: {len(p2p)} point-to-point collectives over '{axis}', "
        f"largest operand {biggest} <= budget {max_elements}",
        collectives=len(p2p), max_elements=biggest,
        per_device_bytes=per_dev * itemsize,
    ))
    return findings


def crosscheck_cost_model(closed, *, axis: str, b: int, h: int, t: int,
                          dh: int, n_dev: int, itemsize: int = 4,
                          tolerance: float = 0.05,
                          location: str = "src/repro/core/cost_model.py:wkv_seqshard_traffic",
                          what: str = "forward") -> list[Finding]:
    """Counted bytes (from the jaxpr) vs modeled bytes (cost model).

    The cost model's ``wkv_seqshard_traffic`` "direct" variant claims
    ``hops·(Dh²+Dh) + Dh²`` elements per (batch, head) per device cross
    the axis.  This pass counts the actual collective operands in the
    traced program and flags divergence above ``tolerance`` — the drift
    alarm that keeps BENCH notes honest.
    """
    from repro.core import cost_model

    counted = counted_axis_elements(closed, axis) * itemsize * n_dev
    modeled = cost_model.wkv_seqshard_traffic(
        b, h, t, dh, n_dev, itemsize=itemsize
    )[2].traffic.fabric_bytes
    div = abs(counted - modeled) / max(modeled, 1)
    msg = (f"{what}: counted {counted} B over '{axis}' vs modeled "
           f"{modeled} B (divergence {div * 100:.2f}%)")
    metrics = dict(counted_bytes=counted, modeled_bytes=modeled,
                   divergence_pct=round(div * 100, 3), n_dev=n_dev)
    if div > tolerance:
        return [error(PASS, location,
                      msg + f" — cost model drifted past {tolerance:.0%}",
                      **metrics)]
    return [info(PASS, location, msg, **metrics)]


# --------------------------------------------------------------------------
# Pass runner: audit the registered seq-parallel entrypoint for a config
# --------------------------------------------------------------------------

def run(cfg, *, mesh=None, seq_axis: str = "seq",
        tolerance: float = 0.05) -> list[Finding]:
    """Audit the sequence-parallel WKV protocol for ``cfg``.

    Traces (never executes) ``wkv_seqshard`` forward and backward over a
    mesh of all visible devices, bounds every seq-axis collective by the
    ``B·H·Dh²`` summary budget, requires reverse hops in the backward,
    and cross-checks counted vs modeled bytes (the latter only on >= 2
    devices, where the hop count is non-degenerate).

    Families with no recurrent WKV layers have no registered collective
    entrypoints — that is reported as an info finding, not silence.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.model.recurrent import RWKV_HEAD_DIM

    loc = "src/repro/kernels/wkv/seqpar.py:wkv_seqshard"
    if "rwkv" not in tuple(cfg.pattern):
        return [info(
            PASS, loc,
            f"{cfg.name}: no seq-parallel collective entrypoints "
            f"registered for pattern {tuple(cfg.pattern)}",
        )]

    from repro.kernels.wkv.seqpar import wkv_seqshard

    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (seq_axis,))
    n_dev = math.prod(mesh.shape.values())
    dh = RWKV_HEAD_DIM
    b, h = 1, max(1, cfg.d_model // dh)
    chunk = 8
    t = 2 * chunk * n_dev
    sds = jax.ShapeDtypeStruct
    args = (
        sds((b, h, t, dh), jnp.float32),   # r
        sds((b, h, t, dh), jnp.float32),   # k
        sds((b, h, t, dh), jnp.float32),   # v
        sds((b, h, t, dh), jnp.float32),   # w
        sds((h, dh), jnp.float32),         # u
        sds((b, h, dh, dh), jnp.float32),  # h0
    )

    def shard(*a):
        return wkv_seqshard(*a, mesh=mesh, seq_axis=seq_axis, chunk=chunk,
                            use_kernel=False)

    def loss(*a):
        o, s = shard(*a)
        return o.sum() + s.sum()

    budget = b * h * dh * dh
    findings: list[Finding] = []
    fwd = jax.make_jaxpr(shard)(*args)
    findings += audit_collectives(
        fwd, axis=seq_axis, max_elements=budget,
        what=f"{cfg.name} forward", location=loc)
    bwd = jax.make_jaxpr(jax.grad(loss, argnums=tuple(range(6))))(*args)
    findings += audit_collectives(
        bwd, axis=seq_axis, max_elements=budget,
        what=f"{cfg.name} backward", location=loc)
    # Reverse hops only exist with >= 2 shards (a 1-device perm is the
    # identity, so direction is undefined there).
    if n_dev >= 2 and not has_reverse_hops(bwd, seq_axis):
        findings.append(error(
            PASS, loc,
            f"{cfg.name} backward: no reverse-direction ppermute hops — "
            f"the transposed carry is not a reverse elevator",
        ))
    if n_dev >= 2:
        findings += crosscheck_cost_model(
            fwd, axis=seq_axis, b=b, h=h, t=t, dh=dh, n_dev=n_dev,
            tolerance=tolerance, what=f"{cfg.name} forward")
    else:
        findings.append(info(
            PASS, loc,
            f"{cfg.name}: single device — counted "
            f"{counted_axis_elements(fwd, seq_axis) * 4} B/device over "
            f"'{seq_axis}'; cost-model cross-check needs >= 2 devices",
        ))
    return findings
