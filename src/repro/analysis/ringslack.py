"""Local-attention ring-slack checker: windowed decode must never wrap.

A window of ``t`` tokens inserted into a local-attention ring of ``S``
slots is exact iff ``S >= attn_window + t - 1`` — or the ring is capped
at ``max_len`` and can never wrap at all.  ``init_decode_state`` sizes
the slack via ``insert_window``; the failure mode of building a state
too small is silent (earlier in-window queries attend to evicted slots:
corrupt logits, no error).

The rule itself lives here — :func:`ring_slack_violations` is the single
source of truth — and ``model.decode_step`` delegates to it at trace
time, so the serving path and the static audit can never disagree.
"""

from __future__ import annotations

from repro.analysis.findings import Finding, error, info

PASS = "ringslack"
LOCATION = "src/repro/model/model.py:_check_ring_slack"


def ring_slack_violations(cfg, state, t: int,
                          max_len: int | None) -> list[str]:
    """Every ring-contract violation in ``state`` for a ``t``-token
    window, as human-readable messages (empty list = contract holds).

    ``max_len=None`` (caller didn't vouch for the cap) treats any
    slack-deficient ring as a violation.
    """
    from repro.model import transformer as tf
    from repro.model.model import KVCache, PagedKVCache

    if t <= 1 or state is None or cfg.attn_window is None:
        return []
    pattern, n_periods, remainder = tf.plan_groups(cfg)
    layers = []
    if n_periods > 0 and state.get("scanned") is not None:
        layers += list(zip(pattern, state["scanned"]))
    layers += list(zip(remainder, state["remainder"]))
    window = cfg.attn_window
    msgs = []
    for kind, st in layers:
        if kind != "local" or not isinstance(st, (KVCache, PagedKVCache)):
            continue
        # A paged node's ring extent is its dense-equivalent view size
        # (the page table only changes *where* slots live, not how many
        # there are); a dense node's is its sequence axis.
        s_ring = (st.s_view if isinstance(st, PagedKVCache)
                  else st.k.shape[-2])
        if s_ring >= window + t - 1:
            continue                       # enough slack for this window
        if max_len is not None and s_ring >= max_len:
            continue                       # capped ring: never wraps
        msgs.append(
            f"decode window of {t} tokens would wrap the local-attention "
            f"ring of layer kind 'local' (cache {tuple(st.k.shape)}, "
            f"attn_window={window}): earlier in-window queries would "
            f"attend to evicted slots.  Build the state with "
            f"init_decode_state(insert_window >= {t}) (ring >= "
            f"{window + t - 1} slots) or pass max_len= to vouch that the "
            f"ring is capped at the position limit."
        )
    return msgs


def run(cfg, *, batch: int = 2, max_len: int = 128,
        windows: tuple[int, ...] = (1, 4, 8)) -> list[Finding]:
    """Audit the ring contract for every window size a serve loop uses.

    Builds abstract decode states exactly the way the engine does —
    through the late-bound ``model.abstract_decode_state`` with
    ``insert_window=t`` — and requires zero violations; then probes the
    negative direction (a state built *without* slack must be rejected
    for multi-token windows), so the guard itself is proven live, not
    just never-triggered.
    """
    from repro.model import model as M

    rcfg = cfg.reduced()
    findings: list[Finding] = []
    if rcfg.attn_window is None:
        return [info(
            PASS, LOCATION,
            f"{cfg.name}: no local-attention layers — ring contract "
            f"trivially holds",
        )]

    for t in windows:
        state = M.abstract_decode_state(
            rcfg, batch=batch, max_len=max_len, insert_window=t
        )
        msgs = ring_slack_violations(rcfg, state, t, max_len)
        if msgs:
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: state built with insert_window={t} still "
                f"violates the ring contract: {msgs[0]}",
                window=t,
            ))
    # The guard must actually fire: a slack-less ring + a window wider
    # than the remaining slack, with no max_len vouching for the cap.
    t_probe = max(windows)
    if t_probe > 1:
        bare = M.abstract_decode_state(
            rcfg, batch=batch, max_len=max_len, insert_window=1
        )
        ring = min(max_len, rcfg.attn_window)
        if ring < max_len and not ring_slack_violations(
            rcfg, bare, t_probe, None
        ):
            findings.append(error(
                PASS, LOCATION,
                f"{cfg.name}: guard did not flag a {t_probe}-token window "
                f"into a slack-less ring of {ring} slots — the trace-time "
                f"check is dead",
                window=t_probe,
            ))
    if not findings:
        findings.append(info(
            PASS, LOCATION,
            f"{cfg.name}: ring contract holds for windows {windows} "
            f"(attn_window={rcfg.attn_window}, max_len={max_len}) and the "
            f"guard fires on slack-less states",
            windows=list(windows),
        ))
    return findings
