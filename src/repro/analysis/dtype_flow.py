"""Dtype-flow lint: bf16 stays bf16 on kernel I/O, f32 stays f32 in state.

The kernels advertise a precise dtype contract (see ``wkv_fused``'s
docstring): activations may arrive in bf16 and come back in bf16 — every
backend accumulates in float32 *internally* — and recurrent decode state
(WKV S, RG-LRU h) is float32 end to end.  Two silent regressions break
that contract without breaking any test:

* a caller-side ``astype(float32)`` sneaks onto the kernel I/O path,
  doubling the unavoidable HBM traffic (``cost_model.wkv_traffic``'s
  ``io`` term) for zero numerical benefit;
* the internal f32 accumulation is dropped, so long sequences quietly
  lose precision in the recurrence.

This pass traces (never executes) the dispatch entrypoints with bf16
activations and checks three things statically:

1. **I/O contract** (``jax.eval_shape``): bf16 in -> bf16 out, state out
   float32 — on both the jnp and Pallas backends.
2. **Upcast lint** (top-level jaxpr walk): no ``convert_element_type``
   bf16 -> f32 on an activation-sized operand *outside* the custom-vjp
   boundary.  Inside is the backend's business (that is the f32
   accumulation); outside is a caller paying double I/O.
3. **f32-accumulation witness** (full jaxpr walk): at least one
   bf16 -> f32 convert exists *somewhere* in the traced program — the
   static shadow of "accumulates in float32 internally".

Plus the state-dtype audit: every ``RecState.h`` leaf in the abstract
decode state must be float32 (``_layer_state_shape`` builds it; a frozen
slot must round-trip bit-identically even under bf16 models).
"""

from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding, error, info

PASS = "dtype_flow"

#: Primitives whose sub-jaxprs are the *backend interior* — intentional
#: f32 accumulation lives there, so the upcast lint does not descend.
CUSTOM_BOUNDARIES = ("custom_vjp_call", "custom_jvp_call", "custom_lin")


def iter_top_eqns(jaxpr, *, boundaries: tuple = CUSTOM_BOUNDARIES):
    """Yield eqns reachable without crossing a custom-diff boundary
    (descends pjit/scan/etc. bodies, stops at custom_vjp/jvp interiors)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if any(eqn.primitive.name.startswith(b) for b in boundaries):
            continue
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for item in vals:
                sub = getattr(item, "jaxpr", item)
                if hasattr(sub, "eqns"):
                    yield from iter_top_eqns(sub, boundaries=boundaries)


def _converts(eqns, src, dst):
    """(shape, elements) of every convert_element_type src->dst in eqns."""
    import jax.numpy as jnp

    out = []
    for eqn in eqns:
        if eqn.primitive.name != "convert_element_type":
            continue
        if jnp.dtype(eqn.params.get("new_dtype")) != jnp.dtype(dst):
            continue
        v = eqn.invars[0]
        if not hasattr(v, "aval"):
            continue
        if jnp.dtype(v.aval.dtype) != jnp.dtype(src):
            continue
        shape = tuple(v.aval.shape)
        out.append((shape, int(np.prod(shape)) if shape else 1))
    return out


def lint_upcasts(closed, *, min_elements: int, what: str,
                 location: str) -> list[Finding]:
    """Error on any activation-sized bf16 -> f32 convert outside the
    custom-diff boundary of ``closed``."""
    import jax.numpy as jnp

    jaxpr = getattr(closed, "jaxpr", closed)
    ups = _converts(iter_top_eqns(jaxpr), jnp.bfloat16, jnp.float32)
    big = [(s, n) for s, n in ups if n >= min_elements]
    if big:
        return [error(
            PASS, location,
            f"{what}: caller-side bf16->f32 upcast of activation-sized "
            f"operand(s) {[s for s, _ in big]} on the kernel I/O path — "
            f"doubles HBM traffic for no numerical benefit",
            upcasts=len(big), largest=max(n for _, n in big),
        )]
    return [info(
        PASS, location,
        f"{what}: no activation-sized bf16->f32 upcasts outside the "
        f"kernel boundary",
    )]


def confirm_f32_accumulation(closed, *, what: str,
                             location: str) -> list[Finding]:
    """Require a bf16 -> f32 convert *somewhere* in the full trace — the
    static witness of internal float32 accumulation."""
    import jax.numpy as jnp

    from repro.analysis.collectives import iter_eqns

    jaxpr = getattr(closed, "jaxpr", closed)
    ups = _converts(iter_eqns(jaxpr), jnp.bfloat16, jnp.float32)
    if not ups:
        return [error(
            PASS, location,
            f"{what}: no bf16->f32 convert anywhere in the trace — the "
            f"backend is accumulating the recurrence in bf16",
        )]
    return [info(
        PASS, location,
        f"{what}: f32 accumulation confirmed "
        f"({len(ups)} internal upcast sites)",
        upcast_sites=len(ups),
    )]


def check_io_contract(fn, args, *, out_dtypes: tuple, what: str,
                      location: str) -> list[Finding]:
    """``jax.eval_shape`` the dispatch and compare leaf dtypes with the
    advertised contract (a tuple parallel to the flattened outputs)."""
    import jax
    import jax.numpy as jnp

    try:
        out = jax.eval_shape(fn, *args)
    except Exception as e:  # noqa: BLE001 — a broken trace IS a finding
        return [error(PASS, location,
                      f"{what}: failed to trace for dtype audit: {e!r}")]
    leaves = jax.tree.leaves(out)
    got = tuple(jnp.dtype(l.dtype) for l in leaves)
    want = tuple(jnp.dtype(d) for d in out_dtypes)
    if got != want:
        return [error(
            PASS, location,
            f"{what}: output dtypes {tuple(str(d) for d in got)} != "
            f"contract {tuple(str(d) for d in want)}",
        )]
    return [info(
        PASS, location,
        f"{what}: I/O contract holds "
        f"({' ,'.join(str(d) for d in want)})",
    )]


def audit_state_dtypes(cfg, *, batch: int = 2, max_len: int = 32,
                       location: str = "src/repro/model/model.py:_layer_state_shape",
                       ) -> list[Finding]:
    """Every RecState.h leaf in the abstract decode state must be f32."""
    import jax
    import jax.numpy as jnp

    from repro.model import model as M
    from repro.model.recurrent import RecState

    state = M.abstract_decode_state(cfg, batch=batch, max_len=max_len)
    bad, n_rec = [], 0
    for node in jax.tree.leaves(
        state, is_leaf=lambda x: isinstance(x, RecState)
    ):
        if not isinstance(node, RecState):
            continue
        n_rec += 1
        if jnp.dtype(node.h.dtype) != jnp.dtype(jnp.float32):
            bad.append(str(node.h.dtype))
    if bad:
        return [error(
            PASS, location,
            f"{cfg.name}: recurrent decode state h carried in {bad} — "
            f"must be float32 for bit-exact slot round-trips",
        )]
    if n_rec == 0:
        return []
    return [info(
        PASS, location,
        f"{cfg.name}: {n_rec} recurrent state group(s) carry h in float32",
        rec_groups=n_rec,
    )]


# --------------------------------------------------------------------------
# Pass runner
# --------------------------------------------------------------------------

def run(cfg, *, b: int = 1, t: int = 64, chunk: int = 16) -> list[Finding]:
    """Dtype-flow audit for ``cfg``'s kernel dispatch paths.

    The WKV entrypoint is late-bound through the module object so the
    audit sees exactly what the model would call (mutation tests — and
    real regressions — swap the attribute).
    """
    import jax
    import jax.numpy as jnp

    findings: list[Finding] = []
    pattern = tuple(cfg.pattern)
    sds = jax.ShapeDtypeStruct

    if "rwkv" in pattern:
        from repro.kernels.wkv import ops as wkv_ops
        from repro.model.recurrent import RWKV_HEAD_DIM

        loc = "src/repro/kernels/wkv/ops.py:wkv_fused"
        dh = RWKV_HEAD_DIM
        h = max(1, cfg.d_model // dh)
        act = sds((b, h, t, dh), jnp.bfloat16)
        u = sds((h, dh), jnp.bfloat16)
        h0 = sds((b, h, dh, dh), jnp.float32)
        args = (act, act, act, act, u, h0)
        min_el = b * h * t * dh

        for uk in (False, True):
            def dispatch(r, k, v, w, u_, h0_, _uk=uk):
                return wkv_ops.wkv_fused(
                    r, k, v, w, u_, h0_, chunk=chunk,
                    use_kernel=_uk, decode=False,
                )

            tag = "kernel" if uk else "jnp"
            findings += check_io_contract(
                dispatch, args, out_dtypes=(jnp.bfloat16, jnp.float32),
                what=f"{cfg.name} wkv_fused[{tag}] bf16",
                location=loc)
            try:
                closed = jax.make_jaxpr(dispatch)(*args)
            except Exception as e:  # noqa: BLE001
                findings.append(error(
                    PASS, loc,
                    f"{cfg.name} wkv_fused[{tag}]: trace failed: {e!r}"))
                continue
            findings += lint_upcasts(
                closed, min_elements=min_el,
                what=f"{cfg.name} wkv_fused[{tag}]", location=loc)
            findings += confirm_f32_accumulation(
                closed, what=f"{cfg.name} wkv_fused[{tag}]", location=loc)

    if "rec" in pattern:
        from repro.kernels.elevator_scan import ops as elev_ops

        loc = "src/repro/kernels/elevator_scan/ops.py:elevator_scan"
        d = cfg.d_rnn
        a = sds((b, t, d), jnp.bfloat16)
        x = sds((b, t, d), jnp.bfloat16)

        def elev(a_, x_):
            return elev_ops.elevator_scan(a_, x_, None, use_kernel=False,
                                          decode=False)

        findings += check_io_contract(
            elev, (a, x), out_dtypes=(jnp.bfloat16,),
            what=f"{cfg.name} elevator_scan bf16", location=loc)
        closed = jax.make_jaxpr(elev)(a, x)
        findings += confirm_f32_accumulation(
            closed, what=f"{cfg.name} elevator_scan", location=loc)

    if not ({"rwkv", "rec"} & set(pattern)):
        findings.append(info(
            PASS, "src/repro/model/transformer.py",
            f"{cfg.name}: attention-only pattern {pattern} — no recurrent "
            f"f32-accumulation contract to audit",
        ))

    findings += audit_state_dtypes(cfg.reduced())
    return findings
