"""Retrace sentinel: serve-loop jits must compile once per shape bucket.

The engine's perf story assumes each jitted piece compiles once and is
then dispatched hundreds of times.  A shape leak — a Python int that
should have been bucketed, a state whose shape depends on the exact
prompt length — silently turns every dispatch into a recompile, and
nothing fails: the serve loop just gets ~100x slower.

This pass runs a *tiny real* serve session (smoke-reduced config, CPU)
with deliberately ragged prompt lengths spanning two admission buckets,
then inspects the engine's jit caches:

* ``_serve_windows``: exactly one entry for one sampling configuration,
  compiled exactly once across all dispatches;
* ``_admits``: at most one entry per *declared* prompt bucket (the
  32-multiple rounding), each compiled once — the bucket arithmetic is
  re-declared here (:data:`PROMPT_BUCKET`) rather than imported from the
  engine, so an engine that stops bucketing cannot fool its own audit;
* ``generate()``: at most two window jits (interior + last), plus a
  prefill compiled once.

This is the one pass that executes anything — counting retraces requires
dispatching — but only at smoke scale (two slots, < 100 positions).
"""

from __future__ import annotations

from repro.analysis.findings import Finding, error, info

PASS = "retrace"
LOCATION = "src/repro/serve/engine.py:ServeEngine"

#: The auditor's own declaration of the admission bucket width.  The
#: engine has an equivalent ``_bucket32``; keeping an independent copy
#: here is deliberate — the audit is the spec, the engine the
#: implementation, and they must agree through behavior, not imports.
PROMPT_BUCKET = 32


def _bucket(n: int) -> int:
    return -(-max(int(n), 1) // PROMPT_BUCKET) * PROMPT_BUCKET


def _cache_size(fn):
    """Compile count of a ``jax.jit`` wrapper (None if unknowable)."""
    try:
        return int(fn._cache_size())
    except Exception:  # noqa: BLE001 — non-jit callables have no cache
        return None


def _check_once(findings, name, fn, *, allow: int = 1):
    n = _cache_size(fn)
    if n is None:
        findings.append(error(
            PASS, LOCATION,
            f"{name}: not a jit wrapper (cannot count retraces) — the "
            f"entry lost its jit boundary",
        ))
    elif n > allow:
        findings.append(error(
            PASS, LOCATION,
            f"{name}: compiled {n} times (allowed {allow}) — a shape is "
            f"leaking through the jit cache key",
            compiles=n, allowed=allow,
        ))


def run(cfg, *, prompt_lens: tuple[int, ...] = (3, 5, 33, 7),
        max_new: int = 4, slots: int = 2) -> list[Finding]:
    """Serve ``prompt_lens`` through a smoke engine and audit retraces."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.model import model as M
    from repro.serve.engine import Request, ServeEngine

    rcfg = cfg.reduced()
    if rcfg.frontend or rcfg.is_enc_dec:
        return [info(
            PASS, LOCATION,
            f"{cfg.name}: frontend/enc-dec serving not audited by the "
            f"retrace sentinel (token-only engine)",
        )]

    params = M.init_params(rcfg, jax.random.PRNGKey(0))
    eng = ServeEngine(rcfg, params=params, max_len=96, decode_window=2)

    rng = np.random.default_rng(0)
    reqs = [
        Request(tokens=rng.integers(1, rcfg.vocab_size, size=(pl,))
                .astype(np.int32), max_new_tokens=max_new)
        for pl in prompt_lens
    ]
    eng.serve(reqs, slots=slots)

    findings: list[Finding] = []
    buckets = {_bucket(pl) for pl in prompt_lens}

    if len(eng._serve_windows) != 1:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: {len(eng._serve_windows)} serve-window jits for "
            f"one sampling configuration (expected 1) — the window cache "
            f"key leaked a non-shape value",
            windows=len(eng._serve_windows),
        ))
    for key, fn in eng._serve_windows.items():
        _check_once(findings, f"{cfg.name} serve_window{key}", fn)

    if len(eng._admits) > len(buckets):
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: {len(eng._admits)} admission jits for prompt "
            f"lengths {tuple(prompt_lens)} spanning {len(buckets)} "
            f"declared {PROMPT_BUCKET}-buckets {sorted(buckets)} — "
            f"admission stopped bucketing prompt shapes",
            admits=len(eng._admits), buckets=len(buckets),
        ))
    for key, fn in eng._admits.items():
        _check_once(findings, f"{cfg.name} admit{key}", fn)

    # Lockstep generate(): interior + last window jits, prefill once.
    prompts = jnp.asarray(
        rng.integers(1, rcfg.vocab_size, size=(2, 16)), jnp.int32
    )
    eng.generate(prompts, 2 * max_new)
    if len(eng._windows) > 2:
        findings.append(error(
            PASS, LOCATION,
            f"{cfg.name}: {len(eng._windows)} decode-window jits after one "
            f"generate() (expected <= 2: interior + last)",
            windows=len(eng._windows),
        ))
    for key, fn in eng._windows.items():
        _check_once(findings, f"{cfg.name} window{key}", fn)
    _check_once(findings, f"{cfg.name} prefill", eng._prefill)

    if not findings:
        findings.append(info(
            PASS, LOCATION,
            f"{cfg.name}: serve session over prompts {tuple(prompt_lens)} "
            f"compiled {len(eng._admits)} admit / "
            f"{len(eng._serve_windows)} serve-window / "
            f"{len(eng._windows)} window jits, each exactly once",
            admits=len(eng._admits), buckets=len(buckets),
        ))
    return findings
