"""CLI: run the static-audit passes and print a findings table.

  PYTHONPATH=src python -m repro.analysis --arch rwkv6-1.6b --strict
  PYTHONPATH=src python -m repro.analysis --fake-devices 8   # all archs

Exit status: nonzero iff any ERROR finding (``--strict``: WARN too).
``--fake-devices N`` forces N XLA host-platform devices so the
collective audit sees a real multi-device mesh on this CPU container —
it must be applied before jax initializes, which is why this module
imports jax only after parsing arguments.
"""

from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--arch", action="append", default=None,
                    help="arch family to audit (repeatable; default: the "
                         "registry's DEFAULT_ARCHS)")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of passes to run")
    ap.add_argument("--strict", action="store_true",
                    help="treat WARN findings as failures too")
    ap.add_argument("--fake-devices", type=int, default=None,
                    help="force N XLA host-platform (CPU) devices")
    args = ap.parse_args(argv)

    if args.fake_devices is not None:
        if "jax" in sys.modules:
            print("error: --fake-devices must be applied before jax "
                  "initializes; run via `python -m repro.analysis`",
                  file=sys.stderr)
            return 2
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.fake_devices}"
        ).strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.analysis.findings import Severity, format_table, worst
    from repro.analysis.registry import DEFAULT_ARCHS, run_passes
    from repro.configs.registry import get_config

    archs = args.arch or list(DEFAULT_ARCHS)
    passes = args.passes.split(",") if args.passes else None

    import jax

    n_dev = len(jax.devices())
    failed = False
    for arch in archs:
        cfg = get_config(arch)
        findings = run_passes(cfg, passes)
        print(format_table(
            findings,
            title=f"{arch} — {len(findings)} findings on {n_dev} device(s)",
        ))
        print()
        top = worst(findings)
        if top >= Severity.ERROR or (args.strict and top >= Severity.WARN):
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
