"""CLI: run the static-audit passes and print a findings table.

  PYTHONPATH=src python -m repro.analysis --arch rwkv6-1.6b --strict
  PYTHONPATH=src python -m repro.analysis --fake-devices 8   # all archs
  PYTHONPATH=src python -m repro.analysis --passes hostsafety --strict

Exit status: nonzero iff any ERROR finding (``--strict``: WARN too).
``--json`` emits the findings as a machine-readable JSON array instead
of tables (same exit-status contract).

``--fake-devices N`` forces N XLA host-platform devices so the
collective audit sees a real multi-device mesh on this CPU container —
it must be applied before jax initializes, which is why this module
imports jax only after parsing arguments.

When every selected pass declares ``JAX_FREE = True`` (currently just
``hostsafety``), the CLI never imports jax or the config registry at
all and runs each pass exactly once — archs are irrelevant to an AST
audit of host code, and tier-1's lane 0 leans on this to fail fast
before anything compiles.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _emit(findings_by_label, as_json: bool, n_dev) -> None:
    if as_json:
        rows = [
            {
                "arch": label,
                "pass": f.pass_name,
                "severity": f.severity.name,
                "location": f.location,
                "message": f.message,
                "metrics": dict(f.metrics),
            }
            for label, findings in findings_by_label
            for f in findings
        ]
        json.dump(rows, sys.stdout, indent=2)
        print()
        return
    from repro.analysis.findings import format_table

    for label, findings in findings_by_label:
        dev = "" if n_dev is None else f" on {n_dev} device(s)"
        print(format_table(
            findings, title=f"{label} — {len(findings)} findings{dev}"))
        print()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--arch", action="append", default=None,
                    help="arch family to audit (repeatable; default: the "
                         "registry's DEFAULT_ARCHS)")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of passes to run")
    ap.add_argument("--strict", action="store_true",
                    help="treat WARN findings as failures too")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON array instead of tables")
    ap.add_argument("--fake-devices", type=int, default=None,
                    help="force N XLA host-platform (CPU) devices")
    args = ap.parse_args(argv)

    if args.fake_devices is not None:
        if "jax" in sys.modules:
            print("error: --fake-devices must be applied before jax "
                  "initializes; run via `python -m repro.analysis`",
                  file=sys.stderr)
            return 2
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.fake_devices}"
        ).strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.analysis.findings import Severity, worst
    from repro.analysis.registry import DEFAULT_ARCHS, get_pass, run_passes

    passes = args.passes.split(",") if args.passes else None

    # Jax-free fast path: an AST audit of host source doesn't vary by
    # arch and must not pay (or risk) a jax import to run.
    if passes is not None and all(
            getattr(get_pass(p), "JAX_FREE", False) for p in passes):
        findings = []
        for p in passes:
            findings += get_pass(p).run(None)
        _emit([("host", findings)], args.json, None)
        top = worst(findings)
        bad = top >= Severity.ERROR or (args.strict and top >= Severity.WARN)
        return 1 if bad else 0

    from repro.configs.registry import get_config

    archs = args.arch or list(DEFAULT_ARCHS)

    import jax

    n_dev = len(jax.devices())
    failed = False
    results = []
    for arch in archs:
        cfg = get_config(arch)
        findings = run_passes(cfg, passes)
        results.append((arch, findings))
        top = worst(findings)
        if top >= Severity.ERROR or (args.strict and top >= Severity.WARN):
            failed = True
    _emit(results, args.json, n_dev)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
