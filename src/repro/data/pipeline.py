"""Deterministic, stateless, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step) via counter-based hashing
(threefry) — no iterator state to checkpoint, so restart-after-failure
resumes exactly by replaying the step index, and elastic re-sharding is
trivial (any host can materialize any slice).

The synthetic stream is Zipf-distributed token ids with a repeated-ngram
structure so the LM loss actually decreases during the example runs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_exponent: float = 1.1


def _zipf_from_uniform(u: jax.Array, vocab: int, s: float) -> jax.Array:
    """Inverse-CDF Zipf sampling (approximate, vectorized)."""
    # P(k) ~ k^-s; approximate inverse CDF with the continuous formula.
    k = jnp.power(1.0 - u, -1.0 / (s - 1.0))
    k = jnp.clip(k, 1.0, float(vocab))
    return (k - 1.0).astype(jnp.int32)


def make_batch(cfg: DataConfig, step: int | jax.Array):
    """Returns {"tokens": (B, S) int32, "labels": (B, S) int32}.

    Labels are next-token targets (shift-by-one; the elevator Δ=-1 edge).
    """
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    b, s = cfg.global_batch, cfg.seq_len
    u = jax.random.uniform(key, (b, s))
    tokens = _zipf_from_uniform(u, cfg.vocab_size, cfg.zipf_exponent)
    # Inject learnable structure: every 8th position repeats the token from
    # 4 positions earlier (a deterministic n-gram pattern).
    pos = jnp.arange(s)
    shifted = jnp.roll(tokens, 4, axis=1)
    tokens = jnp.where((pos % 8 == 0) & (pos >= 4), shifted, tokens)
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}


def batch_specs(cfg: DataConfig):
    """ShapeDtypeStructs for one batch (dry-run inputs)."""
    shape = (cfg.global_batch, cfg.seq_len)
    return {
        "tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(shape, jnp.int32),
    }


def host_batch_numpy(cfg: DataConfig, step: int) -> dict:
    """Host-side numpy variant (no device allocation), for loaders."""
    rng = np.random.default_rng((cfg.seed << 32) ^ step)
    b, s = cfg.global_batch, cfg.seq_len
    u = rng.random((b, s))
    k = np.power(1.0 - u, -1.0 / (cfg.zipf_exponent - 1.0))
    tokens = (np.clip(k, 1.0, float(cfg.vocab_size)) - 1.0).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}
