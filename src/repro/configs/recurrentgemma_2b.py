"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000 — RG-LRU recurrent
blocks + local attention (window 2048), 1 attention : 2 recurrent.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    pattern=("rec", "rec", "local"),
    attn_window=2048,
    mlp_type="geglu",
    rglru=True,
    conv_width=4,
    d_rnn=2560,
    tie_embeddings=True,
    sub_quadratic=True,
    microbatch=4,
)
