"""Qwen2-0.5B [arXiv:2407.10671; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 — QKV bias.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    pattern=("attn",),
    mlp_type="swiglu",
    tie_embeddings=True,
    sub_quadratic=False,
    microbatch=4,
)
