"""Architecture registry: ``get_config(name)`` / ``list_archs()``."""

from __future__ import annotations

import importlib

_ARCHS = (
    "qwen2_vl_7b",
    "recurrentgemma_2b",
    "dbrx_132b",
    "qwen3_moe_235b_a22b",
    "gemma3_1b",
    "minitron_8b",
    "nemotron_4_15b",
    "qwen2_0_5b",
    "rwkv6_1_6b",
    "seamless_m4t_large_v2",
)


def canonical(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def list_archs() -> tuple[str, ...]:
    return _ARCHS


def get_config(name: str):
    mod_name = canonical(name)
    if mod_name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {_ARCHS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG
