"""SeamlessM4T-large-v2 text backbone [arXiv:2308.11596; hf].

24L(enc) + 24L(dec) d_model=1024 16H (kv=16, i.e. MHA) d_ff=8192
vocab=256206 — encoder-decoder with cross-attention; audio frontend stubbed
as precomputed frame embeddings per the assignment spec.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    pattern=("attn",),
    mlp_type="swiglu",   # backbone MLP (GLU family)
    tie_embeddings=True,
    frontend="audio",
    sub_quadratic=False,
    microbatch=2,
)
