"""Architecture configuration schema.

One :class:`ArchConfig` per assigned architecture (exact numbers from the
assignment table) plus a ``reduced()`` smoke-test variant of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default: d_model // num_heads

    # --- attention ---------------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False                # per-head RMSNorm on q/k (Qwen3)
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl M-RoPE (t,h,w)
    attn_window: int | None = None       # sliding-window size for local layers
    pattern: tuple[str, ...] = ("attn",)  # repeating layer pattern, e.g.
    #   gemma3: ("local",)*5 + ("global",)  recurrentgemma: ("rec","rec","attn")
    attn_logit_softcap: float | None = None

    # --- mlp ----------------------------------------------------------------
    mlp_type: str = "swiglu"             # swiglu | geglu | relu2
    mlp_bias: bool = False

    # --- moe ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # --- recurrent ----------------------------------------------------------
    rglru: bool = False                  # RG-LRU recurrent blocks ("rec" kind)
    conv_width: int = 4                  # temporal conv in recurrent blocks
    d_rnn: int | None = None             # recurrence width (default d_model)
    rwkv: bool = False                   # RWKV6 blocks ("rwkv" kind)

    # --- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0              # >0 => enc-dec; num_layers = decoder

    # --- embeddings / misc --------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    max_seq_len: int = 524_288
    sub_quadratic: bool = False          # can run long_500k
    frontend: str | None = None          # 'vision' | 'audio' stub embeddings
    dtype: str = "bfloat16"

    # --- distribution defaults (overridable per run) ------------------------
    remat: str = "full"                  # none | dots | full
    microbatch: int = 1                  # grad-accumulation chunks
    prefill_chunks: int = 1              # batch-split chunks for prefill
    moe_impl: str = "gather"             # gather | a2a (shard_map all-to-all)
    attn_batch_over_model: bool = False  # shard attention batch over model
    fsdp_gather_weights: bool = False    # explicitly all-gather FSDP-
    #   sharded weights at use (ZeRO-3 weight gathering) instead of
    #   letting GSPMD all-reduce partial activations (perf variant)
    head_pad: int = 0                    # zero-capacity extra q heads so
    #   (num_heads + head_pad) divides the TP width (perf variant)
    #   axis too (for head counts that don't divide the TP width)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.d_rnn is None:
            object.__setattr__(self, "d_rnn", self.d_model)
        if self.num_heads and self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: heads {self.num_heads} % kv {self.num_kv_heads}")

    # -- derived -------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a 128 multiple (shardable by 16).

        Standard production practice (e.g. seamless's 256206 -> 256256);
        padded logits are masked to -inf so semantics are unchanged.
        """
        return -(-self.vocab_size // 128) * 128

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Kind of each of the ``num_layers`` decoder layers, from pattern."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * hd * nq + 2 * d * hd * nkv + hd * nq * d
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.num_experts:
            mlp = mlp * self.num_experts + d * self.num_experts
        rec = 0
        if self.rglru:
            dr = self.d_rnn
            rec = 2 * d * dr + dr * d + self.conv_width * dr + 3 * dr
        if self.rwkv:
            rec = 6 * d * d
        total = 0
        for kind in self.layer_kinds:
            if kind in ("attn", "local", "global"):
                total += attn + mlp
            elif kind == "rec":
                total += rec + mlp
            elif kind == "rwkv":
                total += rec + mlp
        if self.is_enc_dec:
            # encoder self-attn + mlp, decoder already counted + cross-attn
            total += self.encoder_layers * (attn + mlp)
            total += self.num_layers * attn  # cross-attention
        total += v * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full_mlp = (3 if self.mlp_type in ("swiglu", "geglu") else 2) * d * f
        inactive = (self.num_experts - self.num_experts_per_tok) * full_mlp
        return self.param_count() - inactive * self.num_layers

    def reduced(self) -> "ArchConfig":
        """Smoke-test configuration of the same family (CPU-friendly)."""
        kv = max(1, min(self.num_kv_heads, 2))
        heads = max(kv, min(self.num_heads, 4))
        heads = (heads // kv) * kv
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, len(self.pattern) * 2),
            d_model=128,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=128 // heads if 128 % heads == 0 else 32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2)
            if self.num_experts
            else 0,
            d_rnn=128,
            encoder_layers=min(self.encoder_layers, 2),
            max_seq_len=512,
            mrope_sections=(8, 4, 4) if self.mrope_sections else None,
            attn_window=min(self.attn_window, 64) if self.attn_window else None,
            dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
