"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — M-RoPE (t/h/w
frequency sections), dynamic-resolution vision frontend stubbed as
precomputed patch embeddings per the assignment spec.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # halves of head_dim=128 -> 64 = 16+24+24
    pattern=("attn",),
    mlp_type="swiglu",
    tie_embeddings=False,
    frontend="vision",
    sub_quadratic=False,
    microbatch=4,
)
