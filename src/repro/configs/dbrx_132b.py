"""DBRX-132B [hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352 — fine-grained MoE,
16 experts top-4.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    rope_theta=500_000.0,
    pattern=("attn",),
    mlp_type="swiglu",
    num_experts=16,
    num_experts_per_tok=4,
    tie_embeddings=False,
    sub_quadratic=False,
    microbatch=8,
    prefill_chunks=4,
)
