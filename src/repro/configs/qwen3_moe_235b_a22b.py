"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936 — 128 experts top-8,
fine-grained experts, per-head q/k RMSNorm.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    pattern=("attn",),
    mlp_type="swiglu",
    num_experts=128,
    num_experts_per_tok=8,
    tie_embeddings=False,
    sub_quadratic=False,
    microbatch=16,
    prefill_chunks=8,
)
