"""RWKV6-1.6B "Finch" [arXiv:2404.05892; unverified].

24L d_model=2048 (attention-free) d_ff=7168 vocab=65536 — data-dependent
decay WKV recurrence + token shift: the paper-technique showcase (both are
fromThreadOrConst Δ=1 patterns).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,        # internal WKV heads (d=2048 / head_dim=64)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    pattern=("rwkv",),
    mlp_type="swiglu",
    rwkv=True,
    tie_embeddings=False,
    sub_quadratic=True,
    microbatch=2,
)
