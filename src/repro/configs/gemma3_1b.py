"""Gemma3-1B [hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 — 5 local : 1 global
interleave, local window 1024, 128k+ context.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    rope_theta=1_000_000.0,
    pattern=("local", "local", "local", "local", "local", "global"),
    attn_window=1024,
    mlp_type="geglu",
    tie_embeddings=True,
    sub_quadratic=True,   # local-dominant; global layers decode O(S)
    microbatch=2,
)
