"""Von-Neumann baseline: shared-memory staging + barrier (paper Fig. 1b/2a).

The GPGPU pattern the paper argues against: producers write intermediate
values to a shared scratchpad, a workgroup barrier orders the phases, and
consumers read the staged values back.  We reproduce it faithfully so the
benchmarks can compare both paths on identical math:

* the scratchpad is an explicitly materialized buffer (on TPU this is an
  HBM round-trip — XLA may not fuse through ``optimization_barrier``);
* the barrier is ``jax.lax.optimization_barrier``, which orders the produce
  and consume phases exactly like ``__syncthreads`` orders warps.

The byte counts reported by :mod:`repro.core.cost_model` charge the staged
buffer twice (write + read), matching the paper's energy accounting.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["stage_through_memory", "barrier", "SharedBuffer"]


def barrier(*arrays):
    """Workgroup barrier: forces every staged value to materialize before any
    consumer reads it (the ``__syncthreads`` analog)."""
    out = jax.lax.optimization_barrier(tuple(arrays))
    return out[0] if len(out) == 1 else out


def stage_through_memory(x: jax.Array) -> jax.Array:
    """Write ``x`` to the scratchpad and read it back after a barrier."""
    return barrier(x)


class SharedBuffer:
    """A CUDA ``__shared__`` array emulation with phase tracking.

    Usage mirrors Fig. 1b: ``buf.write(values)`` then ``buf.sync()`` then
    ``buf.read(idx)``.  Reads before a sync raise, mirroring the data race
    the barrier exists to prevent.  Byte traffic is tracked for the cost
    model.
    """

    def __init__(self, values_shape, dtype=jnp.float32):
        self._shape = tuple(values_shape)
        self._dtype = dtype
        self._buf = None
        self._synced = False
        self.bytes_written = 0
        self.bytes_read = 0

    def write(self, values: jax.Array):
        if values.shape != self._shape:
            raise ValueError(f"shape {values.shape} != buffer {self._shape}")
        self._buf = values.astype(self._dtype)
        self._synced = False
        self.bytes_written += values.size * values.dtype.itemsize
        return self

    def sync(self):
        if self._buf is None:
            raise RuntimeError("sync before any write")
        self._buf = barrier(self._buf)
        self._synced = True
        return self

    def read(self, idx=None) -> jax.Array:
        if not self._synced:
            raise RuntimeError("shared-memory read before barrier (data race)")
        out = self._buf if idx is None else self._buf[idx]
        self.bytes_read += (
            out.size * out.dtype.itemsize
            if hasattr(out, "size")
            else self._buf.dtype.itemsize
        )
        return out
