"""Elevator node semantics (paper §3, §4.1, §4.3).

The elevator node is the hardware primitive behind ``fromThreadOrConst``:
for every thread ``TID`` it delivers the token produced by thread
``TID - delta``; when the producer falls outside the thread block or outside
the current *transmission window*, the preconfigured constant ``C`` is
delivered instead (paper Fig. 4).

On TPU the "thread axis" is an array axis.  A positive ``delta`` therefore
becomes a shift *toward higher indices* with ``const`` injected at the window
boundary.  The in-core version below is pure ``jnp`` (it lowers to VREG lane
rotates / VMEM block shifts — never an HBM round trip); the cross-device
version lives in :mod:`repro.core.device_comm`, and the block-carry (token
buffer) version inside the Pallas kernels in :mod:`repro.kernels`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "from_thread_or_const",
    "from_thread_or_const_nd",
    "tag_value",
    "CascadePlan",
    "plan_cascade",
    "TOKEN_BUFFER_SIZE",
]

# Paper Table 2 / §4.3: each elevator node carries a 16-entry token buffer.
TOKEN_BUFFER_SIZE = 16


def tag_value(x: jax.Array, name: str | None = None) -> jax.Array:
    """``tagValue<var>()`` — mark the exported version of a variable.

    JAX traces SSA values, so versions are implicit; the call is kept for
    API fidelity with the paper and as a documentation anchor.  It is the
    identity on the value (optionally named for debugging/HLO inspection).
    """
    if name is not None:
        # Named identity so the tagged value is findable in lowered HLO.
        return jax.named_call(lambda v: v, name=f"tag_value_{name}")(x)
    return x


def _window_ids(n: int, window: int | None) -> jax.Array:
    if window is None:
        return jnp.zeros((n,), dtype=jnp.int32)
    return (jnp.arange(n, dtype=jnp.int32) // window).astype(jnp.int32)


def from_thread_or_const(
    x: jax.Array,
    delta: int,
    const,
    *,
    window: int | None = None,
    axis: int = 0,
) -> jax.Array:
    """``fromThreadOrConst<var, delta, const[, window]>()`` over one axis.

    out[tid] = x[tid - delta]  if ``tid - delta`` lies in the same
    transmission window (and inside the thread block), else ``const``.

    ``delta`` may be negative (receive from a *higher* TID, e.g. the
    ``tid + 1`` operand of the paper's convolution example).
    ``window`` partitions the thread axis into consecutive groups of that
    size; communication never crosses a group boundary (paper §3.2).
    """
    if delta == 0:
        return x
    n = x.shape[axis]
    x = jnp.moveaxis(x, axis, 0)

    # Shift by delta along the (leading) thread axis.
    shifted = jnp.roll(x, delta, axis=0)

    tid = jnp.arange(n, dtype=jnp.int32)
    src = tid - delta
    valid = (src >= 0) & (src < n)
    if window is not None:
        valid &= (tid // window) == (src // window)

    const_arr = jnp.asarray(const, dtype=x.dtype)
    valid = valid.reshape((n,) + (1,) * (x.ndim - 1))
    out = jnp.where(valid, shifted, const_arr)
    return jnp.moveaxis(out, 0, axis)


def from_thread_or_const_nd(
    x: jax.Array,
    deltas: Sequence[int],
    const,
    *,
    axes: Sequence[int] | None = None,
    windows: Sequence[int | None] | None = None,
) -> jax.Array:
    """Multi-dimensional ``fromThreadOrConst`` (2D/3D TID spaces, Table 1).

    ``deltas[i]`` applies along ``axes[i]``.  A token is valid only if the
    source coordinate is in-bounds (and in-window) along *every* axis,
    matching the paper's multi-dimensional ΔTID encoding.
    """
    if axes is None:
        axes = tuple(range(len(deltas)))
    if windows is None:
        windows = (None,) * len(deltas)
    if len(axes) != len(deltas) or len(windows) != len(deltas):
        raise ValueError("deltas/axes/windows length mismatch")

    const_arr = jnp.asarray(const, dtype=x.dtype)
    shifted = x
    valid = jnp.ones((), dtype=bool)
    # Broadcastable validity over all thread axes.
    valid_shape = [1] * x.ndim
    valid = jnp.ones(tuple(valid_shape), dtype=bool)
    for delta, axis, window in zip(deltas, axes, windows):
        if delta == 0:
            continue
        n = x.shape[axis]
        shifted = jnp.roll(shifted, delta, axis=axis)
        tid = jnp.arange(n, dtype=jnp.int32)
        src = tid - delta
        ok = (src >= 0) & (src < n)
        if window is not None:
            ok &= (tid // window) == (src // window)
        shape = [1] * x.ndim
        shape[axis] = n
        valid = valid & ok.reshape(shape)
    return jnp.where(valid, shifted, const_arr)


@dataclasses.dataclass(frozen=True)
class CascadePlan:
    """Compile-time cascade of elevator nodes for a large ΔTID (paper §4.3).

    ``node_deltas`` chains token buffers: e.g. Δ=18 with a 16-entry buffer
    maps to two cascaded nodes with Δ=16 and Δ=2 (paper Fig. 10a).  When the
    chain would exceed ``max_nodes``, the value spills to memory (the paper's
    Live Value Cache fallback; HBM on TPU).
    """

    delta: int
    node_deltas: tuple[int, ...]
    spilled: bool

    @property
    def num_nodes(self) -> int:
        return len(self.node_deltas)


def plan_cascade(
    delta: int,
    *,
    token_buffer: int = TOKEN_BUFFER_SIZE,
    max_nodes: int = 16,
) -> CascadePlan:
    """Plan the elevator cascade for ``delta`` (paper §4.3).

    num_nodes = ceil(|Δ| / token_buffer); spill if it exceeds ``max_nodes``.
    """
    mag = abs(delta)
    if mag == 0:
        return CascadePlan(delta, (), False)
    sign = 1 if delta > 0 else -1
    n_full, rem = divmod(mag, token_buffer)
    deltas = [token_buffer * sign] * n_full + ([rem * sign] if rem else [])
    if len(deltas) > max_nodes:
        return CascadePlan(delta, (), True)
    return CascadePlan(delta, tuple(deltas), False)


def cascaded_from_thread_or_const(
    x: jax.Array,
    delta: int,
    const,
    *,
    window: int | None = None,
    axis: int = 0,
    token_buffer: int = TOKEN_BUFFER_SIZE,
    max_nodes: int = 16,
) -> tuple[jax.Array, CascadePlan]:
    """Apply ``from_thread_or_const`` through an explicit cascade.

    Functionally identical to a single shift by ``delta`` (the tests assert
    this); structurally it mirrors the hardware chaining so the cost model
    can count nodes/spills.  A spilled plan falls back to the direct shift —
    the semantic equivalent of staging through the Live Value Cache.
    """
    plan = plan_cascade(delta, token_buffer=token_buffer, max_nodes=max_nodes)
    if plan.spilled or not plan.node_deltas:
        return from_thread_or_const(x, delta, const, window=window, axis=axis), plan
    # Chain the nodes.  Validity must be evaluated against the *total* delta
    # (a token dying at any hop dies overall), so chain shifts with a
    # sentinel-free approach: shift values hop by hop, then apply the total
    # boundary/window mask once (equivalent because shifts compose).
    n = x.shape[axis]
    shifted = x
    for d in plan.node_deltas:
        shifted = jnp.roll(shifted, d, axis=axis)
    tid = jnp.arange(n, dtype=jnp.int32)
    src = tid - delta
    valid = (src >= 0) & (src < n)
    if window is not None:
        valid &= (tid // window) == (src // window)
    shape = [1] * x.ndim
    shape[axis] = n
    out = jnp.where(valid.reshape(shape), shifted, jnp.asarray(const, x.dtype))
    return out, plan
