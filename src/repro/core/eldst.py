"""Enhanced load/store (eLDST) semantics — ``fromThreadOrMem`` (paper §3.3, §4.2).

``fromThreadOrMem<delta[, window]>(addr, predicate)``: a thread whose
``predicate`` is true issues the memory load; every other thread receives the
value *forwarded* from thread ``TID - delta`` — i.e. the recurrence

    out[t] = mem[t]            if pred[t]
           = out[t - delta]    otherwise (within the transmission window)
           = const             if no producer exists in the window

Each value is thus loaded once and reused ``window / delta`` times
(paper §4.2), collapsing e.g. matmul loads from N·K·M to N·M (§3.3).

The recurrence decomposes into ``delta`` independent fill-forward chains
(positions with equal ``tid mod delta``), each solved with an associative
scan — O(log n) depth on the VPU, no HBM staging.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["from_thread_or_mem", "ForwardStats", "forward_stats"]


@dataclasses.dataclass(frozen=True)
class ForwardStats:
    """Memory-traffic accounting for one eLDST site (drives Fig. 11/12 analogs)."""

    loads_issued: int          # predicated loads that reached memory
    loads_forwarded: int       # values served by inter-thread forwarding
    loads_naive: int           # loads the von-Neumann version would issue

    @property
    def traffic_reduction(self) -> float:
        return self.loads_naive / max(self.loads_issued, 1)


def _fill_forward(values: jax.Array, pred: jax.Array, const, axis: int) -> jax.Array:
    """out[j] = values[j] if pred[j] else out[j-1]; const before first pred."""

    def combine(a, b):
        va, pa = a
        vb, pb = b
        keep = pb
        # Broadcast keep over trailing value dims.
        keep_v = keep.reshape(keep.shape + (1,) * (va.ndim - keep.ndim))
        return jnp.where(keep_v, vb, va), pa | pb

    scanned_v, has_p = jax.lax.associative_scan(combine, (values, pred), axis=axis)
    has_p = has_p.reshape(has_p.shape + (1,) * (scanned_v.ndim - has_p.ndim))
    return jnp.where(has_p, scanned_v, jnp.asarray(const, values.dtype))


def from_thread_or_mem(
    mem_values: jax.Array,
    pred: jax.Array,
    delta: int,
    *,
    window: int | None = None,
    const=0,
    axis: int = 0,
) -> jax.Array:
    """Evaluate the eLDST forwarding recurrence along ``axis``.

    ``mem_values[t]`` is the value thread ``t`` *would* load (the address
    contents); only positions with ``pred[t]`` actually charge the memory
    system — :func:`forward_stats` accounts for the rest.  ``pred`` has the
    shape of the thread axis.
    """
    if delta <= 0:
        raise ValueError("fromThreadOrMem forwards from lower TIDs; delta must be > 0")
    x = jnp.moveaxis(mem_values, axis, 0)
    n = x.shape[0]
    if pred.shape != (n,):
        raise ValueError(f"pred must have shape ({n},), got {pred.shape}")
    win = window if window is not None else n

    # Pad the thread axis so it splits into whole windows, then windows into
    # whole (chain-step, residue) tiles.  Padded slots have pred=False and are
    # dropped on exit.
    n_pad_win = (-n) % win
    total = n + n_pad_win
    g = total // win
    win_pad = (-win) % delta
    wtot = win + win_pad
    j = wtot // delta

    def pad_to(arr, size, value):
        pad_width = [(0, size - arr.shape[0])] + [(0, 0)] * (arr.ndim - 1)
        return jnp.pad(arr, pad_width, constant_values=value)

    xp = pad_to(x, total, 0)
    pp = pad_to(pred, total, False)
    # (g, win) -> (g, j, delta): position within window = jj*delta + r.
    xp = xp.reshape((g, win) + x.shape[1:])
    pp = pp.reshape((g, win))
    if win_pad:
        xp = jnp.pad(xp, [(0, 0), (0, win_pad)] + [(0, 0)] * (x.ndim - 1))
        pp = jnp.pad(pp, [(0, 0), (0, win_pad)], constant_values=False)
    xp = xp.reshape((g, j, delta) + x.shape[1:])
    pp = pp.reshape((g, j, delta))

    out = _fill_forward(xp, pp, const, axis=1)

    out = out.reshape((g, wtot) + x.shape[1:])[:, :win]
    out = out.reshape((total,) + x.shape[1:])[:n]
    return jnp.moveaxis(out, 0, axis)


def forward_stats(pred, delta: int, *, window: int | None = None) -> ForwardStats:
    """Static accounting for an eLDST site (pred evaluated on host / numpy)."""
    import numpy as np

    p = np.asarray(pred)
    n = p.shape[0]
    loads = int(p.sum())
    return ForwardStats(
        loads_issued=loads,
        loads_forwarded=int(n - loads),
        loads_naive=int(n),
    )
