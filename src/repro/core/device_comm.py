"""Device-space elevator nodes — the paper's fabric edges mapped onto ICI.

A CGRA elevator node moves a token from thread ``TID`` to ``TID + delta`` and
injects a constant at the boundary.  Across a TPU mesh the same pattern is a
``lax.ppermute`` (collective-permute) along a named axis: point-to-point,
producer→consumer, no global barrier — in contrast to the all-gather /
shared-buffer pattern that mirrors GPGPU scratchpad staging.

All functions here must run inside ``shard_map`` (they use named axes).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "axis_size",
    "device_shift",
    "halo_exchange",
    "ring_pass",
    "seq_carry_scan",
]


def axis_size(axis_name: str) -> int:
    # jax 0.4.x has no jax.lax.axis_size; psum of a literal 1 over the named
    # axis folds to the static mesh size inside shard_map.
    return jax.lax.psum(1, axis_name)


def device_shift(x: jax.Array, axis_name: str, delta: int = 1, fill=0.0) -> jax.Array:
    """Elevator shift across devices: shard ``i`` receives shard ``i - delta``.

    Boundary shards (no producer) receive ``fill`` — the elevator constant C.
    Exactly one collective-permute; O(|x|) bytes point-to-point on ICI.
    """
    n = axis_size(axis_name)
    if delta == 0:
        return x
    perm = [(i, i + delta) for i in range(n) if 0 <= i + delta < n]
    shifted = jax.lax.ppermute(x, axis_name, perm)
    idx = jax.lax.axis_index(axis_name)
    src = idx - delta
    has_producer = (src >= 0) & (src < n)
    return jnp.where(has_producer, shifted, jnp.asarray(fill, x.dtype))


def ring_pass(x: jax.Array, axis_name: str, delta: int = 1) -> jax.Array:
    """Cyclic variant (ring): shard ``i`` receives shard ``(i - delta) mod n``.

    Used by ring-style forwarding (e.g. rotating K/V or operand tiles so a
    value loaded from HBM once visits every shard — the eLDST pattern).
    """
    n = axis_size(axis_name)
    perm = [(i, (i + delta) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_name, perm)


def halo_exchange(
    x: jax.Array,
    axis_name: str,
    *,
    left: int = 0,
    right: int = 0,
    seq_axis: int = 0,
    fill=0.0,
) -> jax.Array:
    """Forward boundary tokens between neighboring sequence shards.

    ``x`` is the local chunk of a sequence-sharded tensor.  The result is the
    local chunk extended with ``left`` trailing tokens of the previous shard
    and ``right`` leading tokens of the next shard — delivered point-to-point
    (one ppermute per side), never by all-gathering the sequence.  Edge
    shards receive ``fill`` (elevator constant) in the missing halo.

    This implements local/sliding-window attention's K/V neighborhood and the
    token-shift halo of RWKV-style models across shards.
    """
    parts = []
    if left:
        tail = jax.lax.slice_in_dim(x, x.shape[seq_axis] - left, x.shape[seq_axis], axis=seq_axis)
        parts.append(device_shift(tail, axis_name, delta=1, fill=fill))
    parts.append(x)
    if right:
        head = jax.lax.slice_in_dim(x, 0, right, axis=seq_axis)
        parts.append(device_shift(head, axis_name, delta=-1, fill=fill))
    if len(parts) == 1:
        return x
    return jnp.concatenate(parts, axis=seq_axis)


def seq_carry_scan(
    chunk_fn,
    carry_init: Any,
    x: jax.Array,
    axis_name: str,
    *,
    reverse: bool = False,
):
    """Sequential carry chain across sequence shards (elevator Δ=1 chain).

    ``chunk_fn(carry, x_local) -> (carry_out, y_local)`` runs on every shard;
    the carry produced by shard ``i`` is forwarded to shard ``i+1`` via
    ppermute.  Shard 0 uses ``carry_init`` (the elevator constant).  The chain
    serializes across shards by construction — it is the *exact* dataflow of
    the paper's prefix-sum example (Fig. 6) at ICI granularity.  Use
    :mod:`repro.core.chunk_scan` for the log-depth alternative when the
    recurrence is associative.

    ``reverse=True`` runs the chain from the *last* shard toward shard 0
    (a Δ=-1 edge): shard ``n-1`` uses ``carry_init`` and each carry is
    forwarded to shard ``i-1``.  This is the device-space reverse elevator
    — the sweep direction of adjoint carries (e.g. the WKV ``dS``) during
    sequence-sharded training.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    # Position along the sweep: hop k activates the k-th shard in sweep
    # order (ascending indices forward, descending in reverse).
    pos = (n - 1 - idx) if reverse else idx
    delta = -1 if reverse else 1

    init = jax.tree.map(jnp.asarray, carry_init)
    # Each shard must observe the carries of all predecessors.  We unroll the
    # shard chain: at hop k every shard runs its chunk against the carry it
    # currently holds, but only the shard whose turn it is (pos == k) keeps
    # its freshly produced output; carries propagate one hop per iteration.
    # Cost: n hops (pipeline-friendly; XLA overlaps the permutes).
    carry_out, y = chunk_fn(init, x)
    for k in range(1, n):
        shifted = jax.tree.map(
            lambda t: device_shift(t, axis_name, delta=delta, fill=0.0), carry_out
        )
        carry_in = jax.tree.map(
            lambda new, ini: jnp.where(pos >= k, new, ini.astype(new.dtype)),
            shifted, init,
        )
        carry_new, y_new = chunk_fn(carry_in, x)
        keep = pos == k
        y = jax.tree.map(lambda a, b: jnp.where(keep, b, a), y, y_new)
        carry_out = jax.tree.map(lambda a, b: jnp.where(pos >= k, b, a), carry_out, carry_new)
    return carry_out, y
