"""dMT-CGRA inter-thread communication — the paper's contribution in JAX.

Public API:
  from_thread_or_const / from_thread_or_const_nd / tag_value  (elevator node)
  from_thread_or_mem                                          (eLDST)
  plan_cascade / CascadePlan                                  (§4.3 cascades)
  SegmentMonoid / ELEMENTWISE / DIAG_STATE                    (composition law)
  linear_scan / chunked_linear_scan / device_linear_scan_carry
  device_shift / halo_exchange / ring_pass / seq_carry_scan   (ICI elevators)
  pipeline_apply                                              (PP forwarding)
  stage_through_memory / barrier / SharedBuffer               (vN baseline)
"""

from repro.core.elevator import (
    TOKEN_BUFFER_SIZE,
    CascadePlan,
    cascaded_from_thread_or_const,
    from_thread_or_const,
    from_thread_or_const_nd,
    plan_cascade,
    tag_value,
)
from repro.core.eldst import ForwardStats, forward_stats, from_thread_or_mem
from repro.core.chunk_scan import (
    DIAG_STATE,
    ELEMENTWISE,
    SegmentMonoid,
    chunked_linear_scan,
    device_linear_scan_carry,
    linear_scan,
)
from repro.core.device_comm import (
    device_shift,
    halo_exchange,
    ring_pass,
    seq_carry_scan,
)
from repro.core.pipeline import pipeline_apply
from repro.core.scratchpad import SharedBuffer, barrier, stage_through_memory
from repro.core import cost_model

__all__ = [
    "TOKEN_BUFFER_SIZE",
    "CascadePlan",
    "cascaded_from_thread_or_const",
    "from_thread_or_const",
    "from_thread_or_const_nd",
    "plan_cascade",
    "tag_value",
    "ForwardStats",
    "forward_stats",
    "from_thread_or_mem",
    "DIAG_STATE",
    "ELEMENTWISE",
    "SegmentMonoid",
    "chunked_linear_scan",
    "device_linear_scan_carry",
    "linear_scan",
    "device_shift",
    "halo_exchange",
    "ring_pass",
    "seq_carry_scan",
    "pipeline_apply",
    "SharedBuffer",
    "barrier",
    "stage_through_memory",
    "cost_model",
]
