"""Pipeline parallelism as elevator forwarding over the stage axis.

GPipe-style microbatch pipelining where the stage-to-stage activation hand-off
is a ``ppermute`` shift (Δ=+1 over the stage axis) — a device-space elevator
node.  Bubble slots are the elevator's boundary constant: stages with no
producer receive zeros and their output is masked out of the final result.

Runs inside ``shard_map`` over the stage axis; the layer weights of stage
``i`` live only on shard ``i`` (the caller shards the stacked stage params).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import device_comm

__all__ = ["pipeline_apply"]


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    x_micro: jax.Array,
    axis_name: str,
):
    """Run ``stage_fn`` as a ``num_stages``-deep pipeline over microbatches.

    Args:
      stage_fn: ``(params, x) -> y`` — one pipeline stage (a block of layers).
      stage_params: this shard's stage parameters (already stage-sharded).
      x_micro: ``(num_micro, micro_batch, ...)`` microbatched input. Every
        shard holds the full microbatch stream; only stage 0 injects it.
      axis_name: mesh axis carrying the stages.

    Returns:
      ``(num_micro, micro_batch, ...)`` outputs of the final stage (valid on
      every shard; non-final shards hold garbage that the caller discards —
      conventionally the result is psum-masked to the last stage's value).

    Schedule: ``num_micro + num_stages - 1`` ticks.  At tick ``t`` stage
    ``s`` processes microbatch ``t - s`` (if in range).  The activation
    hand-off is one collective-permute per tick — point-to-point, no global
    barrier, exactly the paper's producer/consumer firing rule.
    """
    n_stages = jax.lax.psum(1, axis_name)
    stage = jax.lax.axis_index(axis_name)
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    buf_shape = x_micro.shape[1:]

    def tick(carry, t):
        incoming, outputs = carry
        # Stage 0 injects microbatch t (bubble = zeros once the stream ends).
        mb_idx = jnp.clip(t, 0, n_micro - 1)
        injected = jax.lax.dynamic_index_in_dim(x_micro, mb_idx, axis=0, keepdims=False)
        injected = jnp.where(t < n_micro, injected, jnp.zeros(buf_shape, x_micro.dtype))
        x_in = jnp.where(stage == 0, injected, incoming)

        y = stage_fn(stage_params, x_in)

        # Final stage commits microbatch t - (n_stages - 1) to the output.
        out_idx = t - (n_stages - 1)
        valid_out = (out_idx >= 0) & (stage == n_stages - 1)
        safe_idx = jnp.clip(out_idx, 0, n_micro - 1)
        committed = jax.lax.dynamic_update_index_in_dim(
            outputs, jnp.where(valid_out, y, outputs[safe_idx]), safe_idx, axis=0
        )
        # Elevator hand-off to the next stage (boundary shards get zeros).
        nxt = device_comm.device_shift(y, axis_name, delta=1, fill=0.0)
        return (nxt, committed), None

    init_in = jnp.zeros(buf_shape, x_micro.dtype)
    init_out = jnp.zeros_like(x_micro)
    # The loop-carried buffers become shard-varying after the first ppermute;
    # mark them varying up front so the scan carry types are stable.  jax
    # 0.4.x has no pvary (no varying-axis types either) — identity there.
    pvary = getattr(jax.lax, "pvary", lambda v, _axes: v)
    init_in = pvary(init_in, (axis_name,))
    init_out = pvary(init_out, (axis_name,))
    (_, outputs), _ = jax.lax.scan(tick, (init_in, init_out), jnp.arange(ticks))
    return outputs
