"""Memory-traffic and energy cost model (paper Fig. 11/12 analogs).

The paper's wins come from eliminating scratchpad round-trips and redundant
global loads.  On a simulator those show up as speedup/power; in this
framework we account them as *bytes moved per memory tier*, which is the
hardware-independent quantity, and convert to energy with per-access costs.

Energy constants are per-byte approximations in picojoules, from the DDR/SRAM
access-energy literature the paper's GPUWattch model draws on (45 nm class,
same as Fermi/GTX480): DRAM ≈ 160 pJ/B, scratchpad/L1 SRAM ≈ 8 pJ/B,
in-fabric forwarding ≈ 0.4 pJ/B (register/NoC hop).  Absolute values are
indicative; the *ratios* drive the Fig. 12 analog.
"""

from __future__ import annotations

import dataclasses

PJ_PER_BYTE = {
    "dram": 160.0,       # global memory
    "scratchpad": 8.0,   # shared memory / L1 SRAM
    "fabric": 0.4,       # direct producer->consumer forwarding (VREG/VMEM/NoC)
}


@dataclasses.dataclass
class Traffic:
    """Bytes moved per tier for one kernel execution."""

    dram_bytes: int = 0
    scratchpad_bytes: int = 0
    fabric_bytes: int = 0

    def energy_pj(self) -> float:
        return (
            self.dram_bytes * PJ_PER_BYTE["dram"]
            + self.scratchpad_bytes * PJ_PER_BYTE["scratchpad"]
            + self.fabric_bytes * PJ_PER_BYTE["fabric"]
        )

    def __add__(self, other: "Traffic") -> "Traffic":
        return Traffic(
            self.dram_bytes + other.dram_bytes,
            self.scratchpad_bytes + other.scratchpad_bytes,
            self.fabric_bytes + other.fabric_bytes,
        )


@dataclasses.dataclass(frozen=True)
class KernelCost:
    name: str
    variant: str          # "naive" | "shared" | "direct"
    traffic: Traffic
    flops: int

    @property
    def energy_pj(self) -> float:
        return self.traffic.energy_pj()

    def arithmetic_intensity(self) -> float:
        total = (
            self.traffic.dram_bytes
            + self.traffic.scratchpad_bytes
            + self.traffic.fabric_bytes
        )
        return self.flops / max(total, 1)


def matmul_traffic(n: int, k: int, m: int, itemsize: int = 4):
    """Paper §3.3: loads drop from N·K·M (naive) to N·M + K·(N+M) (direct).

    naive:  every thread loads its full row/column -> N*M*(2K) element loads.
    shared: stage A and B tiles through scratchpad; global loads (N*K + K*M),
            scratchpad write (N*K + K*M) + read 2*K per thread.
    direct: one thread per row/col issues the load (fromThreadOrMem); other
            threads receive forwarded operands through the fabric.
    """
    out_writes = n * m * itemsize
    naive = Traffic(dram_bytes=(n * m * 2 * k) * itemsize + out_writes)
    shared = Traffic(
        dram_bytes=(n * k + k * m) * itemsize + out_writes,
        scratchpad_bytes=((n * k + k * m) + n * m * 2 * k) * itemsize,
    )
    direct = Traffic(
        dram_bytes=(n * k + k * m) * itemsize + out_writes,
        fabric_bytes=(n * m * 2 * k - (n * k + k * m)) * itemsize,
    )
    flops = 2 * n * k * m
    return (
        KernelCost("matmul", "naive", naive, flops),
        KernelCost("matmul", "shared", shared, flops),
        KernelCost("matmul", "direct", direct, flops),
    )


def conv1d_traffic(n: int, taps: int = 3, itemsize: int = 4):
    """Paper Fig. 1: naive reloads each element ``taps`` times; direct loads
    once and forwards the shifted copies through elevator nodes."""
    out_writes = n * itemsize
    naive = Traffic(dram_bytes=(n * taps + taps) * itemsize + out_writes)
    shared = Traffic(
        dram_bytes=(n + taps) * itemsize + out_writes,
        scratchpad_bytes=(n + n * taps) * itemsize,
    )
    direct = Traffic(
        dram_bytes=(n + taps) * itemsize + out_writes,
        fabric_bytes=(n * (taps - 1)) * itemsize,
    )
    flops = 2 * n * taps
    return (
        KernelCost("conv1d", "naive", naive, flops),
        KernelCost("conv1d", "shared", shared, flops),
        KernelCost("conv1d", "direct", direct, flops),
    )


def scan_traffic(n: int, itemsize: int = 4):
    """Prefix sum (paper Fig. 6): the shared version re-stages partial sums
    log2(n) times (Hillis-Steele in scratchpad); direct communicates each
    partial exactly once through the fabric."""
    import math

    out_writes = n * itemsize
    steps = max(1, int(math.ceil(math.log2(max(n, 2)))))
    naive = Traffic(dram_bytes=(n + n * steps * 2) * itemsize + out_writes)
    shared = Traffic(
        dram_bytes=n * itemsize + out_writes,
        scratchpad_bytes=(2 * n * steps) * itemsize,
    )
    direct = Traffic(
        dram_bytes=n * itemsize + out_writes,
        fabric_bytes=n * itemsize,
    )
    flops = n
    return (
        KernelCost("scan", "naive", naive, flops),
        KernelCost("scan", "shared", shared, flops),
        KernelCost("scan", "direct", direct, flops),
    )


def stencil2d_traffic(h: int, w: int, pts: int = 5, itemsize: int = 4):
    """hotspot/SRAD-style 2D stencil: naive reloads each neighbor; direct
    forwards row halos through the fabric."""
    n = h * w
    out_writes = n * itemsize
    naive = Traffic(dram_bytes=n * pts * itemsize + out_writes)
    shared = Traffic(
        dram_bytes=n * itemsize + out_writes,
        scratchpad_bytes=(n + n * pts) * itemsize,
    )
    direct = Traffic(
        dram_bytes=n * itemsize + out_writes,
        fabric_bytes=n * (pts - 1) * itemsize,
    )
    flops = n * pts * 2
    return (
        KernelCost("stencil2d", "naive", naive, flops),
        KernelCost("stencil2d", "shared", shared, flops),
        KernelCost("stencil2d", "direct", direct, flops),
    )


def wkv_traffic(b: int, h: int, t: int, dh: int, chunk: int = 64,
                itemsize: int = 4):
    """RWKV6 WKV recurrence (decay-ratio chunked form), per forward pass.

    naive:  sequential scan — the (dh, dh) state round-trips through memory
            every token (the decode pattern applied to a full sequence).
    shared: the chunked jnp path — inputs load once, but the per-chunk decay
            tensors (logw, cum_incl, cum_excl, r_dec, k_inv, k_rem), the
            masked score matrix, the intra/inter partial outputs and the
            ``lax.scan`` state carry all stage through HBM (Fig. 1b).
    direct: the fused Pallas kernel — the state lives in a VMEM scratch and
            the decay tensors never leave the fabric (kernels/wkv).
    """
    n = max(1, t // chunk)
    state = dh * dh
    # Unavoidable I/O: r/k/v/w in, out + final state + h0.
    io = b * h * (4 * t * dh + t * dh + 2 * state) * itemsize
    naive = Traffic(dram_bytes=io + b * h * t * 2 * state * itemsize)
    staged = b * h * (
        6 * t * dh            # logw, cum_incl, cum_excl, r_dec, k_inv, k_rem
        + n * chunk * chunk   # masked intra-chunk scores
        + 2 * t * dh          # intra, inter partial outputs
        + 2 * n * state       # scan carry: S written + read per chunk
    ) * itemsize
    shared = Traffic(dram_bytes=io, scratchpad_bytes=2 * staged)
    direct = Traffic(dram_bytes=io, fabric_bytes=staged)
    # Scores + intra matmul + inter read + state update, 2 flops per MAC.
    flops = b * h * (
        2 * 2 * n * chunk * chunk * dh   # scores + scores @ v
        + 2 * 2 * t * dh * dh            # inter read + k^T v update
    )
    return (
        KernelCost("wkv", "naive", naive, flops),
        KernelCost("wkv", "shared", shared, flops),
        KernelCost("wkv", "direct", direct, flops),
    )


def wkv_bwd_traffic(b: int, h: int, t: int, dh: int, chunk: int = 64,
                    itemsize: int = 4):
    """RWKV6 WKV backward pass (reverse chunk sweep), per step.

    naive:  autodiff of the sequential scan — the (dh, dh) state is staged
            per token by the forward and read back, and the adjoint state
            round-trips per token.
    shared: ``jax.grad`` of the chunked jnp path — the forward's residuals
            (six decay tensors, masked scores, per-chunk scan states) are
            staged to HBM and read back, and the backward's own
            intermediates (decay/score adjoints, partial grads, the dS
            scan carry) stage the same way (Fig. 1b, twice).
    direct: the reverse Pallas kernel — decays and scores are *recomputed*
            in-fabric from the primals; the only staged residual is the
            per-chunk entry state ``s_hist`` (written by the training
            forward, read by the reverse sweep), and the adjoint state dS
            rides the VMEM carry.
    """
    n = max(1, t // chunk)
    state = dh * dh
    # Unavoidable grad I/O: primals + do + dS_out in, dr/dk/dv/dw/du/dh0 out.
    io = b * h * (9 * t * dh + dh + 2 * state) * itemsize
    naive = Traffic(dram_bytes=io + b * h * t * 4 * state * itemsize)
    resid = b * h * (
        6 * t * dh            # logw, cum_incl, cum_excl, r_dec, k_inv, k_rem
        + n * chunk * chunk   # masked scores
        + n * state           # per-chunk scan states (saved by lax.scan)
    ) * itemsize
    bwd_stage = b * h * (
        6 * t * dh            # dscores operands + decay adjoints (dcum_*)
        + n * chunk * chunk   # dscores
        + 2 * t * dh          # intra/inter partial grads
        + 2 * n * state       # dS carry: written + read per chunk
    ) * itemsize
    shared = Traffic(dram_bytes=io, scratchpad_bytes=2 * (resid + bwd_stage))
    # s_hist is direct's one staged intermediate (written fwd, read bwd) —
    # same tier as shared's residuals; everything else is recomputed
    # in-fabric.
    s_hist = b * h * 2 * n * state * itemsize
    direct = Traffic(dram_bytes=io, scratchpad_bytes=s_hist,
                     fabric_bytes=resid + bwd_stage)
    # Recomputed scores/decays + 5 chunk-local (L,L) matmuls + 5 (dh, dh)
    # state-sized matmuls per token block — ~2.5x the forward's MXU work.
    flops = b * h * (
        2 * 5 * n * chunk * chunk * dh
        + 2 * 5 * t * dh * dh
    )
    return (
        KernelCost("wkv_bwd", "naive", naive, flops),
        KernelCost("wkv_bwd", "shared", shared, flops),
        KernelCost("wkv_bwd", "direct", direct, flops),
    )


def wkv_decode_token_io(b: int, h: int, dh: int, k: int = 1,
                        itemsize: int = 4) -> int:
    """Unavoidable decode token I/O: r/k/v/w in, o out, + u once per head.
    Shared by every :func:`wkv_decode_traffic` variant — callers subtract
    it to isolate the state bytes the decode window amortizes."""
    return b * h * k * 5 * dh * itemsize + h * dh * itemsize


def wkv_decode_traffic(b: int, h: int, dh: int, k: int = 1,
                       itemsize: int = 4):
    """WKV decode: K generated tokens through one (Dh × Dh)-state layer.

    naive:  per-token dispatch — the state round-trips HBM every token
            (2·Dh² bytes/token), which dominates decode traffic since the
            token I/O is only O(Dh).  This is what the pre-decode-kernel
            serve loop paid: ``wkv_traffic``'s "naive" row restricted to
            one token, K times.
    shared: the state staged through scratchpad within a window — HBM
            sees one round-trip per window, but every intermediate state
            still crosses a memory tier per token (the GPGPU
            shared-memory rendering).
    direct: the decode window kernel (kernels/wkv/decode): one HBM read
            of S at window entry + one write at exit; the K-1
            intermediate states ride the VMEM carry (fabric tier).
            Per-token state bytes drop by ~K×.
    """
    state = dh * dh
    tok_io = wkv_decode_token_io(b, h, dh, k, itemsize)
    naive = Traffic(dram_bytes=tok_io + b * h * k * 2 * state * itemsize)
    shared = Traffic(
        dram_bytes=tok_io + b * h * 2 * state * itemsize,
        scratchpad_bytes=b * h * 2 * k * state * itemsize,
    )
    direct = Traffic(
        dram_bytes=tok_io + b * h * 2 * state * itemsize,
        fabric_bytes=b * h * 2 * max(k - 1, 0) * state * itemsize,
    )
    # Per token: state matvec read (r·S) + rank-1 update (kᵀv, decay), 2
    # flops per MAC.
    flops = b * h * k * 2 * 2 * dh * dh
    return (
        KernelCost("wkv_decode", "naive", naive, flops),
        KernelCost("wkv_decode", "shared", shared, flops),
        KernelCost("wkv_decode", "direct", direct, flops),
    )


def wkv_seqshard_traffic(b: int, h: int, t: int, dh: int, n_dev: int,
                         itemsize: int = 4):
    """Sequence-parallel WKV: bytes crossing the ``seq`` mesh axis per
    layer step (totals over all devices).

    naive:  re-gather the token activations — every device receives the
            other shards' r/k/v/w and runs the full sequence itself; the
            O(T·D) pattern sequence sharding is supposed to remove.
    shared: all-gather the per-shard exit states behind a barrier (every
            device receives all n (Dh × Dh) states, then composes
            locally) — the GPGPU shared-buffer pattern at ICI granularity.
    direct: the segment-summary protocol (kernels/wkv/seqpar):
            ceil(log2 n) + 1 point-to-point ppermute hops, each moving the
            (decay, state) summary — dh + dh² per (batch, head) — plus
            one masked psum of the final state.  O(Dh²), independent
            of T.
    """
    import math

    state = dh * dh
    summary = state + dh
    # ceil(log2 n) doubling rounds plus the final Δ=+1 boundary shift; at
    # n = 1 the scan degenerates to that single shift (verified against
    # the traced collective count by analysis.collectives' cross-check).
    hops = int(math.ceil(math.log2(max(n_dev, 1)))) + 1
    tokens = 4 * t * dh                               # r, k, v, w
    naive = Traffic(
        dram_bytes=b * h * (n_dev - 1) * tokens * itemsize
    )
    shared = Traffic(
        scratchpad_bytes=b * h * n_dev * (n_dev - 1) * state * itemsize
    )
    direct = Traffic(
        fabric_bytes=b * h * n_dev * (hops * summary + state) * itemsize
    )
    # Same math work on every variant: the local fused sweep dominates;
    # carry composition adds n·hops (Dh²) multiply-adds.
    flops = b * h * (2 * 2 * t * dh * dh + 2 * n_dev * hops * state)
    return (
        KernelCost("wkv_seqshard", "naive", naive, flops),
        KernelCost("wkv_seqshard", "shared", shared, flops),
        KernelCost("wkv_seqshard", "direct", direct, flops),
    )


def serve_batch_steps(new_tokens, slots: int, window: int = 1):
    """Slot-step accounting for a ragged decode workload: lockstep vs
    continuous batching (the scheduler-level rendering of the paper's
    barrier argument — model-independent, so it composes with any
    per-step cost).

    ``new_tokens``: per-request generation budgets, arrival order.
    ``slots``: batch slots.  ``window``: tokens per decode dispatch (K).

    lockstep:   requests run in arrival-order batches of ``slots``; every
                batch is padded to its longest member — a workgroup-global
                barrier: a finished request keeps burning a slot-step per
                step until the slowest one ends, and the next batch waits.
    continuous: finished slots are refilled from the queue at window
                boundaries (each admission emits the request's first
                token from its prefill, the engine contract) — the
                point-to-point hand-off: a slot's next request starts the
                moment the previous one ends.

    Returns ``(useful_tokens, lockstep_steps, continuous_steps)`` where
    the step counts are total slot-steps scanned (useful / steps is the
    utilization; lockstep / continuous is the modeled speedup at equal
    per-step cost).
    """
    new_tokens = [int(n) for n in new_tokens]
    if not new_tokens or slots < 1 or window < 1:
        raise ValueError("need >= 1 request, slots >= 1, window >= 1")
    useful = sum(new_tokens)

    lockstep = 0
    for i in range(0, len(new_tokens), slots):
        batch = new_tokens[i : i + slots]
        # Prefill emits token 1; the remaining max-1 decode in windows of
        # ``window`` steps, every slot of the batch marching together.
        win_steps = -(-(max(batch) - 1) // window) * window if max(batch) > 1 else 0
        lockstep += len(batch) * win_steps

    continuous = 0
    queue = list(new_tokens)[::-1]          # pop() = arrival order
    remaining = [0] * slots
    while queue or any(remaining):
        for s in range(slots):
            if remaining[s] == 0 and queue:
                remaining[s] = queue.pop() - 1   # admission emits token 1
        if not any(remaining):
            # Every live slot finished at admission (budget-1 requests):
            # no window to run — admit again / fall out via the loop test.
            continue
        continuous += slots * window             # one masked window dispatch
        for s in range(slots):
            remaining[s] = max(0, remaining[s] - window)
    return useful, lockstep, continuous


def serve_recovery_steps(prompt_lens, accepted, victim: int,
                         window: int = 1):
    """Positions re-processed to recover ONE faulted slot: isolated
    quarantine+re-prefill vs a batch-global restart (the robustness dual
    of the barrier argument — a fault's blast radius is one slot's
    hand-off, not a workgroup-global rollback).

    ``prompt_lens`` / ``accepted``: per-slot prompt lengths and tokens
    accepted so far; ``victim``: the faulted slot; ``window``: tokens per
    decode dispatch (K).

    isolated: one masked admission prefill replays the victim's prompt +
              accepted prefix — ``prompt_lens[victim] +
              accepted[victim]`` positions, one dispatch, neighbors
              untouched (their cost is zero by the bit-identity
              invariant).
    global:   every slot re-prefills its prompt and the whole batch
              re-decodes to the furthest accepted token in lockstep
              windows — ``sum(prompts) + slots * ceil(max(accepted)/K)*K``
              slot-steps.

    Returns ``(isolated_steps, global_steps)``; global / isolated is the
    modeled recovery-cost ratio of restart-the-world over per-slot
    recovery.
    """
    prompt_lens = [int(p) for p in prompt_lens]
    accepted = [int(a) for a in accepted]
    if len(prompt_lens) != len(accepted) or not prompt_lens:
        raise ValueError("need matching, non-empty prompt/accepted lists")
    if not 0 <= victim < len(prompt_lens) or window < 1:
        raise ValueError("victim out of range or window < 1")
    isolated = prompt_lens[victim] + accepted[victim]
    redecode = -(-max(accepted) // window) * window if max(accepted) else 0
    global_ = sum(prompt_lens) + len(prompt_lens) * redecode
    return isolated, global_


def serve_fleet_drain(work, depths, window: int = 1):
    """Makespan model for routing a burst of requests across a replica
    fleet: recovery-aware least-loaded placement vs depth-blind
    round-robin (the scheduling dual of
    :func:`serve_recovery_steps` — a replica digesting handoff
    re-prefills is *behind*, and a router that ignores that debt piles
    new work onto the busiest replica).

    ``work``: per-request modeled slot-steps (prompt + budget, the same
    unit :func:`serve_batch_steps` counts); ``depths``: per-replica
    pre-existing debt in slot-steps (queued work plus the
    :func:`serve_recovery_steps`-isolated cost of any pending handoff
    re-prefills); ``window``: tokens per decode dispatch — each
    placement is rounded up to whole dispatches.

    Returns ``(aware_steps, blind_steps)``: the drain makespan (max
    per-replica total) under greedy least-loaded placement seeded with
    ``depths``, and under round-robin placement that ignores them.
    ``blind / aware >= 1`` is the modeled win of recovery-aware routing.
    """
    work = [int(w) for w in work]
    depths = [int(d) for d in depths]
    if not depths:
        raise ValueError("need at least one replica depth")
    if window < 1 or any(w < 1 for w in work) or any(d < 0 for d in depths):
        raise ValueError("window < 1, empty work item, or negative depth")
    quant = [-(-w // window) * window for w in work]
    aware = list(depths)
    for w in quant:
        aware[aware.index(min(aware))] += w
    blind = list(depths)
    for i, w in enumerate(quant):
        blind[i % len(blind)] += w
    return max(aware), max(blind)


def serve_paged_pool(prompt_lens, new_tokens, slots: int, page_size: int,
                     window: int = 1):
    """Pages-in-flight accounting for a ragged serve workload: the paged
    pool's high-water mark vs the dense engine's static footprint (the
    statically-partitioned-scratchpad argument applied to KV storage).

    Replays the same admission schedule as :func:`serve_batch_steps`'s
    continuous branch, with the engine's allocate-all-at-admission rule:
    a request entering a slot reserves ``ceil((prompt + budget) /
    page_size)`` pages for its whole lifetime and frees them the step it
    completes.  The dense engine instead provisions every slot for the
    worst request up front — ``slots × ceil(max(prompt + budget) /
    page_size)`` pages live for the whole serve, whatever the actual
    tokens in flight.

    Returns ``(peak_pages, dense_pages)``: the pool high-water mark and
    the dense-equivalent static page count.  ``dense_pages / peak_pages``
    is the modeled capacity win — the pool size at which paged serving
    first matches dense throughput with zero admission waits.
    """
    prompt_lens = [int(p) for p in prompt_lens]
    new_tokens = [int(t) for t in new_tokens]
    if (len(prompt_lens) != len(new_tokens) or not prompt_lens
            or slots < 1 or page_size < 1 or window < 1):
        raise ValueError(
            "need matching non-empty prompts/budgets, slots >= 1, "
            "page_size >= 1, window >= 1")
    need = [-(-(p + t) // page_size) for p, t in zip(prompt_lens, new_tokens)]
    dense_pages = slots * max(need)

    queue = list(range(len(new_tokens)))[::-1]   # pop() = arrival order
    remaining = [0] * slots
    pages = [0] * slots
    peak = 0
    while queue or any(remaining):
        for s in range(slots):
            if remaining[s] == 0:
                pages[s] = 0
                if queue:
                    ri = queue.pop()
                    remaining[s] = max(new_tokens[ri] - 1, 0)
                    pages[s] = need[ri]
                    if remaining[s] == 0:        # done at admission
                        pages[s] = 0
        peak = max(peak, sum(pages))
        if not any(remaining):
            continue
        for s in range(slots):
            if remaining[s] > 0:
                remaining[s] = max(0, remaining[s] - window)
                if remaining[s] == 0:
                    pages[s] = 0
    return peak, dense_pages


def serve_prefix_admission(prefix_len: int, suffix_len: int,
                           n_requests: int, page_size: int):
    """Positions prefilled to admit ``n_requests`` sharing one prefix:
    recurrent-state prefix sharing vs cold re-prefill.

    shared: the prefix's page-aligned head (``floor(prefix_len /
            page_size) × page_size`` positions) is prefilled ONCE — its
            KV pages are shared read-only and its WKV S / RG-LRU h copied
            into each admitted slot — and each admission prefills only
            the leftover prefix tail plus its own suffix.
    cold:   every admission re-prefills prefix + suffix from position 0
            (what the dense engine does for each request).

    Returns ``(shared_positions, cold_positions)``; cold / shared is the
    modeled admission-cost ratio the ``serve_paged`` bench row checks
    against its measured admission times.
    """
    if (prefix_len < 0 or suffix_len < 1 or n_requests < 1
            or page_size < 1):
        raise ValueError(
            "need prefix_len >= 0, suffix_len >= 1, n_requests >= 1, "
            "page_size >= 1")
    aligned = (prefix_len // page_size) * page_size
    shared = aligned + n_requests * (prefix_len - aligned + suffix_len)
    cold = n_requests * (prefix_len + suffix_len)
    return shared, cold


def reduce_traffic(n: int, itemsize: int = 4):
    """Tree reduction: shared version stages each level through scratchpad;
    direct uses windowed elevator edges per level."""
    import math

    steps = max(1, int(math.ceil(math.log2(max(n, 2)))))
    naive = Traffic(dram_bytes=(2 * n) * itemsize)
    shared = Traffic(dram_bytes=n * itemsize, scratchpad_bytes=2 * n * itemsize * 2)
    direct = Traffic(dram_bytes=n * itemsize, fabric_bytes=n * itemsize)
    flops = n
    return (
        KernelCost("reduce", "naive", naive, flops),
        KernelCost("reduce", "shared", shared, flops),
        KernelCost("reduce", "direct", direct, flops),
    )
