"""Lowering-mode flags.

``unrolled_cost_mode``: XLA's HLO cost analysis visits a while-loop body
ONCE, so any ``lax.scan`` hides (trip_count - 1)/trip_count of its FLOPs/
bytes from ``cost_analysis()``.  For roofline extraction the dry-run lowers
a reduced-depth model with every scan unrolled (this flag), then
extrapolates exactly: cost(2 periods) - cost(1 period) = per-period cost.
Normal execution keeps scans rolled (compile time, code size).
"""

from __future__ import annotations

import contextlib
import threading


class _Flags(threading.local):
    unroll = False


_FLAGS = _Flags()


@contextlib.contextmanager
def unrolled_cost_mode():
    prev = _FLAGS.unroll
    _FLAGS.unroll = True
    try:
        yield
    finally:
        _FLAGS.unroll = prev


def scan_unroll() -> bool | int:
    """Value to pass as ``lax.scan(..., unroll=)``."""
    return True if _FLAGS.unroll else 1
