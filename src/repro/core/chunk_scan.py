"""Windowed linear-recurrence scans built from elevator carries.

The paper's prefix-sum example (Fig. 6) is the degenerate case of

    h[t] = a[t] * h[t-1] + b[t]          (a ≡ 1, b = loaded value)

with the inter-thread edge ``fromThreadOrConst<sum, Δ=1, C=0>``.  This module
generalizes the pattern into the workhorse behind the SSM/hybrid
architectures (RG-LRU, RWKV6 decay):

* :func:`linear_scan` — reference associative scan (log-depth, in-core).
* :func:`chunked_linear_scan` — two-level scheme: dense within-chunk scans +
  an across-chunk carry chain.  The carry chain is exactly a cascade of
  elevator nodes with Δ=1 over chunk space; the Pallas kernel
  (:mod:`repro.kernels.elevator_scan`) keeps the carry in VMEM scratch.
* :func:`device_linear_scan_carry` — the same composition across a *mesh*
  axis: each shard contributes its segment summary ``(A, B)``; a log-depth
  Hillis–Steele chain of ``ppermute`` shifts (device-space elevator nodes)
  delivers the entering carry to every shard.  Point-to-point, no gather.

Segment composition law (associative):
    (a1, b1) ∘then∘ (a2, b2) = (a2·a1, a2·b1 + b2)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import device_comm

__all__ = [
    "linear_scan",
    "chunked_linear_scan",
    "device_linear_scan_carry",
]


def _compose(first, second):
    """Compose two recurrence segments; ``first`` is applied first."""
    a1, b1 = first
    a2, b2 = second
    return a2 * a1, a2 * b1 + b2


def linear_scan(a: jax.Array, b: jax.Array, *, axis: int = 0, h0=None) -> jax.Array:
    """h[t] = a[t]*h[t-1] + b[t] with h[-1] = h0 (default 0). Log-depth."""
    if h0 is not None:
        # Fold h0 into the first step: h[0] = a[0]*h0 + b[0].  Promote to
        # jax arrays first so the fold is unconditional — the old
        # ``hasattr(b, "at")`` guard silently dropped h0 for numpy inputs.
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        h0 = jnp.asarray(h0, b.dtype)
        idx = [slice(None)] * b.ndim
        idx[axis] = slice(0, 1)
        first = tuple(idx)
        b = b.at[first].set(a[first] * h0 + b[first])
    _, h = jax.lax.associative_scan(lambda x, y: _compose(x, y), (a, b), axis=axis)
    return h


def chunked_linear_scan(
    a: jax.Array,
    b: jax.Array,
    *,
    chunk: int,
    axis: int = 0,
    h0=None,
) -> jax.Array:
    """Two-level scan: intra-chunk associative scans + inter-chunk carries.

    Mirrors the dMT-CGRA structure: the within-chunk scan is the dataflow
    graph body; the across-chunk carry is the elevator edge (Δ=1 over chunk
    index, C = h0).  Functionally identical to :func:`linear_scan` — the
    tests assert allclose — but exposes the chunked schedule the Pallas
    kernel implements with a VMEM carry.
    """
    a = jnp.moveaxis(a, axis, 0)
    b = jnp.moveaxis(b, axis, 0)
    t = a.shape[0]
    if t % chunk:
        raise ValueError(f"sequence length {t} not divisible by chunk {chunk}")
    n_chunks = t // chunk
    rest = a.shape[1:]
    ac = a.reshape((n_chunks, chunk) + rest)
    bc = b.reshape((n_chunks, chunk) + rest)

    # Intra-chunk inclusive scans (dense, parallel over chunks).
    acum, bcum = jax.lax.associative_scan(_compose, (ac, bc), axis=1)

    # Chunk summaries = last element of each inclusive scan.
    a_sum = acum[:, -1]
    b_sum = bcum[:, -1]

    # Across-chunk carry chain: exclusive scan over chunk summaries.  This is
    # the elevator cascade: carry[k] enters chunk k.
    def step(carry, summary):
        a_s, b_s = summary
        new_carry = a_s * carry + b_s
        return new_carry, carry

    h_init = jnp.zeros(rest, b.dtype) if h0 is None else jnp.broadcast_to(
        jnp.asarray(h0, b.dtype), rest
    )
    _, carries = jax.lax.scan(step, h_init, (a_sum, b_sum))

    # Inject the entering carry into every position of the chunk.
    h = acum * carries[:, None] + bcum
    h = h.reshape((t,) + rest)
    return jnp.moveaxis(h, 0, axis)


def device_linear_scan_carry(a_seg: jax.Array, b_seg: jax.Array, axis_name: str):
    """Entering carry per shard for a sequence sharded over ``axis_name``.

    ``a_seg``/``b_seg`` are the local segment summaries (product of decays,
    accumulated input).  Returns ``(carry_a, carry_b)`` such that the state
    entering shard ``i`` is ``carry_a * h0 + carry_b`` — i.e. the composition
    of all predecessor segments.  log2(n) ppermute hops (Hillis–Steele),
    each a device-space elevator shift with the identity segment (1, 0) as
    the boundary constant.
    """
    n = device_comm.axis_size(axis_name)
    acc_a, acc_b = a_seg, b_seg
    d = 1
    while d < n:
        shifted_a = device_comm.device_shift(acc_a, axis_name, delta=d, fill=1.0)
        shifted_b = device_comm.device_shift(acc_b, axis_name, delta=d, fill=0.0)
        # Predecessor block applied first, current block second.
        acc_a, acc_b = _compose((shifted_a, shifted_b), (acc_a, acc_b))
        d *= 2
    # acc now holds the inclusive composition; the entering carry is the
    # predecessor's inclusive value — one more elevator shift.
    carry_a = device_comm.device_shift(acc_a, axis_name, delta=1, fill=1.0)
    carry_b = device_comm.device_shift(acc_b, axis_name, delta=1, fill=0.0)
    return carry_a, carry_b
