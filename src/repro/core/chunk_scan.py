"""Windowed linear-recurrence scans built from elevator carries.

The paper's prefix-sum example (Fig. 6) is the degenerate case of

    h[t] = a[t] * h[t-1] + b[t]          (a ≡ 1, b = loaded value)

with the inter-thread edge ``fromThreadOrConst<sum, Δ=1, C=0>``.  This module
generalizes the pattern into the workhorse behind the SSM/hybrid
architectures (RG-LRU, RWKV6 decay):

* :func:`linear_scan` — reference associative scan (log-depth, in-core).
* :func:`chunked_linear_scan` — two-level scheme: dense within-chunk scans +
  an across-chunk carry chain.  The carry chain is exactly a cascade of
  elevator nodes with Δ=1 over chunk space; the Pallas kernel
  (:mod:`repro.kernels.elevator_scan`) keeps the carry in VMEM scratch.
* :func:`device_linear_scan_carry` — the same composition across a *mesh*
  axis: each shard contributes its segment summary ``(A, B)``; a log-depth
  Hillis–Steele chain of ``ppermute`` shifts (device-space elevator nodes)
  delivers the entering carry to every shard.  Point-to-point, no gather.

All three run ONE composition law, the :class:`SegmentMonoid`:

    (a1, b1) ∘then∘ (a2, b2) = (a2·a1, a2★b1 + b2)

where ``★`` is the monoid's action of a decay on a state.  Two instances
cover every recurrence in this repo:

* :data:`ELEMENTWISE` — decay and state share a shape; ``★`` is ``*``.
  RG-LRU / diagonal scans (and the paper's prefix sum with a ≡ 1).
* :data:`DIAG_STATE` — decay is a (..., Dh) vector acting on the *rows* of
  a (..., Dh, Dh) matrix state: ``a ★ S = a[..., :, None] * S``.  This is
  the WKV segment summary (diag-decay ⊗ S) of :mod:`repro.kernels.wkv`:
  a whole device's sequence shard collapses to the O(Dh²) pair
  ``(prod w, S_exit)``, which is all that ever crosses the mesh axis.

The *adjoint* of either recurrence is the same monoid swept the other way
(the backward of ``S' = a★S + B`` carries ``dS = a★dS' + dB``), so
``reverse=True`` on the device sweeps gives the device-space reverse
elevator used for sequence-sharded training.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import device_comm

__all__ = [
    "SegmentMonoid",
    "ELEMENTWISE",
    "DIAG_STATE",
    "linear_scan",
    "chunked_linear_scan",
    "device_linear_scan_carry",
]


@dataclasses.dataclass(frozen=True)
class SegmentMonoid:
    """Associative composition of ``(decay, state)`` segment summaries.

    ``scale(a, b)`` is the action of a decay on a state-shaped value.
    Decays always compose elementwise (``a2 * a1``); only the action on the
    state varies between recurrences.  The identity element is ``(1, 0)``
    — exactly the elevator boundary constants :func:`device_comm.device_shift`
    injects at the edge of the fabric.
    """

    scale: Callable[[jax.Array, jax.Array], jax.Array]

    def compose(self, first, second):
        """Summary of ``first``-then-``second`` (first applied first)."""
        a1, b1 = first
        a2, b2 = second
        return a2 * a1, self.scale(a2, b1) + b2

    def apply(self, segment, h):
        """Run a summarized segment from state ``h``: ``a★h + b``."""
        a, b = segment
        return self.scale(a, h) + b


ELEMENTWISE = SegmentMonoid(scale=lambda a, b: a * b)
DIAG_STATE = SegmentMonoid(scale=lambda a, b: a[..., :, None] * b)


def _compose(first, second):
    """Back-compat alias: the elementwise composition law."""
    return ELEMENTWISE.compose(first, second)


def linear_scan(a: jax.Array, b: jax.Array, *, axis: int = 0, h0=None) -> jax.Array:
    """h[t] = a[t]*h[t-1] + b[t] with h[-1] = h0 (default 0). Log-depth."""
    if h0 is not None:
        # Fold h0 into the first step: h[0] = a[0]*h0 + b[0].  Promote to
        # jax arrays first so the fold is unconditional — the old
        # ``hasattr(b, "at")`` guard silently dropped h0 for numpy inputs.
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        h0 = jnp.asarray(h0, b.dtype)
        idx = [slice(None)] * b.ndim
        idx[axis] = slice(0, 1)
        first = tuple(idx)
        b = b.at[first].set(a[first] * h0 + b[first])
    _, h = jax.lax.associative_scan(ELEMENTWISE.compose, (a, b), axis=axis)
    return h


def chunked_linear_scan(
    a: jax.Array,
    b: jax.Array,
    *,
    chunk: int,
    axis: int = 0,
    h0=None,
    monoid: SegmentMonoid = ELEMENTWISE,
) -> jax.Array:
    """Two-level scan: intra-chunk associative scans + inter-chunk carries.

    Mirrors the dMT-CGRA structure: the within-chunk scan is the dataflow
    graph body; the across-chunk carry is the elevator edge (Δ=1 over chunk
    index, C = h0).  Functionally identical to :func:`linear_scan` — the
    tests assert allclose — but exposes the chunked schedule the Pallas
    kernel implements with a VMEM carry.

    With ``monoid=DIAG_STATE`` the state ``b`` carries extra trailing
    dimensions (e.g. a (Dh, Dh) matrix per step decayed by a (Dh,) vector
    ``a``) — the same composition :func:`device_linear_scan_carry` runs
    across a mesh axis for sequence-sharded WKV.
    """
    a = jnp.moveaxis(a, axis, 0)
    b = jnp.moveaxis(b, axis, 0)
    t = a.shape[0]
    if t % chunk:
        raise ValueError(f"sequence length {t} not divisible by chunk {chunk}")
    n_chunks = t // chunk
    rest_a = a.shape[1:]
    rest_b = b.shape[1:]
    ac = a.reshape((n_chunks, chunk) + rest_a)
    bc = b.reshape((n_chunks, chunk) + rest_b)

    # Intra-chunk inclusive scans (dense, parallel over chunks).
    acum, bcum = jax.lax.associative_scan(monoid.compose, (ac, bc), axis=1)

    # Chunk summaries = last element of each inclusive scan.
    a_sum = acum[:, -1]
    b_sum = bcum[:, -1]

    # Across-chunk carry chain: exclusive scan over chunk summaries.  This is
    # the elevator cascade: carry[k] enters chunk k.
    def step(carry, summary):
        new_carry = monoid.apply(summary, carry)
        return new_carry, carry

    h_init = jnp.zeros(rest_b, b.dtype) if h0 is None else jnp.broadcast_to(
        jnp.asarray(h0, b.dtype), rest_b
    )
    _, carries = jax.lax.scan(step, h_init, (a_sum, b_sum))

    # Inject the entering carry into every position of the chunk.
    h = monoid.apply((acum, bcum), carries[:, None])
    h = h.reshape((t,) + rest_b)
    return jnp.moveaxis(h, 0, axis)


def device_linear_scan_carry(
    a_seg: jax.Array,
    b_seg: jax.Array,
    axis_name: str,
    *,
    monoid: SegmentMonoid = ELEMENTWISE,
    reverse: bool = False,
):
    """Entering carry per shard for a sequence sharded over ``axis_name``.

    ``a_seg``/``b_seg`` are the local segment summaries (product of decays,
    accumulated input).  Returns ``(carry_a, carry_b)`` such that the state
    entering shard ``i`` is ``monoid.scale(carry_a, h0) + carry_b`` — i.e.
    the composition of all predecessor segments.  log2(n) ppermute hops
    (Hillis–Steele), each a device-space elevator shift with the identity
    segment (1, 0) as the boundary constant.

    ``reverse=True`` runs the sweep from the *last* shard toward shard 0:
    the carry entering shard ``i`` is then the composition of all successor
    segments (applied last-to-first).  This is the device-space reverse
    elevator — the adjoint carry ``dS`` of a forward recurrence flows
    exactly this way during sequence-sharded training.
    """
    n = device_comm.axis_size(axis_name)
    sgn = -1 if reverse else 1
    acc_a, acc_b = a_seg, b_seg
    d = 1
    while d < n:
        shifted_a = device_comm.device_shift(acc_a, axis_name, delta=sgn * d, fill=1.0)
        shifted_b = device_comm.device_shift(acc_b, axis_name, delta=sgn * d, fill=0.0)
        # Predecessor block applied first, current block second.
        acc_a, acc_b = monoid.compose((shifted_a, shifted_b), (acc_a, acc_b))
        d *= 2
    # acc now holds the inclusive composition; the entering carry is the
    # predecessor's inclusive value — one more elevator shift.
    carry_a = device_comm.device_shift(acc_a, axis_name, delta=sgn, fill=1.0)
    carry_b = device_comm.device_shift(acc_b, axis_name, delta=sgn, fill=0.0)
    return carry_a, carry_b
