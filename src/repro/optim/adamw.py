"""AdamW with ZeRO-compatible sharding, global-norm clipping, schedules.

Optimizer state mirrors the parameter tree (same PartitionSpecs), so under
the production mesh the moments are ZeRO-sharded for free: the param specs
already shard every tensor over ("data", "model").
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment  (param-tree)
    nu: Any          # second moment (param-tree)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_state(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def abstract_state(params) -> AdamWState:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(sds, params),
        nu=jax.tree.map(sds, params),
    )


def state_pspecs(param_specs) -> AdamWState:
    from jax.sharding import PartitionSpec as P

    return AdamWState(step=P(), mu=param_specs, nu=param_specs)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    def upd_leaf(p, g, m, v):
        # Layer-stacked leaves update one layer-slice at a time: the fp32
        # working set is 1/num_layers of the leaf instead of a full fp32
        # image of it (XLA:TPU fuses this chain anyway; the chunking keeps
        # the *unfused* peak bounded too, e.g. 235B-param optimizer state).
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda a: upd(*a), (p, g, m, v))
        return upd(p, g, m, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd_leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
