"""Gradient compression: int8 block quantization with error feedback.

For cross-pod data parallelism the gradient all-reduce crosses the slow
inter-pod links; int8 quantization cuts those bytes 4× (bf16→int8 with a
per-block fp32 scale ≈ 2.03× vs bf16, 4.06× vs fp32).  Error feedback
(Seide et al.; 1-bit SGD lineage) accumulates the quantization residual
locally and re-injects it next step, preserving convergence.

``compressed_gradients`` is a drop-in transform on the grad tree; the
launcher enables it for multi-pod meshes (`--grad-compression int8`).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class ErrorFeedbackState(NamedTuple):
    residual: Any  # param-tree of fp32 residuals


def init_error_feedback(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def quantize_int8(x: jax.Array):
    """Blockwise symmetric int8 quantization along the last axis."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, x.shape, pad


def dequantize_int8(q, scale, shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_leaf(g: jax.Array, residual: jax.Array):
    """Quantize (g + residual); return (dequantized, new_residual)."""
    target = g.astype(jnp.float32) + residual
    q, scale, shape, pad = quantize_int8(target)
    deq = dequantize_int8(q, scale, shape, pad)
    return deq.astype(g.dtype), target - deq


def compressed_gradients(grads, ef: ErrorFeedbackState):
    """Apply int8 + error feedback to every gradient leaf.

    Returns (grads_compressed, new_ef).  On the production mesh this runs
    *before* the cross-pod reduce so the slow links carry int8; in this
    repo's CPU runs the transform exercises the identical numerics.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [compress_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_r = treedef.unflatten([o[1] for o in out])
    return new_g, ErrorFeedbackState(residual=new_r)


def compression_ratio(grads) -> float:
    """Bytes(int8+scales) / bytes(original)."""
    total_in = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
    total_out = sum(
        g.size * 1 + (g.size // BLOCK + 1) * 4 for g in jax.tree.leaves(grads)
    )
    return total_out / max(total_in, 1)
