"""Production meshes (TPU v5e pods).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips — the "pod" axis crosses
the inter-pod DCN/ICI boundary; gradient all-reduce over it is the slow
link that gradient compression targets.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax

# TPU v5e per-chip hardware constants (roofline terms).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~45-50 GB/s on v5e)
ICI_LINKS = 4                   # 2D torus: 4 links per chip


def _make_mesh(shape, axes):
    """jax.make_mesh across versions: 0.4.x has no axis_types kwarg (auto
    mode is the only behavior there, which is what we ask for anyway)."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return _make_mesh((data, model), ("data", "model"))


def make_seq_mesh(n: int | None = None, axis: str = "seq"):
    """1-D mesh over host devices for sequence-parallel runs (benches and
    the multi-device CI lane; production meshes reuse the model axis via
    the ``prefill_seq`` rules instead)."""
    if n is None:
        n = len(jax.devices())
    return _make_mesh((n,), (axis,))
