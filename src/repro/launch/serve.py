"""Serving launcher: batched greedy generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32

``--continuous`` runs the continuous-batching scheduler instead: a ragged
request queue (prompt lengths and budgets drawn per request) served
through a fixed slot pool with EOS/budget detection inside the jitted
window and slot recycling:

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --continuous --requests 8 --slots 2 --temperature 0.8 --top-k 40

Fault-isolation knobs (all ``--continuous``): ``--deadline-ms`` /
``--max-queue`` bound request latency and queue depth (typed ``deadline``
/ ``shed`` outcomes), ``--watchdog-timeout`` arms the per-dispatch hang
watchdog, ``--snapshot-every`` / ``--snapshot-dir`` checkpoint the engine
for preemption recovery, and ``--chaos-seed`` (+ ``--chaos-nan-rate``
etc.) runs the serve under seed-deterministic fault injection — the
chaos-smoke drill asserts every injected fault was quarantined and
recovered with all requests still completing:

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --continuous --requests 6 --slots 2 --chaos-seed 7 --chaos-nan-at 2

``--paged`` swaps the per-slot dense KV rings for a pooled page store
(``--page-size`` / ``--pool-pages``) and runs the paged drill: the same
requests served by a dense reference engine, exiting nonzero unless
every stream is bit-identical and the page-table audit is clean.
``--prefix-len N`` additionally registers one N-token shared prefix and
admits every request through it (recurrent-state prefix sharing):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --continuous --paged --requests 5 --slots 2 --max-len 128 \
      --prefix-len 40 --pool-pages 4

``--replicas N`` (with ``--continuous``) serves the same queue through a
health-checked replica fleet (:mod:`repro.serve.fleet`) instead of one
engine, and runs the fleet drill: ``--chaos-replica-kill-at K`` kills
one replica at its K-th decode dispatch (``--chaos-bitflip-at`` flips a
state bit for the ``--checksum-every`` corruption detector), and the
drill exits nonzero unless every request completes on the survivors
with outcome ok/eos/recovered and every stream is bit-identical to a
fault-free single-engine run:

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --continuous --replicas 3 --requests 6 --slots 2 \
      --snapshot-every 1 --checksum-every 2 --chaos-replica-kill-at 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.model import model as M
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--decode-window", type=int, default=8,
                    help="tokens generated per decode dispatch (K)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching scheduler (ragged queue, "
                         "slot recycling) instead of lockstep generate()")
    ap.add_argument("--requests", type=int, default=8,
                    help="[--continuous] queued requests")
    ap.add_argument("--slots", type=int, default=4,
                    help="[--continuous] batch slots")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="[--continuous] 0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="[--continuous] wall-clock budget per request; "
                         "expired requests end with outcome 'deadline'")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="[--continuous] bounded admission queue beyond "
                         "the slot pool; overflow is shed, not queued")
    ap.add_argument("--watchdog-timeout", type=float, default=None,
                    help="[--continuous] per-dispatch hang deadline (s)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="[--continuous] snapshot the engine every N "
                         "decode dispatches (needs --snapshot-dir)")
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--restore-from", default=None,
                    help="[--continuous] resume a snapshotted serve "
                         "(same requests/args/seed)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="[--continuous] enable seed-deterministic fault "
                         "injection (chaos drill mode: exits nonzero "
                         "unless every fault is recovered)")
    ap.add_argument("--chaos-nan-rate", type=float, default=0.0)
    ap.add_argument("--chaos-drop-rate", type=float, default=0.0)
    ap.add_argument("--chaos-hang-rate", type=float, default=0.0)
    ap.add_argument("--chaos-nan-at", type=int, nargs="*", default=(),
                    help="pin NaN faults to decode-dispatch indices")
    ap.add_argument("--chaos-drop-at", type=int, nargs="*", default=())
    ap.add_argument("--chaos-hang-at", type=int, nargs="*", default=())
    ap.add_argument("--paged", action="store_true",
                    help="[--continuous] pooled KV pages + per-slot page "
                         "tables (drill mode: exits nonzero unless every "
                         "stream is bit-identical to the dense engine)")
    ap.add_argument("--page-size", type=int, default=32,
                    help="[--paged] tokens per KV page (multiple of 32)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="[--paged] private pages per node pool "
                         "(default: dense-equivalent sizing)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="[--paged] register one shared prefix of this "
                         "many tokens and admit every request through it")
    ap.add_argument("--replicas", type=int, default=1,
                    help="[--continuous] serve through a replica fleet "
                         "(drill mode: exits nonzero unless all requests "
                         "complete bit-identically on survivors)")
    ap.add_argument("--checksum-every", type=int, default=0,
                    help="[--continuous] arm silent-corruption checksums; "
                         "shadow spot check every N windows")
    ap.add_argument("--chaos-bitflip-at", type=int, nargs="*", default=(),
                    help="pin silent state bit flips to decode-dispatch "
                         "indices (needs --checksum-every to detect)")
    ap.add_argument("--chaos-replica-kill-at", type=int, nargs="*",
                    default=(),
                    help="[--replicas] kill one replica at these decode-"
                         "dispatch indices (fires once; needs "
                         "--snapshot-every for handoff)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.is_enc_dec:
        raise SystemExit("enc-dec serving demo: use examples/serve_decode.py")

    if args.paged and not args.continuous:
        raise SystemExit("--paged requires --continuous")
    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.replicas > 1 and not args.continuous:
        raise SystemExit("--replicas requires --continuous")
    if args.chaos_bitflip_at and not args.checksum_every:
        raise SystemExit("--chaos-bitflip-at needs --checksum-every to "
                         "be detectable")

    print(f"initializing {cfg.name} ({cfg.param_count()/1e6:.1f}M params)...")
    params = M.init_params(cfg, jax.random.key(args.seed))
    engine = ServeEngine(cfg, params, max_len=args.max_len,
                         decode_window=args.decode_window,
                         paged=args.paged, page_size=args.page_size,
                         pool_pages=args.pool_pages)
    rng = np.random.default_rng(args.seed)

    if args.continuous:
        if args.prompt_len < 1 or args.new_tokens < 1 or args.requests < 1:
            raise SystemExit(
                "--continuous needs --prompt-len, --new-tokens and "
                "--requests all >= 1")
        # Ragged draws in [lo, arg]: lo collapses to the arg itself when
        # the arg is small, so tiny smoke settings stay valid.
        p_lo = min(4, args.prompt_len)
        n_lo = min(2, args.new_tokens)
        reqs = [
            Request(
                tokens=jnp.asarray(
                    rng.integers(
                        0, cfg.vocab_size,
                        (int(rng.integers(p_lo, args.prompt_len + 1)),)),
                    jnp.int32),
                max_new_tokens=int(rng.integers(n_lo, args.new_tokens + 1)),
            )
            for _ in range(args.requests)
        ]
        if args.paged and args.prefix_len:
            if args.prefix_len < args.page_size:
                raise SystemExit("--prefix-len must cover at least one page")
            prefix = rng.integers(
                0, cfg.vocab_size, (args.prefix_len,)).astype(np.int32)
            pid = engine.register_prefix(prefix)
            reqs = [
                Request(tokens=np.concatenate(
                            [prefix, np.asarray(r.tokens, np.int32)]),
                        max_new_tokens=r.max_new_tokens, prefix_id=pid)
                for r in reqs
            ]
        if args.replicas > 1:
            return _fleet_drill(args, cfg, params, reqs)
        paged_ref = None
        if args.paged:
            # Dense reference on the same weights/requests: the paged
            # drill's bit-identity oracle (prefix admissions included —
            # the dense engine just re-prefills the prefix per request).
            dense_eng = ServeEngine(cfg, params, max_len=args.max_len,
                                    decode_window=args.decode_window)
            paged_ref = dense_eng.serve(
                [Request(tokens=r.tokens, max_new_tokens=r.max_new_tokens)
                 for r in reqs],
                slots=args.slots, temperature=args.temperature,
                top_k=args.top_k, eos_id=args.eos_id, seed=args.seed)
        useful = sum(r.max_new_tokens for r in reqs)
        chaos = baseline = None
        if args.chaos_seed is not None:
            from repro.serve.chaos import ChaosInjector

            chaos = ChaosInjector(
                seed=args.chaos_seed, nan_rate=args.chaos_nan_rate,
                drop_rate=args.chaos_drop_rate,
                hang_rate=args.chaos_hang_rate,
                nan_at=tuple(args.chaos_nan_at),
                drop_at=tuple(args.chaos_drop_at),
                hang_at=tuple(args.chaos_hang_at),
                bitflip_at=tuple(args.chaos_bitflip_at),
            )
            # Fault-free reference for the isolation invariant: every
            # request's stream under chaos must match this bit-for-bit.
            baseline = engine.serve(
                reqs, slots=args.slots, temperature=args.temperature,
                top_k=args.top_k, eos_id=args.eos_id, seed=args.seed)
        t0 = time.perf_counter()
        outs = engine.serve(reqs, slots=args.slots,
                            temperature=args.temperature, top_k=args.top_k,
                            eos_id=args.eos_id, seed=args.seed,
                            deadline_ms=args.deadline_ms,
                            max_queue=args.max_queue,
                            watchdog_timeout_s=args.watchdog_timeout,
                            snapshot_every=args.snapshot_every,
                            snapshot_dir=args.snapshot_dir,
                            restore_from=args.restore_from, chaos=chaos,
                            checksum_every=args.checksum_every)
        dt = time.perf_counter() - t0
        emitted = sum(o.size for o in outs)
        st = engine.last_serve_stats
        print(f"served {len(reqs)} ragged requests "
              f"({emitted}/{useful} tokens) in {dt:.2f}s "
              f"({emitted/dt:.1f} tok/s; {st['decode_dispatches']} decode "
              f"dispatches, {st['admissions']} admissions, "
              f"{st['slot_steps']} slot-steps at K={args.decode_window})")
        counts: dict[str, int] = {}
        for o in outs:
            counts[o.outcome] = counts.get(o.outcome, 0) + 1
        print("outcomes:", " ".join(
            f"{k}={v}" for k, v in sorted(counts.items())))
        lens = [int(o.size) for o in outs]
        print(f"per-request emitted lengths: {lens}")
        print("first request tokens:", outs[0].tolist())
        if chaos is not None:
            faults = sum(chaos.counters.values())
            print(f"chaos drill: {faults} injected faults "
                  f"{dict(chaos.counters)}; quarantines="
                  f"{st['quarantines']} recoveries={st['recoveries']} "
                  f"retries={st['dispatch_retries']} "
                  f"watchdog_timeouts={st['watchdog_timeouts']}")
            if faults == 0:
                raise SystemExit("chaos drill injected no faults — "
                                 "pin some with --chaos-nan-at etc.")
            if chaos.counters["nan"] and not st["recoveries"]:
                raise SystemExit("chaos drill: NaN faults injected but "
                                 "none recovered")
            if chaos.counters["bitflip"] and not st["corruptions"]:
                raise SystemExit("chaos drill: bit flips injected but the "
                                 "checksum chain detected none")
            bad = [r for r in outs
                   if r.outcome not in ("ok", "eos", "recovered")]
            if bad:
                raise SystemExit(
                    f"chaos drill: unrecovered outcomes {bad}")
            for i, (want, got) in enumerate(zip(baseline, outs)):
                if not np.array_equal(np.asarray(want), np.asarray(got)):
                    raise SystemExit(
                        f"chaos drill: request {i} diverged from the "
                        "fault-free run — isolation invariant broken")
            print("chaos drill: all faults recovered; every stream "
                  "bit-identical to the fault-free run")
        if args.paged:
            pg = engine.last_paged_stats
            print(f"paged: page_size={pg['page_size']} "
                  f"shared_pages={pg['shared_pages']} "
                  f"pool_bytes={pg['pool_bytes']} "
                  f"dense_bytes={pg['dense_bytes']} "
                  f"peak_mapped_bytes={pg['peak_mapped_bytes']} "
                  f"prefix_admissions={st['prefix_admissions']} "
                  f"page_waits={st['page_waits']}")
            if pg["page_table_violations"]:
                raise SystemExit(
                    f"paged drill: {pg['page_table_violations']} page-"
                    "table violations (double-map / freed-page reach)")
            if args.pool_pages is not None and (
                    pg["pool_bytes"] >= pg["dense_bytes"]):
                raise SystemExit(
                    "paged drill: explicitly sized pool does not beat the "
                    f"dense footprint ({pg['pool_bytes']} >= "
                    f"{pg['dense_bytes']} bytes)")
            for i, (want, got) in enumerate(zip(paged_ref, outs)):
                if want.outcome != got.outcome or not np.array_equal(
                        np.asarray(want), np.asarray(got)):
                    raise SystemExit(
                        f"paged drill: request {i} diverged from the dense "
                        f"engine ({want.outcome} vs {got.outcome}) — paging "
                        "must be an exact storage-layout change")
            print("paged drill: every stream bit-identical to the dense "
                  "engine")
        return

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. prefill; "
          f"{engine.last_decode_dispatches} decode dispatches at "
          f"K={args.decode_window})")
    print("first sequence:", np.asarray(out[0]).tolist())


def _fleet_drill(args, cfg, params, reqs):
    """Serve ``reqs`` through a replica fleet and hold it to the
    single-engine bar: every request completes on the survivors with a
    clean outcome, bit-identical to a fault-free single-engine run."""
    import tempfile

    from repro.serve.chaos import ChaosInjector
    from repro.serve.fleet import FleetRouter

    kill_at = tuple(args.chaos_replica_kill_at)
    bitflip_at = tuple(args.chaos_bitflip_at)
    if kill_at and not args.snapshot_every:
        raise SystemExit("--chaos-replica-kill-at needs --snapshot-every "
                         "(handoff resumes from the victim's snapshot)")

    def build():
        return ServeEngine(cfg, params, max_len=args.max_len,
                           decode_window=args.decode_window,
                           paged=args.paged, page_size=args.page_size,
                           pool_pages=args.pool_pages)

    # Fault-free single-engine reference (recoverable=True so the ring
    # sizing — and with it every stream — matches the fleet's sessions).
    baseline = build().serve(
        reqs, slots=args.slots, temperature=args.temperature,
        top_k=args.top_k, eos_id=args.eos_id, seed=args.seed,
        recoverable=True)

    engines = [build() for _ in range(args.replicas)]
    victim = 1 if args.replicas > 1 else 0
    chaos = None
    if kill_at or bitflip_at or args.chaos_seed is not None:
        chaos = [None] * args.replicas
        chaos[victim] = ChaosInjector(
            seed=args.chaos_seed or 0, nan_rate=args.chaos_nan_rate,
            nan_at=tuple(args.chaos_nan_at), bitflip_at=bitflip_at,
            replica_kill_at=kill_at)
    snap_root = args.snapshot_dir or (
        tempfile.mkdtemp(prefix="fleet_snap_") if args.snapshot_every
        else None)
    t0 = time.perf_counter()
    fleet = FleetRouter(
        engines, reqs, slots=args.slots, temperature=args.temperature,
        top_k=args.top_k, eos_id=args.eos_id, seed=args.seed,
        deadline_ms=args.deadline_ms, max_queue=args.max_queue,
        watchdog_timeout_s=args.watchdog_timeout,
        snapshot_every=args.snapshot_every, snapshot_root=snap_root,
        checksum_every=args.checksum_every, chaos=chaos)
    outs = fleet.run()
    dt = time.perf_counter() - t0
    emitted = sum(o.size for o in outs)
    st = fleet.stats
    print(f"fleet served {len(reqs)} requests over {args.replicas} "
          f"replicas ({emitted} tokens) in {dt:.2f}s "
          f"({emitted/dt:.1f} tok/s; {st['rounds']} rounds, "
          f"{st['assignments']} assignments, {st['replica_deaths']} "
          f"deaths, {st['handoffs']} handoffs)")
    per = fleet.stats_by_replica()
    print("per-replica dispatches:",
          [s["decode_dispatches"] for s in per],
          "states:", [m.state for m in fleet.monitors])
    counts: dict[str, int] = {}
    for o in outs:
        counts[o.outcome] = counts.get(o.outcome, 0) + 1
    print("outcomes:", " ".join(
        f"{k}={v}" for k, v in sorted(counts.items())))
    if kill_at:
        if not st["replica_deaths"]:
            raise SystemExit("fleet drill: pinned replica kill never fired")
        if not (st["handoffs"] or st["handoff_requeued_fresh"]):
            raise SystemExit("fleet drill: replica died but nothing was "
                             "handed off or re-queued")
    if bitflip_at and not sum(s["corruptions"] for s in per):
        raise SystemExit("fleet drill: bit flips injected but the "
                         "checksum chain detected none")
    bad = [o.outcome for o in outs
           if o.outcome not in ("ok", "eos", "recovered")]
    if bad:
        raise SystemExit(f"fleet drill: unclean outcomes {bad}")
    for i, (want, got) in enumerate(zip(baseline, outs)):
        if not np.array_equal(np.asarray(want), np.asarray(got)):
            raise SystemExit(
                f"fleet drill: request {i} diverged from the fault-free "
                "single-engine run — handoff broke bit-identity")
    print("fleet drill: all requests completed on survivors, every "
          "stream bit-identical to the fault-free single-engine run")


if __name__ == "__main__":
    main()
