"""Serving launcher: batched greedy generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.model import model as M
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--decode-window", type=int, default=8,
                    help="tokens generated per decode dispatch (K)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.is_enc_dec:
        raise SystemExit("enc-dec serving demo: use examples/serve_decode.py")

    print(f"initializing {cfg.name} ({cfg.param_count()/1e6:.1f}M params)...")
    params = M.init_params(cfg, jax.random.key(args.seed))
    engine = ServeEngine(cfg, params, max_len=args.max_len,
                         decode_window=args.decode_window)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. prefill; "
          f"{engine.last_decode_dispatches} decode dispatches at "
          f"K={args.decode_window})")
    print("first sequence:", np.asarray(out[0]).tolist())


if __name__ == "__main__":
    main()
