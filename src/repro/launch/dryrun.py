import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 host-platform placeholder devices back the
(2, 16, 16) production mesh.  Nothing is executed — ``.lower().compile()``
proves the distribution config is coherent, ``memory_analysis()`` proves it
fits, ``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import get_config, list_archs
from repro.launch import inputs as I
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh
from repro.model import model as M
from repro.model.sharding import make_rules, sharding_context, to_pspec
from repro.optim import adamw
from repro.serve import engine
from repro.train import step as train_mod

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _mode_for(shape_name: str, kind: str) -> str:
    if kind == "train":
        return "train"
    if kind == "prefill":
        return "prefill"
    return "decode_long" if shape_name == "long_500k" else "decode"


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg_override=None):
    """Build (lowered, mesh, rules) for one cell. Raises on inapplicable."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = I.cell_is_applicable(cfg, shape_name)
    if not ok:
        raise SkipCell(why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    mode = _mode_for(shape_name, shape.kind)
    rules = make_rules(mesh, mode)

    if shape.kind == "train":
        state_specs = train_mod.train_state_pspecs(cfg, rules)
        state_sds = train_mod.abstract_train_state(cfg)
        batch_sds, batch_axes = I.batch_specs(cfg, shape)
        batch_specs_tree = I.resolve_pspecs(batch_axes, rules)
        step_fn = train_mod.make_train_step(cfg)

        def fn(state, batch):
            new_state, metrics = step_fn(state, batch)
            return new_state, metrics

        in_sh = (_named(mesh, state_specs), _named(mesh, batch_specs_tree))
        with mesh, sharding_context(mesh, rules):
            lowered = jax.jit(
                fn, in_shardings=in_sh, donate_argnums=(0,)
            ).lower(state_sds, batch_sds)

    elif shape.kind == "prefill":
        params_specs = M.param_pspecs(cfg, rules)
        params_sds = M.abstract_params(cfg)
        batch_sds, batch_axes = I.batch_specs(cfg, shape)
        batch_specs_tree = I.resolve_pspecs(batch_axes, rules)
        prefill = engine.make_prefill_step(cfg)

        def fn(params, batch):
            kw = {}
            if "frontend_embeds" in batch:
                kw["frontend_embeds"] = batch["frontend_embeds"]
            if "positions" in batch:
                kw["positions"] = batch["positions"]
            if "enc_embeds" in batch:
                kw["enc_tokens_embeds"] = batch["enc_embeds"]
            return prefill(params, batch["tokens"], **kw)

        in_sh = (_named(mesh, params_specs), _named(mesh, batch_specs_tree))
        with mesh, sharding_context(mesh, rules):
            lowered = jax.jit(fn, in_shardings=in_sh).lower(params_sds, batch_sds)

    else:  # decode
        import dataclasses as dc

        dcfg = dc.replace(cfg, remat="none", microbatch=1)
        params_specs = M.param_pspecs(dcfg, rules)
        params_sds = M.abstract_params(dcfg)
        state_sds, tok_sds, len_sds, extras, extras_axes = I.decode_specs(dcfg, shape)
        state_specs = M.decode_state_pspecs(
            dcfg, shape.global_batch, shape.seq_len, rules
        )
        decode = engine.make_decode_step(dcfg)

        if extras:
            enc_spec = to_pspec(extras_axes["enc_out"], rules)

            def fn(params, state, tokens, length, enc_out):
                return decode(params, state, tokens, length, enc_out=enc_out)

            in_sh = (
                _named(mesh, params_specs), _named(mesh, state_specs),
                NamedSharding(mesh, to_pspec(("batch", None), rules)),
                NamedSharding(mesh, P()),
                NamedSharding(mesh, enc_spec),
            )
            args = (params_sds, state_sds, tok_sds, len_sds, extras["enc_out"])
        else:
            def fn(params, state, tokens, length):
                return decode(params, state, tokens, length)

            in_sh = (
                _named(mesh, params_specs), _named(mesh, state_specs),
                NamedSharding(mesh, to_pspec(("batch", None), rules)),
                NamedSharding(mesh, P()),
            )
            args = (params_sds, state_sds, tok_sds, len_sds)

        with mesh, sharding_context(mesh, rules):
            lowered = jax.jit(fn, in_shardings=in_sh, donate_argnums=(1,)).lower(*args)

    return lowered, mesh, rules, cfg, shape


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: Path | None = None, verbose: bool = True,
             roofline: bool = True) -> dict:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_tag}"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_tag}
    t0 = time.time()
    try:
        lowered, mesh, rules, cfg, shape = lower_cell(
            arch, shape_name, multi_pod=multi_pod
        )
        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

        ma = compiled.memory_analysis()
        result["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        }
        ca = R.cost_analysis_dict(compiled)
        result["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        hlo = compiled.as_text()
        result["hlo_bytes"] = len(hlo)
        coll = R.parse_collective_bytes(hlo)
        result["collectives_raw"] = coll
        del compiled, lowered, hlo

        # Raw rolled-program numbers undercount while-loop bodies; the
        # roofline terms come from the exact bilinear extrapolation over
        # reduced-depth unrolled lowers (single-pod only, per spec).
        if roofline and not multi_pod:
            from repro.launch.roofline_run import extrapolated_costs

            ex = extrapolated_costs(arch, shape_name, multi_pod=False)
            tot = ex["extrapolated"]
            terms = R.roofline_terms(
                {"flops": tot["flops"], "bytes accessed": tot["bytes"]},
                {"total_bytes": tot["coll"]},
            )
            result["roofline"] = terms.as_dict()
            # Fused-execution HBM estimate (CPU HLO bytes are unfused; see
            # roofline.analytic_hbm_bytes docstring + EXPERIMENTS.md).
            mode = _mode_for(shape_name, shape.kind)
            ana = R.analytic_hbm_bytes(cfg, shape, 256, mode)
            result["roofline"]["memory_analytic_s"] = ana / 819e9
            result["roofline"]["hbm_bytes_analytic"] = ana
            terms_f = {
                "compute": result["roofline"]["compute_s"],
                "memory(fused est)": result["roofline"]["memory_analytic_s"],
                "collective": result["roofline"]["collective_s"],
            }
            result["roofline"]["dominant_fused"] = max(terms_f, key=terms_f.get)
            result["collectives_by_op"] = tot["coll_by_op"]
            result["model_flops"] = R.model_flops(cfg, shape)
            n_chips = 512 if multi_pod else 256
            result["model_flops_per_chip"] = result["model_flops"] / n_chips
            result["useful_flops_ratio"] = (
                result["model_flops_per_chip"] / tot["flops"]
                if tot["flops"]
                else None
            )
        result["ok"] = True
    except SkipCell as e:
        result["ok"] = True
        result["skipped"] = str(e)
    except Exception as e:  # noqa: BLE001 — reported as a failed cell
        result["ok"] = False
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]

    result["total_s"] = round(time.time() - t0, 1)
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=1))
    if verbose:
        status = "SKIP" if result.get("skipped") else ("OK" if result["ok"] else "FAIL")
        extra = ""
        if "roofline" in result:
            r = result["roofline"]
            extra = (f" dom={r.get('dominant_fused', r['dominant'])} "
                     f"comp={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s "
                     f"memF={r.get('memory_analytic_s', 0):.4f}s "
                     f"coll={r['collective_s']:.4f}s")
        if "memory" in result:
            extra += f" peak={result['memory']['peak_estimate_bytes']/2**30:.2f}GiB"
        print(f"[{status}] {tag} ({result['total_s']}s){extra}", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = [args.arch] if args.arch else list(list_archs())
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_tag = "2x16x16" if multi_pod else "16x16"
                tag = f"{arch}__{shape}__{mesh_tag}"
                if args.skip_existing and (out_dir / f"{tag}.json").exists():
                    prev = json.loads((out_dir / f"{tag}.json").read_text())
                    if prev.get("ok"):
                        print(f"[CACHED] {tag}", flush=True)
                        continue
                res = run_cell(arch, shape, multi_pod=multi_pod, out_dir=out_dir,
                               roofline=not args.no_roofline)
                failures += 0 if res["ok"] else 1
    print(f"done; failures={failures}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
