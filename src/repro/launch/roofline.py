"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh) cell, in seconds (per step):

  compute    = HLO_FLOPs            / PEAK_FLOPS_BF16      (per chip)
  memory     = HLO_bytes_accessed   / HBM_BW               (per chip)
  collective = Σ collective bytes   / (ICI_BW_PER_LINK)    (per chip)

``cost_analysis()`` is per-device (the SPMD program), so no further
division by chip count.  Collective bytes are parsed from the
post-partitioning HLO text (they do not appear in cost_analysis).
"""

from __future__ import annotations

import dataclasses
import re

from repro.launch import mesh as mesh_mod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Result types preceding the op name, e.g.
#   %x = bf16[16,128]{1,0} all-gather(...)
#   %y = (f32[8], f32[16]) all-reduce-start(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: 0.4.x
    returns a one-dict-per-program list, newer jax a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-collective-op byte totals + counts from post-SPMD HLO."""
    out = {op: {"bytes": 0, "count": 0} for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # Skip the -done halves of async pairs (counted at -start).
        if f"{op}-done" in line:
            continue
        # Result type(s) sit inside the matched "= <type> op(" span.
        total = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(m.group(0))
        )
        out[op]["bytes"] += total
        out[op]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    collective_bytes: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "dominant": self.dominant,
        }


def roofline_terms(cost: dict, collectives: dict) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = float(collectives.get("total_bytes", 0))
    return RooflineTerms(
        compute_s=flops / mesh_mod.PEAK_FLOPS_BF16,
        memory_s=hbm / mesh_mod.HBM_BW,
        collective_s=coll / (mesh_mod.ICI_BW_PER_LINK * mesh_mod.ICI_LINKS),
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D (+ attention quadratic term) per step.

    train counts fwd+bwd (×3 of forward's 2ND); prefill counts forward;
    decode counts one token (D = batch tokens).
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn = _attention_flops(cfg, shape.seq_len, shape.global_batch) * 3
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn = _attention_flops(cfg, shape.seq_len, shape.global_batch)
    else:  # decode: one new token per sequence
        tokens = shape.global_batch * 1
        base = 2.0 * n_active * tokens
        attn = _decode_attention_flops(cfg, shape.seq_len, shape.global_batch)
    return base + attn


def _attention_flops(cfg, s: int, b: int) -> float:
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind not in ("attn", "local", "global"):
            continue
        window = cfg.attn_window if kind == "local" else None
        eff = min(window, s) if window else s
        # 2 matmuls (QK^T and PV), causal halves the full square.
        per_q = eff if window else s / 2
        total += 2 * 2 * b * s * per_q * cfg.num_heads * cfg.head_dim
    if cfg.is_enc_dec:
        total *= 2.2  # encoder self + decoder self + cross (approx)
    return total


def _decode_attention_flops(cfg, s: int, b: int) -> float:
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind in ("attn", "global"):
            total += 2 * 2 * b * s * cfg.num_heads * cfg.head_dim
        elif kind == "local":
            total += 2 * 2 * b * min(cfg.attn_window or s, s) * cfg.num_heads * cfg.head_dim
        elif kind in ("rec", "rwkv"):
            total += 2 * b * (cfg.d_rnn or cfg.d_model) * 4
    return total


def analytic_hbm_bytes(cfg, shape, n_chips: int, mode: str) -> float:
    """Fused-execution HBM traffic estimate per chip (roofline lower bound).

    ``cost_analysis()['bytes accessed']`` on the CPU-compiled module counts
    every unfused intermediate, overstating TPU traffic by ~10-100x (XLA:TPU
    fuses elementwise chains into single HBM passes; Pallas kernels keep
    block working sets in VMEM).  This model counts only irreducible HBM
    passes; the table reports both (see EXPERIMENTS.md §Roofline method).
    """
    itemsize = 2  # bf16 params/activations
    params = cfg.param_count()
    params_active = cfg.active_param_count()
    p_dev = params * itemsize / n_chips
    d = cfg.d_model
    tp = 16  # model-axis width

    if mode == "train":
        m = max(1, cfg.microbatch)
        tokens_dev = shape.global_batch * shape.seq_len / (n_chips / tp)
        # Params: per microbatch the data-axis all-gather materializes the
        # model-shard (params/tp) for fwd + bwd reads; optimizer rw in fp32.
        gathered = params * itemsize / tp
        param_traffic = m * 2 * gathered + p_dev / itemsize * (4 + 8 + 8 + 2 + 8)
        if cfg.num_experts:
            # Only routed experts' weights stream per microbatch.
            param_traffic *= params_active / params * 0.5 + 0.5
        # Activations: residual stream per layer (fwd save + bwd read +
        # recompute write/read) ~4 passes; ~6 intermediate tensors per layer
        # fused into ~3 extra passes of d-width traffic.
        act = tokens_dev * d * itemsize * cfg.num_layers * 7
        # Logits + CE in fp32 (vocab sharded over tp).
        logits = tokens_dev * cfg.vocab_size / tp * 4 * 3
        return (param_traffic + act + logits) / 1.0
    if mode == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / (n_chips / tp)
        param_traffic = params * itemsize / tp
        act = tokens_dev * d * itemsize * cfg.num_layers * 3
        logits = tokens_dev * cfg.vocab_size / tp * 4
        return param_traffic + act + logits
    # decode: every step streams active params once + reads the KV cache.
    param_traffic = params_active * itemsize / n_chips
    kv = 0.0
    for kind in cfg.layer_kinds:
        if kind in ("attn", "global"):
            s_eff = shape.seq_len
        elif kind == "local":
            s_eff = min(cfg.attn_window or shape.seq_len, shape.seq_len)
        else:
            s_eff = (cfg.d_rnn or d)  # recurrent state, not seq-length bound
            kv += shape.global_batch * s_eff * 4 * 2 / n_chips
            continue
        kv += (
            shape.global_batch * cfg.num_kv_heads * s_eff * cfg.head_dim
            * itemsize * 2 / n_chips
        )
    return param_traffic + kv
