"""Exact roofline cost extraction via reduced-depth unrolled extrapolation.

XLA's HLO cost analysis visits while-loop bodies once, so the rolled
production program underreports FLOPs/bytes/collectives by the scan trip
counts.  Instead of unrolling the full model (compile-time explosion), we
exploit bilinearity: with L = layer periods and m = microbatches,

    cost(L, m) = a + b·L + c·m + d·L·m

(a: fixed embed/logits/optimizer-base work; b: per-period work incl. its
optimizer update; c: per-microbatch fixed work, e.g. logits per chunk;
d: per-period-per-microbatch work, e.g. FSDP param all-gathers).  Four
small *fully-unrolled* lowers — (L₁,1), (L₂,1), (L₁,2), (L₂,2) — identify
(a,b,c,d) exactly, and the full cell's cost is evaluated at
(n_periods, n_micro).  Remainder layers are included in both L points so
they fold into `a`.  Decode/prefill cells have no microbatch loop → 2
points suffice.  Token-proportional work is constant in m (each microbatch
carries 1/m of the batch), so it lands in a + b·L, as required.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.launch import roofline as R
from repro.core.lowering import unrolled_cost_mode
from repro.model.transformer import plan_groups


def _measure(arch, shape_name, cfg, *, multi_pod=False):
    """Lower one reduced config fully unrolled; return cost dict."""
    from repro.launch.dryrun import lower_cell

    with unrolled_cost_mode():
        lowered, mesh, rules, _, _ = lower_cell(
            arch, shape_name, multi_pod=multi_pod, cfg_override=cfg
        )
    compiled = lowered.compile()
    ca = R.cost_analysis_dict(compiled)
    coll = R.parse_collective_bytes(compiled.as_text())
    out = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": float(coll["total_bytes"]),
        "coll_by_op": {
            k: v["bytes"] for k, v in coll.items() if isinstance(v, dict)
        },
    }
    del compiled, lowered
    return out


def _combine(c1, c2, w1, w2):
    out = {k: w1 * c1[k] + w2 * c2[k] for k in ("flops", "bytes", "coll")}
    out["coll_by_op"] = {
        k: w1 * c1["coll_by_op"][k] + w2 * c2["coll_by_op"][k]
        for k in c1["coll_by_op"]
    }
    return out


def extrapolated_costs(arch: str, shape_name: str, *, multi_pod: bool = False,
                       verbose: bool = True, base_cfg=None) -> dict:
    """Per-device HLO cost of the FULL cell, via bilinear extrapolation."""
    cfg = base_cfg if base_cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    pattern, n_periods, remainder = plan_groups(cfg)
    p, r = len(pattern), len(remainder)
    l1, l2 = p + r, 2 * p + r

    enc_full = cfg.encoder_layers
    enc_ratio = enc_full / cfg.num_layers if enc_full else 0.0

    def reduced(n_layers, n_micro):
        return dataclasses.replace(
            cfg,
            num_layers=n_layers,
            microbatch=n_micro,
            encoder_layers=max(1, round(n_layers * enc_ratio)) if enc_full else 0,
        )

    has_micro = shape.kind == "train" and cfg.microbatch > 1
    n_micro_full = cfg.microbatch if shape.kind == "train" else 1

    c11 = _measure(arch, shape_name, reduced(l1, 1), multi_pod=multi_pod)
    c21 = _measure(arch, shape_name, reduced(l2, 1), multi_pod=multi_pod)
    # Per-period slope at m=1; intercept (embed/logits/opt + remainder).
    b1 = _combine(c21, c11, 1.0, -1.0)               # b + d   (at m=1)
    a1 = _combine(c11, b1, 1.0, -1.0)                # a + c   (at m=1)

    if has_micro:
        c12 = _measure(arch, shape_name, reduced(l1, 2), multi_pod=multi_pod)
        c22 = _measure(arch, shape_name, reduced(l2, 2), multi_pod=multi_pod)
        b2 = _combine(c22, c12, 1.0, -1.0)           # b + 2d
        d = _combine(b2, b1, 1.0, -1.0)              # d
        b = _combine(b1, d, 1.0, -1.0)               # b
        a2 = _combine(c12, b2, 1.0, -1.0)            # a + 2c
        c = _combine(a2, a1, 1.0, -1.0)              # c
        a = _combine(a1, c, 1.0, -1.0)               # a
        m = n_micro_full
        total = _combine(
            _combine(a, b, 1.0, float(n_periods)),
            _combine(c, d, float(m), float(n_periods * m)),
            1.0, 1.0,
        )
        points = {"c11": c11, "c21": c21, "c12": c12, "c22": c22}
    else:
        total = _combine(a1, b1, 1.0, float(n_periods))
        points = {"c11": c11, "c21": c21}

    if verbose:
        print(
            f"  roofline[{arch} {shape_name}]: flops/dev={total['flops']:.3e} "
            f"bytes/dev={total['bytes']:.3e} coll/dev={total['coll']:.3e}",
            flush=True,
        )
    return {
        "extrapolated": total,
        "n_periods": n_periods,
        "n_micro": n_micro_full,
        "points": points,
    }
