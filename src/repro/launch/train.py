"""Training launcher: real execution on available devices, full FT loop.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt

On the CPU container this drives the reduced (smoke) configs end-to-end —
the same code path a TPU job uses, minus mesh size.  Fault tolerance comes
from ft.watchdog.run_with_restarts + checkpoint.AsyncSaver; the data
pipeline is stateless (step-keyed), so restarts never skip or repeat data.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.ft.watchdog import run_with_restarts
from repro.optim import adamw
from repro.train import step as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.frontend or cfg.is_enc_dec:
        # Text-only training driver; frontend archs train their backbone on
        # token streams (stub embeddings are a serving-time input).
        cfg = dataclasses.replace(cfg, frontend=None)

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
    )
    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 1), total_steps=args.steps
    )
    # Donated TrainState: params + optimizer moments update in place
    # instead of copying two model-sized trees per step (and the
    # repro.analysis donation pass audits exactly this entrypoint).
    step_fn = train_mod.make_jitted_train_step(
        cfg, opt_cfg, compress=args.compress_grads
    )
    saver = ckpt.AsyncSaver()
    metrics_log = []

    def make_state():
        return train_mod.init_train_state(
            cfg, jax.random.key(args.seed), compress=args.compress_grads
        )

    def do_step(state, step):
        if cfg.is_enc_dec:
            batch = make_batch(dcfg, step)
            batch["enc_embeds"] = jnp.zeros(
                (args.batch, args.seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        else:
            batch = make_batch(dcfg, step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        metrics_log.append((step, loss))
        if step % args.log_every == 0:
            tok_s = args.batch * args.seq / dt
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{tok_s:,.0f} tok/s", flush=True)
        return state

    def save_fn(state, step):
        if args.ckpt_dir:
            saver.save_async(args.ckpt_dir, step, state)

    def restore_fn():
        if not args.ckpt_dir:
            return None
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is None:
            return None
        template = make_state()
        state, step = ckpt.restore(args.ckpt_dir, template)
        return state, step

    state, stats = run_with_restarts(
        make_state=make_state,
        step_fn=do_step,
        save_fn=save_fn,
        restore_fn=restore_fn,
        num_steps=args.steps,
        checkpoint_every=args.ckpt_every,
        watchdog_timeout_s=1800.0,
        on_event=lambda m: print(f"[ft] {m}", flush=True),
    )
    saver.wait()
    first = metrics_log[0][1] if metrics_log else float("nan")
    last = metrics_log[-1][1] if metrics_log else float("nan")
    print(f"done: steps={stats['steps_run']} restarts={stats['restarts']} "
          f"loss {first:.4f} -> {last:.4f}", flush=True)


if __name__ == "__main__":
    main()
