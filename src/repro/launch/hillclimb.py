import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing: A/B config variants on the three chosen cells.

Each experiment = (cell, variant-name, config-transform).  For every
variant we re-run the exact roofline extraction (bilinear extrapolated
unrolled lowers) and the full-model compile (memory), then record
hypothesis -> before -> after into experiments/perf/.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --cell moe   # qwen3 train
  PYTHONPATH=src python -m repro.launch.hillclimb --cell vl    # qwen2-vl prefill
  PYTHONPATH=src python -m repro.launch.hillclimb --cell rwkv  # rwkv6 train
  PYTHONPATH=src python -m repro.launch.hillclimb --variant a2a --cell moe
"""

import argparse
import dataclasses
import json
import time
from pathlib import Path

PERF_DIR = Path(__file__).resolve().parents[3] / "experiments" / "perf"


def _variants():
    """cell key -> (arch, shape, {variant: transform})."""
    return {
        "moe": (
            "qwen3-moe-235b-a22b", "train_4k",
            {
                "baseline": lambda c: c,
                # H1: replace gather-MoE (GSPMD all-gathers the token
                # activations per layer) with shard_map all-to-all dispatch:
                # collective bytes per MoE layer should drop from
                # O(tokens*d*tp) to 2*k*cf*tokens*d.
                "a2a": lambda c: dataclasses.replace(c, moe_impl="a2a"),
                # H2: a2a + fewer microbatches => fewer FSDP param re-gathers
                # (params re-gather once per microbatch); activation memory
                # rises, traded against collective time.
                "a2a_mb8": lambda c: dataclasses.replace(
                    c, moe_impl="a2a", microbatch=8
                ),
                # H3: lighter remat: keep dots, recompute elementwise only —
                # trades HBM for fewer recomputed FLOPs.
                "a2a_remat_dots": lambda c: dataclasses.replace(
                    c, moe_impl="a2a", remat="dots"
                ),
                # H4: ZeRO-3 weight gathering — gather FSDP weight shards at
                # use instead of letting GSPMD all-reduce partial activations
                # (collective bytes: activations >> weights at 4k tokens).
                "a2a_wgather": lambda c: dataclasses.replace(
                    c, moe_impl="a2a", fsdp_gather_weights=True
                ),
            },
        ),
        "vl": (
            "qwen2-vl-7b", "prefill_32k",
            {
                "baseline": lambda c: c,
                # H1: 28 heads don't divide TP=16 -> GSPMD replicates
                # attention activations over the model axis.  Pad to 32
                # zero-capacity heads (2 per shard): activations shard, the
                # resharding all-gathers disappear.
                "head_pad32": lambda c: dataclasses.replace(c, head_pad=4),
                # H2: head padding + chunked prefill (batch 32 -> 4 chunks):
                # bounds live activations; collectives unchanged per token.
                "head_pad32_chunked": lambda c: dataclasses.replace(
                    c, head_pad=4, prefill_chunks=4
                ),
                # H3: weight gathering on top — prefill contracts sharded
                # weight dims against 1M-token activations otherwise.
                "head_pad32_wgather": lambda c: dataclasses.replace(
                    c, head_pad=4, fsdp_gather_weights=True
                ),
            },
        ),
        "rwkv": (
            "rwkv6-1.6b", "train_4k",
            {
                "baseline": lambda c: c,
                # H1: microbatch 2 -> 1: halves per-step FSDP param
                # re-gathers (grad accumulation re-gathers every microbatch);
                # WKV activations are small, memory can absorb it.
                "mb1": lambda c: dataclasses.replace(c, microbatch=1),
                # H2: wider WKV head-state chunks: chunk 16 -> 64 quarters
                # the number of inter-chunk state round-trips per layer
                # (carry traffic), at slightly higher in-chunk flops.
                # (chunk is a call-site arg; exposed via rwkv_chunk.)
                "mb1_remat_dots": lambda c: dataclasses.replace(
                    c, microbatch=1, remat="dots"
                ),
                # H3: weight gathering (118GB/dev of all-reduce in the
                # baseline comes from contracting FSDP-sharded weight dims).
                "mb1_wgather": lambda c: dataclasses.replace(
                    c, microbatch=1, fsdp_gather_weights=True
                ),
            },
        ),
    }


def run_variant(arch, shape_name, name, transform, out_dir: Path):
    import jax

    from repro.configs.registry import get_config
    from repro.launch import roofline as R
    from repro.launch.dryrun import lower_cell
    from repro.launch.roofline_run import extrapolated_costs

    # Patch get_config so every downstream consumer sees the variant.
    import repro.configs.registry as reg

    base = get_config(arch)
    cfg_v = transform(base)
    orig = reg.get_config
    reg.get_config = lambda n: cfg_v if reg.canonical(n) == reg.canonical(arch) else orig(n)
    result = {"arch": arch, "shape": shape_name, "variant": name}
    t0 = time.time()
    try:
        ex = extrapolated_costs(arch, shape_name, multi_pod=False, base_cfg=cfg_v)
        tot = ex["extrapolated"]
        terms = R.roofline_terms(
            {"flops": tot["flops"], "bytes accessed": tot["bytes"]},
            {"total_bytes": tot["coll"]},
        )
        result["roofline"] = terms.as_dict()
        result["collectives_by_op"] = tot["coll_by_op"]

        lowered, *_ = lower_cell(arch, shape_name, multi_pod=False,
                                 cfg_override=cfg_v)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        result["peak_bytes"] = (
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        )
        result["ok"] = True
        del compiled, lowered
    except Exception as e:  # noqa: BLE001
        import traceback

        result["ok"] = False
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-3000:]
    finally:
        reg.get_config = orig
    result["total_s"] = round(time.time() - t0, 1)

    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape_name}__{name}.json").write_text(
        json.dumps(result, indent=1)
    )
    r = result.get("roofline", {})
    status = "OK" if result["ok"] else f"FAIL {result.get('error', '')[:60]}"
    print(
        f"[{status}] {arch} {shape_name} {name}: "
        f"comp={r.get('compute_s', 0):.4f}s coll={r.get('collective_s', 0):.4f}s "
        f"mem={r.get('memory_s', 0):.3f}s "
        f"peak={result.get('peak_bytes', 0)/2**30:.2f}GiB ({result['total_s']}s)",
        flush=True,
    )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=["moe", "vl", "rwkv"], required=True)
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()

    arch, shape, variants = _variants()[args.cell]
    todo = {args.variant: variants[args.variant]} if args.variant else variants
    for name, transform in todo.items():
        run_variant(arch, shape, name, transform, PERF_DIR)


if __name__ == "__main__":
    main()
