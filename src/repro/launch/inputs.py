"""input_specs(): ShapeDtypeStruct stand-ins + PartitionSpecs per cell.

Weak-type-correct, shardable, zero allocation — the dry-run lowers
``train_step`` / ``prefill_step`` / ``decode_step`` against these.

Family conventions (see DESIGN.md §5):
  * vlm: first seq_len//4 positions are precomputed patch embeddings
    (stub vision frontend) + M-RoPE (3, B, S) positions.
  * audio enc-dec: encoder consumes precomputed frame embeddings (B, S, D);
    the decoder sees seq_len text tokens (train/prefill) or a KV cache of
    seq_len (decode) with cross-attention onto the S-frame encoder memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.model import model as M
from repro.model.sharding import to_pspec


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Training/prefill batch: SDS tree + PartitionSpec tree."""
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    pspecs = {"tokens": ("batch", "seq")}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        pspecs["labels"] = ("batch", "seq")
    if cfg.frontend == "vision":
        s_f = s // 4
        specs["frontend_embeds"] = jax.ShapeDtypeStruct((b, s_f, cfg.d_model), _dt(cfg))
        pspecs["frontend_embeds"] = ("batch", "seq", "act_embed")
        specs["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        pspecs["positions"] = (None, "batch", "seq")
    if cfg.is_enc_dec:
        specs["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), _dt(cfg))
        pspecs["enc_embeds"] = ("batch", "seq", "act_embed")
    return specs, pspecs


def decode_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Decode step inputs: (state_sds, tokens_sds, length_sds) + pspec trees."""
    b, s = shape.global_batch, shape.seq_len
    state = M.abstract_decode_state(cfg, batch=b, max_len=s)
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    length = jax.ShapeDtypeStruct((), jnp.int32)
    extras = {}
    extras_pspecs = {}
    if cfg.is_enc_dec:
        extras["enc_out"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), _dt(cfg))
        extras_pspecs["enc_out"] = ("batch", "kv_seq", "act_embed")
    return state, tokens, length, extras, extras_pspecs


def resolve_pspecs(axes_tree, rules):
    """Map logical-axes tuples -> PartitionSpec via the rules table."""
    return jax.tree.map(
        lambda axes: to_pspec(axes, rules) if isinstance(axes, tuple) else P(),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) or x is None,
    )


def cell_is_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §5 skips)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            f"{cfg.name} is pure full-attention; 500k decode requires "
            "sub-quadratic context handling (documented skip, DESIGN.md §5)"
        )
    return True, ""
