"""Paper benchmark suite (Table 3) — shared-memory vs direct forwarding.

Run:  PYTHONPATH=src:. python examples/rodinia_suite.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import rodinia

if __name__ == "__main__":
    rodinia.main()
