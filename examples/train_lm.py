"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Builds a mid-size config from the qwen2 family (real vocab, 8 layers),
streams the deterministic synthetic corpus, checkpoints asynchronously,
and survives an injected failure via restart-from-checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; a few hundred steps takes a while on 1 CPU core — use
--d-model 256 --steps 60 for a quick pass.)
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.configs.registry import get_config
from repro.launch import train as train_launcher


def build_100m(d_model: int):
    base = get_config("qwen2-0.5b")
    return dataclasses.replace(
        base,
        name="qwen2-100m",
        num_layers=8,
        d_model=d_model,
        num_heads=8,
        num_kv_heads=2,
        head_dim=d_model // 8,
        d_ff=d_model * 4,
        vocab_size=32_768,
        dtype="float32",
        remat="none",
        microbatch=1,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = build_100m(args.d_model)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    import sys

    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    sys.argv = [
        "train", "--arch", "qwen2-0.5b", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", ckpt_dir, "--ckpt-every", "50", "--lr", "3e-4",
    ]
    # Patch the launcher's config resolution to our 100M model.
    import repro.configs.registry as reg

    orig = reg.get_config
    reg.get_config = lambda name: cfg if name == "qwen2-0.5b" else orig(name)
    try:
        train_launcher.main()
    finally:
        reg.get_config = orig


if __name__ == "__main__":
    main()
