"""Quickstart: the paper's primitives in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    from_thread_or_const,
    from_thread_or_mem,
    linear_scan,
    plan_cascade,
    tag_value,
)

# --- fromThreadOrConst: thread t reads thread t-1's value (Fig. 1c) -------
x = jnp.arange(8.0)
left_neighbor = from_thread_or_const(x, delta=1, const=0.0)
print("x:            ", x)
print("x[t-1] or 0:  ", left_neighbor)

# 1D convolution exactly as the paper writes it (margins = constant C):
kernel = jnp.asarray([0.25, 0.5, 0.25])
conv = (
    from_thread_or_const(x, 1, 0.0) * kernel[0]
    + x * kernel[1]
    + from_thread_or_const(x, -1, 0.0) * kernel[2]
)
print("conv3:        ", conv)

# --- prefix sum (Fig. 6): the elevator edge carries the running sum -------
sums = linear_scan(jnp.ones_like(x), tag_value(x, "sum"))
print("prefix sum:   ", sums)

# --- fromThreadOrMem: one thread loads, others receive forwarded (Fig. 2b)
mem = jnp.arange(10.0, 18.0)           # the values each thread WOULD load
pred = jnp.asarray([t % 4 == 0 for t in range(8)])  # only threads 0,4 load
shared_load = from_thread_or_mem(mem, pred, delta=1, window=4)
print("loads issued: ", int(pred.sum()), "of", mem.shape[0])
print("forwarded:    ", shared_load)

# --- cascading (paper Fig. 10a): Δ=18 with 16-entry token buffers ---------
plan = plan_cascade(18)
print("cascade for Δ=18:", plan.node_deltas, "spilled:", plan.spilled)

# --- the same edge across a device mesh (ICI elevator) --------------------
from jax.sharding import Mesh, PartitionSpec as P
import numpy as np
from repro.core import device_shift

if len(jax.devices()) > 1:
    mesh = Mesh(np.array(jax.devices()), ("x",))
    out = jax.shard_map(
        lambda v: device_shift(v, "x", 1, fill=-1.0),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"),
    )(jnp.arange(float(len(jax.devices()))))
    print("device-space elevator:", out)
else:
    print("(single device: device-space elevator demo skipped)")
